"""Unit coverage for the persistent block store subsystem
(``repro.storage``): roundtrips on both backends, WAL group-commit
durability, segment-footer index rebuild, torn-tail recovery, tombstone
persistence, cleanup-driven compaction with its space bound, batched
reads/readahead, reconcile, and the zero-byte cost-accounting contract.
"""
import numpy as np
import pytest

from repro.storage import (
    LogBlockStore, NpzBlockStore, SimulatedCost, make_store,
)

W1 = (0.0, 10.0)
W2 = (10.0, 20.0)


def _arrays(fill, cap=64, width=2, seed=0):
    rng = np.random.default_rng(seed)
    a = {
        "keys": np.zeros((cap,), np.int32),
        "timestamps": np.zeros((cap,), np.float64),
        "values": np.zeros((cap, width), np.float32),
    }
    a["keys"][:fill] = rng.integers(0, 99, fill)
    a["timestamps"][:fill] = rng.uniform(0.0, 100.0, fill)
    a["values"][:fill] = rng.normal(size=(fill, width))
    return a


@pytest.mark.parametrize("backend", ["log", "npz"])
def test_put_get_roundtrip(tmp_path, backend):
    s = make_store(backend, tmp_path)
    a = _arrays(17, seed=1)
    s.put(W1, 1, a, 17)
    s.commit()
    got = s.get(W1, 1)
    assert got is not None
    for k in ("keys", "timestamps", "values"):
        np.testing.assert_array_equal(got[k][:17], a[k][:17])
    # full-capacity shape restored (log re-pads the fill slice)
    assert got["keys"].shape == a["keys"].shape
    assert got["values"].shape == a["values"].shape
    assert s.current_fill(W1, 1) == 17
    assert s.get(W1, 2) is None
    assert s.current_fill(W2, 1) is None     # window is part of the key


@pytest.mark.parametrize("backend", ["log", "npz"])
def test_delete_tombstones(tmp_path, backend):
    s = make_store(backend, tmp_path)
    s.put(W1, 1, _arrays(8), 8)
    s.commit()
    s.delete(W1, 1)
    s.commit()
    assert s.get(W1, 1) is None
    assert s.live_bytes() == 0


def test_group_commit_durability(tmp_path):
    """A crash (reopen without close) keeps everything acknowledged and
    drops everything not — even fully-written records past the ack."""
    s = LogBlockStore(tmp_path, segment_bytes=64 << 10)
    a = _arrays(10, seed=2)
    s.put(W1, 1, a, 10)
    s.commit()                               # acknowledged
    s.put(W1, 2, _arrays(10, seed=3), 10)    # never acknowledged
    # no close(): simulated SIGKILL
    s2 = LogBlockStore(tmp_path, segment_bytes=64 << 10)
    assert s2.current_fill(W1, 1) == 10
    np.testing.assert_array_equal(s2.get(W1, 1)["values"], a["values"])
    assert s2.get(W1, 2) is None             # unacked -> dropped


def test_torn_tail_truncated_on_recovery(tmp_path):
    """Garbage appended past the last WAL ack (a crash mid-spill) is
    truncated away; acknowledged records survive intact."""
    s = LogBlockStore(tmp_path, segment_bytes=64 << 10)
    s.put(W1, 1, _arrays(12, seed=4), 12)
    s.commit()
    with open(s.active_segment_path(), "ab") as f:
        f.write(b"\xde\xad\xbe\xef" * 13)    # torn partial record
    s2 = LogBlockStore(tmp_path, segment_bytes=64 << 10)
    assert s2.stats["recovery_truncated_bytes"] >= 52
    assert s2.current_fill(W1, 1) == 12
    # the recovered store keeps working: appends land after the truncate
    s2.put(W1, 5, _arrays(5, seed=5), 5)
    s2.commit()
    s3 = LogBlockStore(tmp_path, segment_bytes=64 << 10)
    assert s3.current_fill(W1, 5) == 5


def test_footer_rebuild_across_segments(tmp_path):
    """Sealed segments rebuild the index from their footers on open; the
    re-put of a key supersedes across segment boundaries."""
    s = LogBlockStore(tmp_path, segment_bytes=8 << 10)
    for i in range(40):
        s.put(W1, i, _arrays(30, seed=i), 30)
    s.put(W1, 0, _arrays(11, seed=100), 11)   # supersede block 0
    s.commit()
    s.close()
    assert s.stats["segments_sealed"] > 1
    s2 = LogBlockStore(tmp_path, segment_bytes=8 << 10)
    assert s2.current_fill(W1, 0) == 11       # newest wins on replay
    for i in range(1, 40):
        assert s2.current_fill(W1, i) == 30
    got = s2.get(W1, 0)
    np.testing.assert_array_equal(got["values"],
                                  _arrays(11, seed=100)["values"])


def test_compaction_bound_and_no_resurrection(tmp_path):
    """Compaction consumes tombstones until on-disk <= max(2 x live,
    one segment); deleted keys stay deleted across compaction + reopen
    even when stale copies lived in older segments."""
    s = LogBlockStore(tmp_path, segment_bytes=8 << 10)
    for i in range(50):
        s.put(W2, i, _arrays(40, seed=i), 40)
    # stale copies: re-put half the keys so older segments hold dead
    # records for them
    for i in range(0, 50, 2):
        s.put(W2, i, _arrays(40, seed=500 + i), 40)
    s.commit()
    for i in range(45):
        s.delete(W2, i)
    s.commit()
    reclaimed = s.compact_if_needed(2.0)
    assert reclaimed > 0
    disk, live = s.on_disk_bytes(), s.live_record_bytes()
    assert disk <= max(2.0 * live, s.segment_bytes) + s.segment_bytes
    assert s.stats["bytes_compacted"] > 0
    s.close()
    s2 = LogBlockStore(tmp_path, segment_bytes=8 << 10)
    for i in range(45):
        assert s2.get(W2, i) is None, f"key {i} resurrected"
    for i in range(45, 50):
        assert s2.current_fill(W2, i) == 40


def test_compaction_after_total_purge_frees_almost_everything(tmp_path):
    s = LogBlockStore(tmp_path, segment_bytes=8 << 10)
    for i in range(30):
        s.put(W1, i, _arrays(40, seed=i), 40)
    s.commit()
    for i in range(30):
        s.delete(W1, i)
    s.commit()
    s.compact_if_needed(2.0)
    assert s.live_bytes() == 0
    # nothing live: the log shrinks to (at most) one segment of
    # carried tombstones/active headroom
    assert s.on_disk_bytes() <= s.segment_bytes + s.segment_bytes


def test_batched_read_and_readahead_cache(tmp_path):
    s = LogBlockStore(tmp_path, segment_bytes=16 << 10)
    want = {}
    for i in range(20):
        a = _arrays(25, seed=i)
        want[i] = a["values"].copy()
        s.put(W1, i, a, 25)
    s.commit()
    got = s.get_many([(W1, i) for i in range(20)])
    assert all(g is not None for g in got)
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g["values"], want[i])
    assert s.stats["batched_reads"] == 1
    # readahead turns the next demand gets into cache hits
    s.readahead([(W1, i) for i in range(5)])
    assert s.stats["readahead_bytes"] > 0
    h0 = s.stats["readahead_hits"]
    for i in range(5):
        assert s.get(W1, i) is not None
    assert s.stats["readahead_hits"] == h0 + 5
    # a re-put invalidates the cached copy
    s.readahead([(W1, 7)])
    fresh = _arrays(9, seed=777)
    s.put(W1, 7, fresh, 9)
    np.testing.assert_array_equal(s.get(W1, 7)["values"][:9],
                                  fresh["values"][:9])


def test_reconcile_drops_orphans(tmp_path):
    s = LogBlockStore(tmp_path, segment_bytes=16 << 10)
    for i in range(6):
        s.put(W1, i, _arrays(10, seed=i), 10)
    s.commit()
    dropped = s.reconcile([(W1, 0), (W1, 1)])
    assert dropped == 4
    assert s.current_fill(W1, 0) == 10
    assert s.get(W1, 3) is None
    s.close()
    s2 = LogBlockStore(tmp_path, segment_bytes=16 << 10)
    assert s2.get(W1, 3) is None             # tombstones were committed


def test_write_amplification_reported(tmp_path):
    s = LogBlockStore(tmp_path, segment_bytes=8 << 10)
    for i in range(20):
        s.put(W1, i, _arrays(40, seed=i), 40)
    s.commit()
    amp = s.write_amplification
    assert 1.0 <= amp < 1.5                  # framing overhead only
    for i in range(15):
        s.delete(W1, i)
    s.commit()
    s.compact_if_needed(1.0)
    # compaction rewrites count as physical writes
    assert s.write_amplification >= amp


def test_simulated_cost_zero_bytes_free():
    c = SimulatedCost(1.0)                   # absurdly expensive tier
    assert c.charge(0) == 0.0
    assert c.charge(-5) == 0.0
    assert c.total_seconds == 0.0


def test_empty_block_transfers_skip_sim_cost(tmp_path):
    """IOScheduler routes cost through the store model and never bills
    an empty block (regression: spill/fetch charged capacity bytes per
    call even at fill 0)."""
    from repro.core.buckets import Block, MemoryBudget
    from repro.core.staging import IOScheduler

    budget = MemoryBudget(1 << 20)
    io = IOScheduler(budget, spill_dir=tmp_path,
                     simulated_seconds_per_byte=1e-3)
    blk = Block.new(64, 1)                   # fill == 0
    blk.persisted = True
    assert io.fetch_block_host(blk) is not None
    io.spill_block_sync(blk)
    assert blk.fill == 0
    assert io.stats["simulated_io_seconds"] == 0.0
    assert io.simcost.total_seconds == 0.0
    io.shutdown()


def test_npz_backend_is_file_per_block(tmp_path):
    s = NpzBlockStore(tmp_path)
    ref = s.put(W1, 3, _arrays(10, seed=3), 10)
    assert ref.exists() and ref.name == "block_3.npz"
    s.delete(W1, 3)
    assert not ref.exists()                  # eager unlink, no tombstone
