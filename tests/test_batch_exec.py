"""Batched multi-window execution: parity vs the per-window reference.

The batched path (core/batch_exec.py) must produce results equal — up to
float associativity — to the per-window reference path, for every
operator that implements the batch contract, under a late-heavy scenario
where one poll batches live expiries AND late re-executions of many
windows at once.
"""
import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import StreamEngine, TumblingWindows
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger

WINDOW = 10.0
N_WINDOWS = 10


def _make_engine(op_name: str, batched: bool, block: int = 64,
                 width: int = 2, num_keys: int = 8,
                 pooled: bool = True) -> StreamEngine:
    aion = AionConfig(block_size=block, batched_execution=batched,
                      block_pool=pooled)
    kw = {}
    if op_name == "stock":
        kw = {"num_keys": num_keys}
    elif op_name == "lrb":
        kw = {"num_segments": num_keys}
    elif op_name == "bigrams":
        kw = {"vocab": 16}
    op = make_operator(op_name, block, width, **kw)
    return StreamEngine(
        assigner=TumblingWindows(WINDOW), operator=op, aion=aion,
        value_width=width, device_budget_bytes=64 << 20,
        trigger=DeltaTTrigger(executions=2),
    )


def _late_heavy_run(eng: StreamEngine, seed: int = 7):
    """Many concurrent windows expiring together, then a late wave into
    most of them — the batch path sees mixed-occupancy live and late
    batches."""
    rng = np.random.default_rng(seed)
    horizon = N_WINDOWS * WINDOW
    n = 3000
    b = EventBatch(rng.integers(0, 8, n),
                   rng.uniform(0, horizon, n),
                   rng.normal(size=(n, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(horizon, now=horizon)      # all windows expire
    nl = 900
    late = EventBatch(rng.integers(0, 8, nl),
                      rng.uniform(0, horizon - WINDOW, nl),
                      rng.normal(size=(nl, 2)).astype(np.float32))
    eng.ingest(late, now=horizon + 1.0)
    for t in np.linspace(horizon + 1,
                         horizon + 1 + 2 * eng.cleanup.current_bound(), 25):
        eng.poll(t)
    results = dict(eng.results)
    metrics = eng.metrics
    eng.close()
    return results, metrics


def _assert_equal_results(got, want, op_name):
    assert set(got) == set(want)
    for wid in want:
        g, w = got[wid], want[wid]
        if isinstance(w, dict):
            for k in w:
                np.testing.assert_allclose(
                    np.asarray(g[k], np.float64),
                    np.asarray(w[k], np.float64), rtol=1e-4, atol=1e-5,
                    err_msg=f"{op_name} {wid} field {k!r}")
        else:
            assert g == pytest.approx(w, rel=1e-4, abs=1e-5), \
                f"{op_name} {wid}"


@pytest.mark.parametrize("pooled", [True, False])
@pytest.mark.parametrize("op_name", ["average", "stock", "lrb", "bigrams"])
def test_batched_matches_reference_late_heavy(op_name, pooled):
    got, m_b = _late_heavy_run(_make_engine(op_name, batched=True,
                                            pooled=pooled))
    want, m_r = _late_heavy_run(_make_engine(op_name, batched=False,
                                             pooled=pooled))
    _assert_equal_results(got, want, op_name)
    # the batched run actually used the batch path, and with real occupancy
    assert m_b.batch_executions >= 1
    assert m_b.mean_batch_occupancy > 1.0
    assert m_b.batched_windows >= N_WINDOWS
    assert m_b.batch_device_seconds > 0.0
    if pooled:
        # zero-copy block-table rows carried the batch
        assert m_b.pooled_rows > 0
    else:
        assert m_b.pooled_rows == 0
    # the reference run never did
    assert m_r.batch_executions == 0
    # both executed every window live, and re-executed late ones
    assert m_b.live_executions == m_r.live_executions == N_WINDOWS
    assert m_b.late_executions >= 1 and m_r.late_executions >= 1


def test_live_batch_occupancy_counts_all_due_windows():
    """>= 8 concurrent due windows fold in ONE device pass."""
    eng = _make_engine("average", batched=True)
    rng = np.random.default_rng(3)
    n = 2000
    b = EventBatch(rng.integers(0, 8, n),
                   rng.uniform(0, N_WINDOWS * WINDOW, n),
                   rng.normal(size=(n, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(N_WINDOWS * WINDOW, now=N_WINDOWS * WINDOW)
    assert eng.metrics.batch_executions == 1
    assert eng.metrics.batch_occupancy_series == [N_WINDOWS]
    assert eng.metrics.live_executions == N_WINDOWS
    eng.close()


def test_percentile_batched_matches_quantile_oracle():
    """The blocking percentile operator now carries a real batch
    contract (sorted-run accumulators); the batched path must produce
    the same quantiles np.quantile computes from the raw events."""
    aion = AionConfig(block_size=64, batched_execution=True)
    op = make_operator("percentile", 64, 1)
    assert op.supports_batch          # fold_batch landed with split-K
    assert op.supports_splitk
    eng = StreamEngine(
        assigner=TumblingWindows(WINDOW), operator=op, aion=aion,
        value_width=1, device_budget_bytes=64 << 20,
        trigger=DeltaTTrigger(executions=1),
    )
    rng = np.random.default_rng(5)
    n = 1200
    b = EventBatch(np.zeros(n, np.int32), rng.uniform(0, 30.0, n),
                   rng.uniform(0, 1, (n, 1)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(30.0, now=30.0)
    assert eng.metrics.batch_executions >= 1
    from repro.core.windows import WindowId
    ts = b.timestamps
    for s in (0.0, 10.0, 20.0):
        sel = (ts >= s) & (ts < s + 10.0)
        res = eng.results[WindowId(s, s + 10.0)]
        for q in (0.5, 0.95, 0.99):
            want = float(np.quantile(b.values[sel, 0], q))
            assert res[q] == pytest.approx(want, rel=1e-4, abs=1e-5)
    eng.close()


def test_single_due_window_uses_reference_path():
    """A batch of one gains nothing from stacking; the executor routes it
    through execute_window."""
    eng = _make_engine("average", batched=True)
    rng = np.random.default_rng(9)
    b = EventBatch(rng.integers(0, 8, 300), rng.uniform(0, 10.0, 300),
                   rng.normal(size=(300, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(10.0, now=10.0)
    assert eng.metrics.live_executions == 1
    assert eng.metrics.batch_executions == 0
    from repro.core.windows import WindowId
    assert eng.results[WindowId(0.0, 10.0)] == pytest.approx(
        float(np.mean(b.values[:, 0])), rel=1e-4, abs=1e-5)
    eng.close()


def test_batched_respects_priority_rule_live_before_late():
    """Within one watermark+poll cycle, the live batch's executions land
    before the late batch's (paper §3: live work outranks re-execution)."""
    eng = _make_engine("average", batched=True)
    rng = np.random.default_rng(11)
    horizon = N_WINDOWS * WINDOW
    b = EventBatch(rng.integers(0, 8, 1500), rng.uniform(0, horizon, 1500),
                   rng.normal(size=(1500, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(horizon, now=horizon)
    live_first = eng.metrics.live_executions
    assert eng.metrics.late_executions == 0   # nothing late yet
    late = EventBatch(rng.integers(0, 8, 400),
                      rng.uniform(0, horizon - WINDOW, 400),
                      rng.normal(size=(400, 2)).astype(np.float32))
    eng.ingest(late, now=horizon + 1.0)
    for t in np.linspace(horizon + 1,
                         horizon + 1 + 2 * eng.cleanup.current_bound(), 20):
        eng.poll(t)
    assert eng.metrics.live_executions == live_first   # no new live work
    assert eng.metrics.late_executions >= 1
    eng.close()


# ------------------------------------------------------------ split-K path

def _make_splitk_engine(op_name: str, chunk: int, **kw) -> StreamEngine:
    import dataclasses
    eng = _make_engine(op_name, batched=True, **kw)
    eng.aion = dataclasses.replace(eng.aion, splitk_chunk_rows=chunk)
    return eng


@pytest.mark.parametrize("op_name",
                         ["average", "stock", "lrb", "percentile"])
def test_splitk_engine_parity(op_name):
    """splitk_chunk_rows > 0 changes only the fold decomposition: engine
    results match the unchunked batched run for every split-K operator,
    and the chunked path actually launched."""
    want, m0 = _late_heavy_run(_make_engine(op_name, batched=True))
    got, m1 = _late_heavy_run(_make_splitk_engine(op_name, chunk=2))
    _assert_equal_results(got, want, op_name)
    assert m1.splitk_launches > 0
    assert m0.splitk_launches == 0


def test_splitk_auto_disables_below_one_chunk():
    """Rounds smaller than one chunk per device fall back to the stripe
    fold — no split-K launches, identical results."""
    want, _ = _late_heavy_run(_make_engine("average", batched=True))
    got, m = _late_heavy_run(_make_splitk_engine("average", chunk=4096))
    _assert_equal_results(got, want, "average")
    assert m.splitk_launches == 0


def test_splitk_ignored_for_unsupported_operator():
    """bigrams' slot-ownership scatter cannot take balanced/chunked rows;
    the knob must be a no-op for it (supports_splitk=False)."""
    want, _ = _late_heavy_run(_make_engine("bigrams", batched=True))
    got, m = _late_heavy_run(_make_splitk_engine("bigrams", chunk=2))
    _assert_equal_results(got, want, "bigrams")
    assert m.splitk_launches == 0


def test_splitk_launch_shapes_closed_under_batch_size():
    """The zero-recompile property: whatever the pooled row count, the
    planner only ever emits launch groups of {1,2,4,8} x chunk rows, so
    a handful of warmed shapes serves every round."""
    eng = _make_splitk_engine("average", chunk=4)
    planner = eng.batch_exec

    class _Blk:
        fill = 3

    shapes = set()
    for rows in (5, 7, 16, 33, 100, 257, 1023):
        # (block, window_slot, pool_slot) rows; only the count matters
        fake = [(_Blk(), i % 7, i) for i in range(rows)]
        groups = planner._plan_table_groups(fake, num_devices=1,
                                            slots_per=7)
        for table, fills, slots, sk in groups:
            assert sk == 4
            assert table.shape == fills.shape == slots.shape
            shapes.add(int(table.shape[0]))
    assert shapes <= {4, 8, 16, 32}          # {1,2,4,8} groups x chunk 4
    eng.close()


def test_splitk_zero_recompiles_across_late_waves():
    """Across late waves of varying size the fold cache stops growing
    once the pow2 group shapes are warm."""
    eng = _make_splitk_engine("average", chunk=2)
    rng = np.random.default_rng(13)
    horizon = N_WINDOWS * WINDOW
    b = EventBatch(rng.integers(0, 8, 3000),
                   rng.uniform(0, horizon, 3000),
                   rng.normal(size=(3000, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(horizon, now=horizon)
    now = horizon
    sizes = (900, 333, 57, 1500, 64, 711)
    cache_after = []
    for nl in sizes:
        late = EventBatch(rng.integers(0, 8, nl),
                          rng.uniform(0, horizon - WINDOW, nl),
                          rng.normal(size=(nl, 2)).astype(np.float32))
        now += 1.0
        eng.ingest(late, now=now)
        for t in np.linspace(now, now + 2 * eng.cleanup.current_bound(),
                             10):
            eng.poll(t)
        now = t
        cache_after.append(eng.operator.fold_batch._cache_size())
    assert eng.metrics.splitk_launches > 0
    # the tail waves (every group shape warm) compile nothing new
    assert cache_after[-1] == cache_after[1], cache_after
    eng.close()


def test_splitk_all_rows_demoted_mid_round():
    """A round whose every pooled row demotes to the stacked fallback
    (no pool at all: classify finds zero resident rows) must still
    finish: zero chunk groups, correct results from fallback alone."""
    eng = _make_splitk_engine("average", chunk=2, pooled=False)
    got, m = _late_heavy_run(eng)
    want, _ = _late_heavy_run(_make_engine("average", batched=True,
                                           pooled=False))
    _assert_equal_results(got, want, "average")
    assert m.splitk_launches == 0          # nothing pooled to chunk
    assert m.batch_executions >= 1
