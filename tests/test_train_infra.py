import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.train import OptConfig, adamw_init, adamw_update, make_train_step
from repro.train.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, read_manifest, restore_checkpoint,
    save_checkpoint,
)
from repro.train.compression import (
    CompressorConfig, compress_grads, compression_ratio, init_error_feedback,
)
from repro.train.optimizer import global_norm, schedule
from repro.train.train_step import TrainState, init_train_state


def _tiny_state(seed=0):
    cfg = reduced(ARCHS["mamba2-780m"])
    model = build_model(cfg)
    return model, init_train_state(model, jax.random.PRNGKey(seed))


# ------------------------------------------------------------- optimizer
def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_adamw_clips_gradients():
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0)}
    _, _, stats = adamw_update(OptConfig(clip_norm=1.0), params, grads, opt)
    assert float(stats["grad_norm"]) == pytest.approx(400.0)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.3, warmup_steps=1, weight_decay=0.0,
                    total_steps=200)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    model, state = _tiny_state()
    path = save_checkpoint(tmp_path, state, step=7, metadata={"arch": "x"})
    assert latest_checkpoint(tmp_path) == path
    assert read_manifest(path)["step"] == 7
    restored = restore_checkpoint(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_over_existing(tmp_path):
    model, state = _tiny_state()
    save_checkpoint(tmp_path, state, step=1)
    model2, state2 = _tiny_state(seed=9)
    save_checkpoint(tmp_path, state2, step=2)
    latest = latest_checkpoint(tmp_path)
    restored = restore_checkpoint(latest, jax.eval_shape(lambda: state2))
    a = jax.tree.leaves(state2)[0]
    b = jax.tree.leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_gc(tmp_path):
    model, state = _tiny_state()
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(state, s, block=True)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]
    assert ck.last_saved_step == 4


def test_restore_after_simulated_crash(tmp_path):
    """Train, 'crash', restore, resume: state matches where it left off."""
    model, state = _tiny_state()
    step_fn = jax.jit(make_train_step(model, OptConfig(warmup_steps=1)))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 512, (2, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 512, (2, 16)), jnp.int32),
    }
    for i in range(3):
        state, _ = step_fn(state, batch)
    save_checkpoint(tmp_path, state, step=3)
    state_after, _ = step_fn(state, batch)       # step 4, then crash

    restored = restore_checkpoint(latest_checkpoint(tmp_path),
                                  jax.eval_shape(lambda: state))
    assert int(restored.opt["step"]) == 3
    resumed, _ = step_fn(restored, batch)
    for a, b in zip(jax.tree.leaves(resumed), jax.tree.leaves(state_after)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)


# ----------------------------------------------------------- compression
def test_int8_compression_bounded_error():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                          jnp.float32)}
    ef = init_error_feedback(g)
    sent, ef2 = compress_grads(CompressorConfig("int8"), g, ef)
    err = float(jnp.max(jnp.abs(sent["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert err <= scale * 0.5 + 1e-7
    # error feedback holds the residual
    np.testing.assert_allclose(np.asarray(ef2["w"]),
                               np.asarray(g["w"] - sent["w"]), atol=1e-7)


def test_error_feedback_preserves_long_run_average():
    """Sum of transmitted grads converges to the sum of true grads."""
    rng = np.random.default_rng(1)
    cfg = CompressorConfig("topk", topk_frac=0.2)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    ef = {"w": jnp.zeros((64,), jnp.float32)}
    total_sent = jnp.zeros((64,))
    n = 60
    for _ in range(n):
        sent, ef = compress_grads(cfg, {"w": g_true}, ef)
        total_sent = total_sent + sent["w"]
    np.testing.assert_allclose(np.asarray(total_sent / n),
                               np.asarray(g_true), atol=0.05)


def test_compression_ratio_values():
    assert compression_ratio(CompressorConfig("int8")) == 0.25
    assert compression_ratio(CompressorConfig("none")) == 1.0
    assert compression_ratio(CompressorConfig("topk", topk_frac=0.01)) \
        == pytest.approx(0.02)


def test_train_step_with_compression_runs():
    model, state = _tiny_state()
    ef = init_error_feedback(state.params)
    holder = {"ef": ef}

    def transform(grads):
        sent, holder["ef"] = compress_grads(CompressorConfig("int8"), grads,
                                            holder["ef"])
        return sent

    step = make_train_step(model, grad_transform=transform)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 512, (2, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 512, (2, 16)), jnp.int32),
    }
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_elastic_reshard_roundtrip():
    """A train state moves onto a (trivial 1x1) mesh and values survive."""
    import jax
    from repro.configs.base import MeshConfig
    from repro.train.elastic import adjust_batch_schedule, elastic_reshard

    model, state = _tiny_state()
    mesh_cfg = MeshConfig((1, 1), ("data", "model"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    new_state = elastic_reshard(state, model, mesh, mesh_cfg,
                                global_batch=8)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    per_shard, step = adjust_batch_schedule(256, old_dp=16, new_dp=8, step=7)
    assert per_shard == 32 and step == 7
    with pytest.raises(ValueError):
        adjust_batch_schedule(256, 16, 7, 0)
