"""Persistent device block pool: slot lifecycle, exhaustion fallback,
purge/destage exactly-once slot frees, snapshot immutability, and
engine-level parity of the pooled batched path."""
import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import StreamEngine, TumblingWindows
from repro.core.block_pool import DeviceBlockPool
from repro.core.buckets import Block, MemoryBudget, Tier
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.staging import IOScheduler
from repro.core.triggers import DeltaTTrigger

CAP, W = 16, 1


def _block(key_val=1, fill=CAP):
    b = Block.new(CAP, W)
    b.host_data["keys"][:] = key_val
    b.host_data["values"][:] = float(key_val)
    b.fill = fill
    return b


# ------------------------------------------------------------ pool basics
def test_alloc_free_cycle_and_exhaustion():
    pool = DeviceBlockPool(4, CAP, W)
    slots = [pool.alloc() for _ in range(4)]
    assert sorted(slots) == [0, 1, 2, 3]
    assert pool.alloc() is None                  # exhausted, no crash
    assert pool.stats["exhausted"] == 1
    pool.free(slots[0])
    assert pool.alloc() == slots[0]


def test_sharded_ranges_no_cross_shard_stealing():
    pool = DeviceBlockPool(8, CAP, W, num_shards=4)
    assert pool.slots_per_shard == 2
    a = pool.alloc(shard=1)
    b = pool.alloc(shard=1)
    assert {pool.shard_of_slot(a), pool.shard_of_slot(b)} == {1}
    # shard 1 range full: no stealing from other shards (a foreign slot
    # could never appear in shard 1's block table)
    assert pool.alloc(shard=1) is None
    assert pool.alloc(shard=2) is not None


def test_commit_read_roundtrip():
    pool = DeviceBlockPool(4, CAP, W)
    blk = _block(7)
    slot = pool.alloc()
    with blk.lock:
        pool.commit(blk, slot, blk.host_data)
    assert blk.pool_slot == slot and blk.pool is pool
    d = pool.read_block(blk)
    np.testing.assert_array_equal(np.asarray(d["keys"]),
                                  blk.host_data["keys"])
    np.testing.assert_allclose(np.asarray(d["values"]),
                               blk.host_data["values"])


def test_snapshot_immutable_under_slot_reuse_while_pinned():
    """A pinned snapshot must survive its slot being freed, reused and
    rewritten — pinned writes take the functional (copy) path, so old
    arena references stay live and unchanged."""
    pool = DeviceBlockPool(1, CAP, W)
    a = _block(1)
    slot = pool.alloc()
    with a.lock:
        pool.commit(a, slot, a.host_data)
    with pool.pinned():
        k_arena, v_arena, slots = pool.snapshot_for([a])
        assert slots == [slot]
        pool.release_slot(a)
        b = _block(9)
        slot2 = pool.alloc()
        assert slot2 == slot                     # same physical slot
        with b.lock:
            pool.commit(b, slot2, b.host_data)
        assert pool.stats["copy_writes"] == 1    # pinned -> functional
        # the old snapshot still reads block a's data
        assert int(np.asarray(k_arena)[slot][0]) == 1
        # the pool's current arena reads block b's
        assert int(np.asarray(pool.keys)[slot][0]) == 9


def test_unpinned_writes_update_in_place():
    """Outside a pinned section, fills donate the arena buffers (O(block)
    updates); the pool's current view always reads the new data."""
    pool = DeviceBlockPool(2, CAP, W)
    a, b = _block(3), _block(5)
    for blk in (a, b):
        s = pool.alloc()
        with blk.lock:
            pool.commit(blk, s, blk.host_data)
    assert pool.stats["copy_writes"] == 0        # both writes donated
    for blk in (a, b):
        d = pool.read_block(blk)
        np.testing.assert_array_equal(np.asarray(d["keys"]),
                                      blk.host_data["keys"])


def test_deferred_fills_batch_into_one_scatter():
    """Inside ``deferred_fills`` commits buffer; the next snapshot/read
    flushes them as ONE batched scatter (k fills cost one arena commit,
    not k functional copies under a pin)."""
    pool = DeviceBlockPool(8, CAP, W)
    blocks = [_block(i + 1) for i in range(4)]
    with pool.pinned(), pool.deferred_fills():
        for blk in blocks:
            s = pool.alloc()
            with blk.lock:
                pool.commit(blk, s, blk.host_data)
        assert pool.stats["deferred_fills"] == 4
        assert pool.stats["batched_fill_commits"] == 0
        # reads flush first: no path observes a slot without its data
        d = pool.read_block(blocks[0])
        np.testing.assert_array_equal(np.asarray(d["keys"]),
                                      blocks[0].host_data["keys"])
        assert pool.stats["batched_fill_commits"] == 1
        assert pool.stats["copy_writes"] == 1     # pinned -> one copy
    for blk in blocks:
        d = pool.read_block(blk)
        np.testing.assert_array_equal(np.asarray(d["keys"]),
                                      blk.host_data["keys"])
    assert pool.stats["batched_fill_commits"] == 1  # nothing re-flushed


def test_deferred_fill_dropped_when_slot_released():
    """A purge racing a deferred fill discards the buffered write: the
    slot returns free and a later occupant is never overwritten."""
    pool = DeviceBlockPool(1, CAP, W)
    a, b = _block(3), _block(9)
    with pool.deferred_fills():
        slot = pool.alloc()
        with a.lock:
            pool.commit(a, slot, a.host_data)
        pool.release_slot(a)                 # purge wins the race
        slot2 = pool.alloc()
        assert slot2 == slot
        with b.lock:
            pool.commit(b, slot2, b.host_data)
    d = pool.read_block(b)
    np.testing.assert_array_equal(np.asarray(d["keys"]),
                                  b.host_data["keys"])  # b, not a


# --------------------------------------------------- exactly-once slot free
def test_purge_while_pooled_frees_slot_exactly_once():
    pool = DeviceBlockPool(4, CAP, W)
    blk = _block()
    slot = pool.alloc()
    with blk.lock:
        pool.commit(blk, slot, blk.host_data)
    blk.tier = Tier.DEVICE
    assert pool.free_slots() == 3
    blk.drop()
    assert pool.free_slots() == 4
    assert blk.pool_slot is None
    blk.drop()                                   # idempotent second drop
    assert pool.free_slots() == 4
    assert pool.stats["frees"] == 1


def test_destage_then_purge_single_free():
    aion = AionConfig(block_size=CAP, pool_slots=4)
    budget = MemoryBudget(1 << 20)
    pool = DeviceBlockPool(4, CAP, W)
    io = IOScheduler(budget, pool=pool)
    blk = _block()
    assert io.stage_block_sync(blk)
    assert blk.pool_slot is not None and blk.tier == Tier.DEVICE
    assert io.stats["pool_fills"] == 1
    io.destage_block_sync(blk)
    assert blk.pool_slot is None and blk.tier == Tier.HOST
    assert pool.free_slots() == 4
    blk.drop()                                   # slot already surrendered
    assert pool.free_slots() == 4
    assert pool.stats["frees"] == 1
    io.shutdown()


def test_stage_racing_drop_releases_own_slot_and_budget():
    """A stage whose block was dropped mid-transfer frees the slot it
    allocated and its budget reservation (the drop never saw the slot)."""
    budget = MemoryBudget(1 << 20)
    pool = DeviceBlockPool(4, CAP, W)
    io = IOScheduler(budget, pool=pool)
    blk = _block()
    blk.dropped = True                # drop landed while request queued
    assert io.stage_block_sync(blk) is False
    assert pool.free_slots() == 4
    assert budget.used_bytes == 0
    io.shutdown()


def test_arena_cap_never_exceeded_by_shard_rounding():
    """Regression: the arena-size clamp rounds DOWN to the shard
    multiple, so a sharded pool never exceeds max_arena_bytes (the
    engine's at-most-half-budget guarantee); below one slot per shard
    the pool disables itself."""
    row = CAP * (4 + 4 * W)
    p = DeviceBlockPool(256, CAP, W, num_shards=8,
                        max_arena_bytes=20 * row)
    assert p.pool_slots == 16                 # 20 rounded DOWN to 8|16
    assert p.arena_bytes <= 20 * row
    tiny = DeviceBlockPool(256, CAP, W, num_shards=8,
                           max_arena_bytes=5 * row)
    assert tiny.pool_slots == 0               # < 1 slot/shard: disabled


def test_concurrent_duplicate_stage_leaks_no_slot():
    """Regression: a prestage racing a demand stage of the same block
    (thread-pool ablation) must not orphan a pool slot — the loser of
    the commit race surrenders its duplicate and reports success."""
    import threading
    budget = MemoryBudget(1 << 20)
    pool = DeviceBlockPool(8, CAP, W)
    io = IOScheduler(budget, pool=pool)
    for _ in range(10):
        blk = _block()
        ts = [threading.Thread(target=io.stage_block_sync, args=(blk,))
              for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert blk.tier == Tier.DEVICE and blk.pool_slot is not None
        io.destage_block_sync(blk)
    assert pool.free_slots() == 8             # every slot came back
    assert budget.used_bytes == 0
    io.shutdown()


def test_commit_uses_caller_snapshot_not_host_data():
    """Regression: a spill can null ``block.host_data`` between a
    stage's host read and its commit; the commit must write the caller's
    snapshot rather than crash (and leak the slot + budget bytes)."""
    pool = DeviceBlockPool(2, CAP, W)
    blk = _block(4)
    slot = pool.alloc()
    hd = blk.host_data
    blk.host_data = None                  # the racing spill's effect
    with blk.lock:
        pool.commit(blk, slot, hd)
    d = pool.read_block(blk)
    np.testing.assert_array_equal(np.asarray(d["keys"]), hd["keys"])


def test_respilled_block_not_leaked_after_device_restage(tmp_path):
    """Regression: a spill candidate popped from the LRU while it is
    device-resident (stage keeps the host shadow) must not stay counted
    as unevictable host bytes — it un-accounts on the failed spill and
    re-registers at its next destage."""
    budget = MemoryBudget(1 << 20)
    pool = DeviceBlockPool(4, CAP, W)
    io = IOScheduler(budget, pool=pool, spill_dir=tmp_path,
                     host_budget_bytes=1 << 30)
    blk = _block()
    assert io.stage_block_sync(blk)
    io.destage_block_sync(blk)            # accounted + in the spill LRU
    assert io._host_bytes == blk.nbytes
    assert io.stage_block_sync(blk)       # back to device, shadow kept
    io.host_budget_bytes = 0
    io._maybe_spill()                     # pops blk; cannot spill (DEVICE)
    assert io._host_bytes == 0            # un-accounted, not leaked
    io.destage_block_sync(blk)            # re-accounts, re-registers,
    assert blk.tier == Tier.STORAGE       # and immediately spills
    assert io._host_bytes == 0
    io.shutdown()


def test_drain_waits_for_threadpool_tasks():
    """Regression: drain() must cover in-flight tasks in the
    sequential_io=False (thread-pool) mode too, where nothing ever
    enters the priority queue."""
    import time as _t
    io = IOScheduler(MemoryBudget(1 << 20), sequential_io=False)
    done = []

    def slow():
        _t.sleep(0.15)
        done.append(1)
    io.submit(0, slow)
    io.drain()
    assert done == [1]
    io.shutdown()


def test_pool_exhaustion_falls_back_to_device_put():
    budget = MemoryBudget(1 << 20)
    pool = DeviceBlockPool(1, CAP, W)
    io = IOScheduler(budget, pool=pool)
    b1, b2 = _block(1), _block(2)
    assert io.stage_block_sync(b1)
    assert b1.pool_slot is not None
    assert io.stage_block_sync(b2)               # pool full -> legacy path
    assert b2.pool_slot is None and b2.device_data is not None
    assert b2.tier == Tier.DEVICE
    assert io.stats["pool_fallbacks"] == 1
    # both read device-side through the batched gather helper
    for b in (b1, b2):
        d = io.fetch_block_arrays(b)
        np.testing.assert_array_equal(np.asarray(d["keys"]),
                                      b.host_data["keys"])
    io.shutdown()


# ------------------------------------------------------------ engine level
def _run_engine(pooled, pool_slots=256, overlap=True, budget=64 << 20,
                op_name="stock", seed=3):
    aion = AionConfig(block_size=64, batched_execution=True,
                      block_pool=pooled, pool_slots=pool_slots,
                      pool_overlap_prefetch=overlap)
    op = make_operator(op_name, 64, 1, **(
        {"num_keys": 8} if op_name == "stock" else {}))
    eng = StreamEngine(assigner=TumblingWindows(10.0), operator=op,
                       aion=aion, value_width=1,
                       device_budget_bytes=budget,
                       trigger=DeltaTTrigger(executions=2))
    rng = np.random.default_rng(seed)
    n = 2500
    b = EventBatch(rng.integers(0, 8, n), rng.uniform(0, 80.0, n),
                   rng.normal(size=(n, 1)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(80.0, now=80.0)
    late = EventBatch(rng.integers(0, 8, 600), rng.uniform(0, 70.0, 600),
                      rng.normal(size=(600, 1)).astype(np.float32))
    eng.ingest(late, now=81.0)
    for t in np.linspace(81, 81 + 2 * eng.cleanup.current_bound(), 15):
        eng.poll(t)
    results = dict(eng.results)
    metrics = eng.metrics
    eng.close()
    return results, metrics


def _assert_results_equal(got, want):
    assert set(got) == set(want)
    for wid in want:
        g, w = got[wid], want[wid]
        for k in w:
            np.testing.assert_allclose(np.asarray(g[k], np.float64),
                                       np.asarray(w[k], np.float64),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{wid} {k}")


def test_pooled_engine_matches_unpooled():
    want, m_ref = _run_engine(False)
    got, m_pool = _run_engine(True)
    _assert_results_equal(got, want)
    assert m_pool.pooled_rows > 0                # table path actually ran
    assert m_ref.pooled_rows == 0


def test_pool_slot_exhaustion_engine_parity():
    """A pool far smaller than the working set degrades rows to the
    stacked fallback without changing any result."""
    want, _ = _run_engine(False)
    got, m = _run_engine(True, pool_slots=2)
    _assert_results_equal(got, want)
    assert m.fallback_rows > 0                   # fallback actually ran
    assert m.pooled_rows > 0


def test_overlap_prefetch_off_parity():
    """pool_overlap_prefetch=False: cold p-blocks read host-side (PR-3
    behaviour), no demand fills are issued from the executor."""
    want, _ = _run_engine(False)
    got, m = _run_engine(True, overlap=False, budget=192 << 10)
    _assert_results_equal(got, want)
    assert m.demand_pool_fills == 0


def test_overlap_prefetch_issues_demand_fills_under_pressure():
    want, _ = _run_engine(False)
    got, m = _run_engine(True, overlap=True, budget=192 << 10)
    _assert_results_equal(got, want)
    assert m.demand_pool_fills > 0


def test_checkpoint_restore_with_pooled_blocks():
    """Pooled blocks checkpoint their event data and restore host-side
    (device placement is re-decided after restart)."""
    aion = AionConfig(block_size=32, block_pool=True, pool_slots=64)
    op = make_operator("average", 32, 1)
    eng = StreamEngine(assigner=TumblingWindows(10.0), operator=op,
                       aion=aion, value_width=1,
                       device_budget_bytes=16 << 20,
                       trigger=DeltaTTrigger(executions=1))
    rng = np.random.default_rng(11)
    b = EventBatch(rng.integers(0, 4, 500), rng.uniform(0, 30.0, 500),
                   rng.normal(size=(500, 1)).astype(np.float32))
    eng.ingest(b, now=0.0)
    assert any(blk.pool_slot is not None
               for st in eng.windows.values() for blk in st.blocks)
    snap = eng.checkpoint_state()
    eng.close()

    eng2 = StreamEngine(assigner=TumblingWindows(10.0), operator=op,
                        aion=aion, value_width=1,
                        device_budget_bytes=16 << 20,
                        trigger=DeltaTTrigger(executions=1))
    eng2.restore_state(snap)
    total = sum(st.total_events for st in eng2.windows.values())
    assert total == 500
    eng2.advance_watermark(40.0, now=40.0)
    from repro.core.windows import WindowId
    for s in (0.0, 10.0, 20.0):
        sel = (b.timestamps >= s) & (b.timestamps < s + 10.0)
        if not sel.any():
            continue
        assert eng2.results[WindowId(s, s + 10.0)] == pytest.approx(
            float(np.mean(b.values[sel, 0])), rel=1e-4, abs=1e-4)
    eng2.close()


def test_pool_disabled_has_no_pool():
    aion = AionConfig(block_size=32, block_pool=False)
    op = make_operator("average", 32, 1)
    eng = StreamEngine(assigner=TumblingWindows(10.0), operator=op,
                       aion=aion, value_width=1,
                       trigger=DeltaTTrigger(executions=1))
    assert eng.pool is None and eng.io.pool is None
    eng.close()
