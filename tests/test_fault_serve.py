import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cleanup import PredictiveCleanup
from repro.distributed.fault import (
    BackupExecutor, EngineRecovery, HeartbeatMonitor, RestartManager,
)
from repro.kernels import ref as R
from repro.serve.kvcache import TieredKVCache
from repro.serve.scheduler import ContinuousBatcher, Request


# ------------------------------------------------------------------ fault
def test_heartbeat_detects_dead_worker():
    hb = HeartbeatMonitor(timeout=1.0)
    hb.beat("w0", now=0.0)
    hb.beat("w1", now=0.0)
    hb.beat("w0", now=5.0)
    assert hb.dead_workers(now=5.5) == ["w1"]
    assert hb.alive_workers(now=5.5) == ["w0"]


def test_backup_executor_straggler_win():
    ex = BackupExecutor(deadline_factor=2.0, min_deadline=0.05)
    calls = {"n": 0}

    def fast():
        return 42

    # warm the EWMA with fast tasks
    for _ in range(3):
        assert ex.run(fast) == 42

    def sometimes_slow():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.5)       # primary straggles
        return 7

    assert ex.run(sometimes_slow) == 7
    assert ex.stats.backups_issued >= 1
    ex.shutdown()


def test_restart_manager_recovers_from_crash():
    saved = {}
    crashes = {"left": 2}

    def step_fn(state, step):
        if crashes["left"] > 0 and step == 5:
            crashes["left"] -= 1
            raise RuntimeError("node failure")
        return state + 1

    rm = RestartManager(save_every=2, max_restarts=5)
    out = rm.run(
        init_state=lambda: 0,
        restore=lambda: (saved["s"], saved["step"]) if saved else None,
        step_fn=step_fn,
        save=lambda s, step: saved.update(s=s, step=step),
        num_steps=10,
    )
    assert rm.restarts == 2
    assert out == 10              # all 10 steps were executed exactly once


def test_heartbeat_timeout_edges():
    """Exactly-at-timeout is alive (strict >); just past it is dead; a
    fresh beat resurrects; an unknown worker is neither."""
    hb = HeartbeatMonitor(timeout=1.0)
    hb.beat("w0", now=0.0)
    assert hb.dead_workers(now=1.0) == []          # boundary: still alive
    assert hb.alive_workers(now=1.0) == ["w0"]
    assert hb.dead_workers(now=1.0 + 1e-9) == ["w0"]
    hb.beat("w0", now=2.0)                         # resurrection
    assert hb.alive_workers(now=2.5) == ["w0"]
    assert hb.dead_workers(now=2.5) == []
    assert "ghost" not in hb.alive_workers(now=2.5) \
        and "ghost" not in hb.dead_workers(now=2.5)


def test_backup_executor_first_result_wins_and_stats():
    """The primary straggles forever; the backup's answer is returned.
    Stats account every launch/backup/win."""
    ex = BackupExecutor(workers=4, deadline_factor=2.0, min_deadline=0.05)
    try:
        for _ in range(3):                          # warm the EWMA fast
            assert ex.run(lambda: 1) == 1
        calls = {"n": 0}

        def primary_hangs():
            calls["n"] += 1
            if calls["n"] == 1:
                time.sleep(2.0)                     # primary: straggler
                return "primary"
            return "backup"
        assert ex.run(primary_hangs) == "backup"    # first result wins
        assert ex.stats.launched == 4
        assert ex.stats.backups_issued == 1
        assert ex.stats.backup_wins == 1
    finally:
        ex.shutdown()


def test_backup_executor_propagates_task_failure():
    ex = BackupExecutor(workers=2, min_deadline=5.0)
    try:
        with pytest.raises(IOError, match="both copies fail"):
            ex.run(lambda: (_ for _ in ()).throw(
                IOError("both copies fail")))
    finally:
        ex.shutdown()


def test_restart_manager_exceeds_max_restarts():
    rm = RestartManager(save_every=10, max_restarts=2)
    with pytest.raises(RuntimeError, match="always down"):
        rm.run(init_state=lambda: 0,
               restore=lambda: None,
               step_fn=lambda s, step: (_ for _ in ()).throw(
                   RuntimeError("always down")),
               save=lambda s, step: None,
               num_steps=5)
    assert rm.restarts == 3                # 2 allowed restarts + the raise


def test_restart_manager_restore_loop_resumes_at_checkpoint():
    """Steps between the last save and the crash re-execute; steps
    before it never do (the executed-step log proves the resume point)."""
    saved = {}
    log = []
    crashes = {"left": 1}

    def step_fn(state, step):
        log.append(step)
        if crashes["left"] and step == 7:
            crashes["left"] -= 1
            raise RuntimeError("crash at 7")
        return state + 1

    rm = RestartManager(save_every=3, max_restarts=3)
    out = rm.run(
        init_state=lambda: 0,
        restore=lambda: (saved["s"], saved["step"]) if saved else None,
        step_fn=step_fn,
        save=lambda s, step: saved.update(s=s, step=step),
        num_steps=9)
    assert out == 9
    # crashed at 7 after saving at 6: resume replays 7, never 0..5
    assert log == [0, 1, 2, 3, 4, 5, 6, 7, 6, 7, 8]


def test_engine_recovery_checkpoint_restore_roundtrip(tmp_path):
    from repro.configs.base import AionConfig
    from repro.core import (
        EventBatch, StreamEngine, TumblingWindows, make_operator,
    )
    rng = np.random.default_rng(11)
    batch = EventBatch(rng.integers(0, 8, 96), rng.uniform(0.0, 10.0, 96),
                       rng.normal(size=(96, 1)).astype(np.float32))
    aion = AionConfig(block_size=32)

    def factory():
        # reopening the store directory IS the WAL replay
        return StreamEngine(
            assigner=TumblingWindows(10.0),
            operator=make_operator("average", aion.block_size, 1),
            aion=aion, value_width=1, spill_dir=tmp_path)

    rec = EngineRecovery(factory, max_restarts=2)
    assert not rec.has_checkpoint
    eng = factory()
    eng.ingest(batch, now=1.0)
    rec.checkpoint(eng, token=96)
    assert rec.has_checkpoint
    eng.close()                            # the "crash" (clean here)

    eng2, token = rec.restore()
    assert token == 96
    assert sum(s.total_events for s in eng2.windows.values()) == 96
    eng2.advance_watermark(10.0, now=2.0)
    result = next(iter(eng2.results.values()))
    assert result is not None
    eng2.close()

    eng3, _ = rec.restore()                # second allowed restart
    eng3.close()
    with pytest.raises(RuntimeError, match="max_restarts"):
        rec.restore()


def test_engine_recovery_requires_checkpoint():
    rec = EngineRecovery(lambda: None, max_restarts=1)
    with pytest.raises(RuntimeError, match="no checkpoint"):
        rec.restore()


# ------------------------------------------------------------------ serve
def _cache(pages=8, page=16, hkv=2, d=32, layers=1):
    return TieredKVCache(num_device_pages=pages, page_size=page,
                         num_kv_heads=hkv, head_dim=d, num_layers=layers,
                         dtype=jnp.float32,
                         cleanup=PredictiveCleanup(min_history=10**9,
                                                   initial_bound=1e9))


def test_kvcache_append_and_table():
    c = _cache()
    c.open_session(1, now=0.0)
    rng = np.random.default_rng(0)
    for t in range(40):
        ok = c.append_token_kv(1, rng.normal(size=(1, 2, 32)),
                               rng.normal(size=(1, 2, 32)), now=float(t))
        assert ok
    table, lens, missing = c.block_table([1], pages_per_seq=4)
    assert int(lens[0]) == 40
    assert (np.asarray(table[0]) >= 0).sum() == 3     # ceil(40/16)
    assert not missing


def test_kvcache_offload_and_restage_preserves_contents():
    """Fill beyond the device pool; evicted pages restage losslessly —
    the attention result equals an un-tiered reference."""
    rng = np.random.default_rng(1)
    c = _cache(pages=4, page=8)
    ks, vs = [], []
    c.open_session(1, now=0.0)
    c.open_session(2, now=0.0)
    # session 2 is predicted idle (big gap), session 1 active
    c.sessions[2].gap_ewma = 1e6
    c.sessions[1].gap_ewma = 0.01
    for t in range(24):
        k = rng.normal(size=(1, 2, 32)).astype(np.float32)
        v = rng.normal(size=(1, 2, 32)).astype(np.float32)
        sid = 1 if t % 2 == 0 else 2
        assert c.append_token_kv(sid, k, v, now=float(t))
        (ks if sid == 1 else vs).append(None)  # bookkeeping only
    # force all of session 2 out, then bring it back
    for li, pg in enumerate(list(c.sessions[2].pages)):
        if pg >= 0:
            c._destage_page(2, li)
    assert all(p < 0 for p in c.sessions[2].pages)
    for li in list(c.sessions[2].host_pages):
        assert c._stage_page(2, li, now=100.0)
    assert all(p >= 0 for p in c.sessions[2].pages)
    assert c.stats["destaged"] >= 1 and c.stats["staged"] >= 1


def test_kvcache_tiered_attention_matches_reference():
    rng = np.random.default_rng(2)
    pages, page, hkv, d = 6, 8, 2, 32
    c = _cache(pages=pages, page=page, hkv=hkv, d=d)
    c.open_session(1, now=0.0)
    n_tok = 30
    k_all = rng.normal(size=(n_tok, 1, hkv, d)).astype(np.float32)
    v_all = rng.normal(size=(n_tok, 1, hkv, d)).astype(np.float32)
    for t in range(n_tok):
        c.append_token_kv(1, k_all[t], v_all[t], now=float(t))
    # destage page 1, then ask for the table (reports missing), restage
    c._destage_page(1, 1)
    table, lens, missing = c.block_table([1], pages_per_seq=4)
    assert missing == [(1, 1)]
    assert c._stage_page(1, 1, now=50.0)
    table, lens, _ = c.block_table([1], pages_per_seq=4)

    q = jnp.asarray(rng.normal(size=(1, 4, d)), jnp.float32)
    out = R.ref_decode_attention_paged(q, c.k_pool[0], c.v_pool[0],
                                       table, lens)
    # reference over the raw (untiered) kv
    k_flat = jnp.asarray(k_all[:, 0])       # [n, hkv, d]
    pad = 4 * page - n_tok
    kp = jnp.pad(k_flat, ((0, pad), (0, 0), (0, 0))).reshape(4, page, hkv, d)
    vp = jnp.pad(jnp.asarray(v_all[:, 0]),
                 ((0, pad), (0, 0), (0, 0))).reshape(4, page, hkv, d)
    ref = R.ref_decode_attention_paged(
        q, kp, vp, jnp.arange(4, dtype=jnp.int32)[None],
        jnp.asarray([n_tok], jnp.int32))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_kvcache_predictive_cleanup_evicts_idle_sessions():
    c = _cache()
    c.cleanup = PredictiveCleanup(coverage=0.9, confidence=0.9,
                                  min_history=10, initial_bound=1e9)
    rng = np.random.default_rng(3)
    c.open_session(1, now=0.0)
    c.open_session(2, now=0.0)
    for t in range(8):
        c.append_token_kv(1, rng.normal(size=(1, 2, 32)),
                          rng.normal(size=(1, 2, 32)), now=0.1 * t)
        c.observe_arrival(1, now=0.1 * t)
    c.cleanup.observe(rng.uniform(0.05, 0.2, 1000))   # short gaps typical
    assert c.cleanup.current_bound() < 1.0
    evicted = c.cleanup_idle(now=100.0)               # both long idle
    assert evicted == 2 and not c.sessions


def test_continuous_batcher_completes_requests():
    rng = np.random.default_rng(4)
    hkv, d, page = 2, 32, 8
    c = _cache(pages=16, page=page, hkv=hkv, d=d)
    sched = ContinuousBatcher(c, max_batch=2, pages_per_seq=8)
    for rid in range(3):
        req = Request(request_id=rid, session_id=rid, prompt_len=5,
                      max_new_tokens=4, arrived_at=0.0)
        kp = rng.normal(size=(1, 5, hkv, d)).astype(np.float32)
        vp = rng.normal(size=(1, 5, hkv, d)).astype(np.float32)
        sched.submit(req, kp, vp, now=0.0)

    def q_fn(sids):
        return jnp.asarray(rng.normal(size=(len(sids), 4, d)), jnp.float32)

    def kv_fn(sids):
        return (rng.normal(size=(len(sids), 1, hkv, d)).astype(np.float32),
                rng.normal(size=(len(sids), 1, hkv, d)).astype(np.float32))

    t = 1.0
    for _ in range(20):
        out = sched.step(q_fn, kv_fn, now=t)
        t += 0.1
        if len(sched.completed) == 3:
            break
    assert len(sched.completed) == 3
    assert all(r.generated == 4 for r in sched.completed)
