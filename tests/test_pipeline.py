"""Pipelined fold execution (ISSUE 6 tentpole): parity with the
synchronous engine, genuine ingest/fold overlap, futures-based emission,
the per-slot epoch scheme's demotion path, and the cleanup purge guard.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import (
    EventBatch, PipelineError, StreamEngine, TumblingWindows, make_operator,
)
from repro.core.batch_exec import BatchWorkItem
from repro.core.pipeline import EnginePipeline


def _batch(n, width=1, seed=0, lo=0.0, hi=10.0):
    rng = np.random.default_rng(seed)
    return EventBatch(rng.integers(0, 8, n), rng.uniform(lo, hi, n),
                      rng.normal(size=(n, width)).astype(np.float32))


def _engine(pipelined, tmp_path=None, **aion_kw):
    aion = AionConfig(block_size=64, pipelined_execution=pipelined,
                      **aion_kw)
    return StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1, spill_dir=tmp_path)


def _drive(eng, n_rounds=15, seed=7):
    rng = np.random.default_rng(seed)
    now = 0.0
    for _ in range(n_rounds):
        n = 150
        ts = rng.uniform(max(now - 12, 0), now + 1, n)
        eng.ingest(EventBatch(rng.integers(0, 6, n), ts,
                              rng.normal(size=(n, 1)).astype(np.float32)),
                   now)
        eng.advance_watermark(now - 4, now)
        eng.poll(now)
        now += 3.0
    eng.advance_watermark(now + 100, now)
    if eng.pipeline is not None:
        assert eng.pipeline.drain()
    assert eng.io.drain()
    # forced final sweep: both modes converge to the fold over ALL events
    items = [BatchWorkItem(wid=wid, state=st, late=True)
             for wid, st in sorted(eng.windows.items())]
    return dict(eng.batch_exec.execute(items, now))


def test_pipelined_matches_sync():
    e_sync = _engine(False)
    e_pipe = _engine(True)
    r_sync = _drive(e_sync)
    r_pipe = _drive(e_pipe)
    assert set(r_sync) == set(r_pipe)
    for wid in r_sync:
        np.testing.assert_allclose(r_sync[wid], r_pipe[wid], atol=1e-5)
    assert e_pipe.metrics.pipeline_rounds > 0
    assert e_pipe.io.stats["errors"] == 0
    e_sync.close()
    e_pipe.close()


def test_pipelined_matches_sync_with_spill(tmp_path):
    e_sync = _engine(False, tmp_path / "sync")
    e_pipe = _engine(True, tmp_path / "pipe")
    r_sync = _drive(e_sync, seed=11)
    r_pipe = _drive(e_pipe, seed=11)
    for wid in r_sync:
        np.testing.assert_allclose(r_sync[wid], r_pipe[wid], atol=1e-5)
    e_sync.close()
    e_pipe.close()


def test_watermark_returns_before_fold_completes():
    """The tentpole behavior: advance_watermark submits the round and
    returns while the fold is still running; the result arrives through
    the window's future."""
    eng = _engine(True)
    eng.ingest(_batch(300, seed=1), now=1.0)
    started = threading.Event()
    release = threading.Event()
    real_execute = eng.batch_exec.execute

    def slow_execute(items, now):
        started.set()
        release.wait(10.0)
        return real_execute(items, now)
    eng.batch_exec.execute = slow_execute
    t0 = time.time()
    eng.advance_watermark(20.0, now=2.0)   # closes window [0, 10)
    submit_latency = time.time() - t0
    assert started.wait(5.0)
    # the caller did not block on the (held-open) fold
    assert submit_latency < 1.0
    wid = next(iter(eng.result_futures))
    fut = eng.result_futures[wid]
    assert not fut.done()
    release.set()
    res = fut.result(timeout=10.0)
    assert res is not None
    assert eng.pipeline.drain()
    assert eng.results[wid] == res
    eng.close()


def test_ingest_during_inflight_fold_keeps_rows():
    """Rows appended while a round is in flight survive: the fold
    snapshots fills, so late rows land in the next execution instead of
    being lost or corrupting the running one."""
    eng = _engine(True)
    eng.ingest(_batch(200, seed=2), now=1.0)
    release = threading.Event()
    real_execute = eng.batch_exec.execute

    def slow_execute(items, now):
        release.wait(10.0)
        return real_execute(items, now)
    eng.batch_exec.execute = slow_execute
    eng.advance_watermark(20.0, now=2.0)
    # ingest more rows for the SAME window while its fold is queued
    eng.ingest(_batch(100, seed=3), now=2.5)
    release.set()
    assert eng.pipeline.drain()
    eng.batch_exec.execute = real_execute
    wid = next(iter(eng.windows))
    st = eng.windows[wid]
    assert st.total_events == 300
    # a fresh fold over everything matches the numpy oracle
    out = eng.batch_exec.execute(
        [BatchWorkItem(wid=wid, state=st, late=True)], 3.0)
    all_vals = np.concatenate([
        _batch(200, seed=2).values[:, 0], _batch(100, seed=3).values[:, 0]])
    np.testing.assert_allclose(out[wid], all_vals.mean(), atol=1e-4)
    eng.close()


def test_round_failure_surfaces_via_futures_and_drain():
    eng = _engine(True)
    eng.ingest(_batch(100, seed=4), now=1.0)

    def boom(items, now):
        raise IOError("injected fold failure")
    eng.batch_exec.execute = boom
    eng.advance_watermark(20.0, now=2.0)
    wid = next(iter(eng.result_futures))
    with pytest.raises(PipelineError, match="injected fold failure"):
        eng.result_futures[wid].result(timeout=10.0)
    with pytest.raises(PipelineError, match="injected fold failure"):
        eng.pipeline.drain()
    # error was consumed by the raise; a clean close is now possible
    del eng.batch_exec.execute
    eng.close()


def test_close_raises_on_failed_round():
    eng = _engine(True)
    eng.ingest(_batch(100, seed=5), now=1.0)
    eng.batch_exec.execute = \
        lambda items, now: (_ for _ in ()).throw(RuntimeError("dead fold"))
    eng.advance_watermark(20.0, now=2.0)
    with pytest.raises(PipelineError, match="dead fold"):
        eng.close()
    del eng.batch_exec.execute
    eng.close()


def test_window_in_flight_guard_bookkeeping():
    pipe = EnginePipeline()
    try:
        eng = _engine(False)               # engine used only as executor
        eng.ingest(_batch(100, seed=6), now=1.0)
        wid = next(iter(eng.windows))
        release = threading.Event()
        real_execute = eng.batch_exec.execute

        def slow_execute(items, now):
            release.wait(10.0)
            return real_execute(items, now)
        eng.batch_exec.execute = slow_execute
        items = [BatchWorkItem(wid=wid, state=eng.windows[wid], late=False)]
        futs = pipe.submit(eng, items, 2.0)
        assert pipe.window_in_flight(wid)
        release.set()
        assert futs[wid].result(timeout=10.0) is not None
        assert pipe.drain()
        assert not pipe.window_in_flight(wid)
        eng.batch_exec.execute = real_execute
        eng.close()
    finally:
        pipe.close()


def test_purge_guard_skips_inflight_windows():
    """Predictive cleanup must not purge a window referenced by a
    queued/executing round."""
    eng = _engine(True)
    eng.ingest(_batch(100, seed=8), now=1.0)
    wid = next(iter(eng.windows))
    release = threading.Event()
    real_execute = eng.batch_exec.execute

    def slow_execute(items, now):
        release.wait(10.0)
        return real_execute(items, now)
    eng.batch_exec.execute = slow_execute
    eng.advance_watermark(20.0, now=2.0)
    assert eng.pipeline.window_in_flight(wid)
    # force cleanup to claim the window is purgeable: the guard must win
    eng.cleanup.should_purge = lambda *a, **kw: True
    eng.poll(now=3.0)
    assert wid in eng.windows              # still alive: fold in flight
    release.set()
    assert eng.pipeline.drain()
    eng.batch_exec.execute = real_execute
    eng.close()


def test_epoch_demotion_falls_back_without_corruption():
    """Rows whose pool slot epoch moved between classification and the
    pinned snapshot must demote to the stacked fallback — results stay
    exact, and the demotion is visible in metrics."""
    aion = AionConfig(block_size=64, pipelined_execution=True,
                      pool_slot_epochs=True)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1)
    if eng.pool is None:
        pytest.skip("block pool disabled in this config")
    # two windows: a single-item round takes the per-window path and
    # never reaches the pooled block-table fold
    b = _batch(400, seed=9, lo=0.0, hi=19.9)
    eng.ingest(b, now=1.0)
    assert len(eng.windows) == 2
    # poison classification: report an epoch one behind the real one so
    # the pinned validation sees a mismatch for every pooled row
    real_slot_epochs = eng.pool.slot_epochs

    def stale_epochs(blocks):
        return [(s, e - 1) for s, e in real_slot_epochs(blocks)]
    eng.pool.slot_epochs = stale_epochs
    items = [BatchWorkItem(wid=wid, state=st, late=False)
             for wid, st in sorted(eng.windows.items())]
    out = eng.batch_exec.execute(items, 2.0)
    eng.pool.slot_epochs = real_slot_epochs
    assert eng.metrics.epoch_demoted_rows > 0
    for wid in eng.windows:
        mask = (b.timestamps >= wid.start) & (b.timestamps < wid.end)
        np.testing.assert_allclose(
            out[wid], b.values[mask, 0].mean(), atol=1e-4)
    eng.close()


def test_prefetch_stages_next_round_while_busy(tmp_path):
    """A round submitted while the worker is busy pre-stages its cold
    blocks at PRIO_STAGE instead of waiting for its turn."""
    eng = _engine(True, tmp_path)
    # window A: live, will hold the worker; window B: cold p-blocks
    eng.ingest(_batch(100, seed=10, lo=0.0, hi=9.9), now=1.0)
    eng.ingest(_batch(100, seed=11, lo=10.0, hi=19.9), now=1.0)
    wids = sorted(eng.windows)
    st_b = eng.windows[wids[1]]
    for blk in list(st_b.blocks):
        eng.io.destage_block_sync(blk)
    assert st_b.p_blocks()
    release = threading.Event()
    real_execute = eng.batch_exec.execute

    def slow_execute(items, now):
        release.wait(10.0)
        return real_execute(items, now)
    eng.batch_exec.execute = slow_execute
    eng.advance_watermark(10.0, now=2.0)   # round 1: window A (worker busy)
    eng.advance_watermark(20.0, now=2.1)   # round 2: window B -> prefetch
    assert eng.pipeline.stats["prefetched_rounds"] >= 1
    release.set()
    assert eng.pipeline.drain()
    eng.batch_exec.execute = real_execute
    eng.close()
