import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.configs.workloads import AVERAGE, STOCK_MARKET
from repro.core import (
    EngineOOM, InMemoryPolicy, PeriodicWatermarkGenerator, StreamEngine,
    TumblingWindows,
)
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.data.generators import make_generator


def _engine(op_name="average", budget=64 << 20, policy=None, width=4,
            num_keys=8, trigger=None, wm_slack=0.0, block=128,
            pooled=True):
    aion = AionConfig(block_size=block, block_pool=pooled)
    kw = {}
    if op_name in ("stock", "lrb"):
        kw = {"num_keys": num_keys} if op_name == "stock" else \
            {"num_segments": num_keys}
    op = make_operator(op_name, aion.block_size, width, **kw)
    return StreamEngine(
        assigner=TumblingWindows(10.0), operator=op, aion=aion,
        value_width=width,
        watermark_gen=PeriodicWatermarkGenerator(10.0, slack=wm_slack),
        device_budget_bytes=budget, policy=policy, trigger=trigger,
    )


def _uniform_batch(n, t0, t1, width=4, seed=0, keys=8):
    rng = np.random.default_rng(seed)
    from repro.core.events import EventBatch
    return EventBatch(rng.integers(0, keys, n),
                      rng.uniform(t0, t1, n),
                      rng.normal(size=(n, width)).astype(np.float32))


def test_live_window_average_correct():
    eng = _engine()
    b = _uniform_batch(500, 0, 10)
    eng.ingest(b, now=0.0)
    eng.ingest(_uniform_batch(10, 10, 20, seed=1), now=11.0)  # push watermark
    eng.advance_watermark(10.0, now=11.0)
    from repro.core.windows import WindowId
    res = eng.results[WindowId(0.0, 10.0)]
    assert res == pytest.approx(float(np.mean(b.values[:, 0])), rel=1e-4,
                                abs=1e-5)
    eng.close()


def test_late_events_update_result():
    """The headline semantic: a late event re-execution folds ALL events
    (on-time + late) into the amended result."""
    eng = _engine(trigger=DeltaTTrigger(executions=2))
    on_time = _uniform_batch(300, 0, 10, seed=2)
    eng.ingest(on_time, now=0.0)
    eng.advance_watermark(10.0, now=10.0)
    late = _uniform_batch(200, 0, 10, seed=3)
    eng.ingest(late, now=12.0)            # late: window [0,10) expired
    # fire all planned re-executions
    for t in np.linspace(12, 12 + 2 * eng.cleanup.current_bound(), 50):
        eng.poll(t)
    from repro.core.windows import WindowId
    res = eng.results[WindowId(0.0, 10.0)]
    allv = np.concatenate([on_time.values[:, 0], late.values[:, 0]])
    assert res == pytest.approx(float(np.mean(allv)), rel=1e-4, abs=1e-5)
    assert eng.metrics.late_executions >= 1
    eng.close()


def test_memory_stays_bounded_with_many_past_windows():
    eng = _engine(budget=8 << 20)
    now = 0.0
    for i in range(30):
        eng.ingest(_uniform_batch(400, now, now + 10, seed=i), now)
        eng.advance_watermark(now + 10, now + 10)
        # sprinkle late events into old windows
        if i > 2:
            eng.ingest(_uniform_batch(100, 0, 10, seed=100 + i), now + 10)
        eng.poll(now + 10)
        now += 10
        assert eng.device_bytes() <= eng.budget.capacity_bytes
    eng.close()


def test_baseline_backend_ooms():
    eng = _engine(budget=1 << 20, policy=InMemoryPolicy())
    now = 0.0
    with pytest.raises(EngineOOM):
        for i in range(50):
            eng.ingest(_uniform_batch(2000, now, now + 10, seed=i), now)
            eng.advance_watermark(now + 10, now + 10)
            now += 10
    eng.close()


def test_predictive_cleanup_purges_old_windows():
    eng = _engine()
    eng.cleanup.min_history = 10
    # 5000 samples can't DKW-certify 99% coverage (needs ~15k); use 90%
    eng.cleanup.coverage = 0.9
    now = 0.0
    for i in range(5):
        eng.ingest(_uniform_batch(200, now, now + 10, seed=i), now)
        eng.advance_watermark(now + 10, now + 10)
        now += 10
    # teach the estimator that lateness is short (~1s)
    eng.cleanup.observe(np.random.default_rng(0).uniform(0.1, 1.0, 5000))
    bound = eng.cleanup.current_bound()
    assert bound < 10.0
    eng.advance_watermark(now + 100, now + 100)
    eng.poll(now + 100)
    assert eng.metrics.purged_windows >= 4
    eng.close()


def test_stock_operator_per_key_aggregates():
    eng = _engine(op_name="stock", num_keys=8)
    b = _uniform_batch(1000, 0, 10, seed=5, keys=8)
    eng.ingest(b, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    from repro.core.windows import WindowId
    res = eng.results[WindowId(0.0, 10.0)]
    for k in range(8):
        mask = b.keys == k
        if mask.any():
            assert res["mean"][k] == pytest.approx(
                float(np.mean(b.values[mask, 0])), rel=1e-4)
            assert res["min"][k] == pytest.approx(
                float(np.min(b.values[mask, 0])), rel=1e-4)
    eng.close()


def test_blocking_operator_stages_everything_first():
    eng = _engine(op_name="percentile", budget=256 << 20)
    b = _uniform_batch(2000, 0, 10, seed=6)
    eng.ingest(b, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    from repro.core.windows import WindowId
    res = eng.results[WindowId(0.0, 10.0)]
    assert res[0.5] == pytest.approx(float(np.quantile(b.values[:, 0], 0.5)),
                                     abs=0.05)
    eng.close()


def test_checkpoint_state_roundtrippable():
    eng = _engine()
    eng.ingest(_uniform_batch(100, 0, 10), now=0.0)
    eng.advance_watermark(10.0, 10.0)
    snap = eng.checkpoint_state()
    assert snap["watermark"] == 10.0
    assert len(snap["windows"]) >= 1
    assert snap["windows"][0]["total_events"] == 100
    eng.close()


def test_engine_checkpoint_restore_roundtrip():
    """Fault tolerance: a restored engine recomputes identical results."""
    eng = _engine()
    b = _uniform_batch(400, 0, 10, seed=11)
    eng.ingest(b, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    snap = eng.checkpoint_state()
    from repro.core.windows import WindowId
    want = eng.results[WindowId(0.0, 10.0)]
    eng.close()

    eng2 = _engine()
    eng2.restore_state(snap)
    assert eng2.tracker.watermark == 10.0
    wid = WindowId(0.0, 10.0)
    assert eng2.windows[wid].total_events == 400
    got = eng2.execute_window(wid, now=11.0, late=True)
    assert got == pytest.approx(want, rel=1e-5, abs=1e-6)
    eng2.close()


def test_checkpoint_restore_full_roundtrip_with_late_events():
    """Round-trip checkpoint_state() -> restore_state(): watermark,
    lateness histogram, per-window event counts (total AND late), block
    boundaries, and re-executed results must all survive."""
    eng = _engine(trigger=DeltaTTrigger(executions=2))
    on_time = _uniform_batch(300, 0, 20, seed=61)
    eng.ingest(on_time, now=0.0)
    eng.advance_watermark(20.0, 20.0)                   # two live windows
    late = _uniform_batch(120, 0, 10, seed=62)
    eng.ingest(late, now=22.0)                          # late into [0,10)
    for t in np.linspace(22, 22 + 2 * eng.cleanup.current_bound(), 20):
        eng.poll(t)
    eng.io.drain()
    snap = eng.checkpoint_state()
    from repro.core.windows import WindowId
    wids = sorted(eng.windows)
    want_results = {w: eng.results[w] for w in wids}
    want_counts = {w: (eng.windows[w].total_events,
                       eng.windows[w].late_events) for w in wids}
    want_fills = {w: [b.fill for b in eng.windows[w].blocks] for w in wids}
    want_hist = (np.asarray(eng.cleanup.hist.counts).copy(),
                 eng.cleanup.hist.total)
    eng.close()

    eng2 = _engine(trigger=DeltaTTrigger(executions=2))
    eng2.restore_state(snap)
    assert eng2.tracker.watermark == 20.0
    np.testing.assert_allclose(np.asarray(eng2.cleanup.hist.counts),
                               want_hist[0])
    assert eng2.cleanup.hist.total == want_hist[1]
    for w in wids:
        st = eng2.windows[w]
        assert (st.total_events, st.late_events) == want_counts[w]
        # block boundaries survive 1:1 (restore must not re-pack events)
        assert [b.fill for b in st.blocks] == want_fills[w]
        got = eng2.execute_window(w, now=23.0, late=True)
        assert got == pytest.approx(want_results[w], rel=1e-5, abs=1e-6)
    eng2.close()


def test_checkpoint_captures_spilled_blocks(tmp_path):
    """Blocks that live in the storage tier at checkpoint time must not
    serialize as empty."""
    aion = AionConfig(block_size=128)
    op = make_operator("average", aion.block_size, 4)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0), operator=op, aion=aion,
        value_width=4, device_budget_bytes=2 << 20,
        spill_dir=tmp_path, host_budget_bytes=64 << 10,
        trigger=DeltaTTrigger(executions=1),
    )
    b = _uniform_batch(3000, 0, 10, seed=71)
    eng.ingest(b, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    eng.io.drain()
    from repro.core.buckets import Tier
    tiers = [blk.tier for st in eng.windows.values() for blk in st.blocks]
    assert any(t == Tier.STORAGE for t in tiers)
    snap = eng.checkpoint_state()
    want = eng.results[list(eng.windows)[0]]
    eng.close()
    total = sum(len(blk["data"].get("keys", []))
                for w in snap["windows"] for blk in w["blocks"])
    assert total >= 3000 // 128 * 128     # every full block captured
    eng2 = _engine()
    eng2.restore_state(snap)
    from repro.core.windows import WindowId
    got = eng2.execute_window(WindowId(0.0, 10.0), now=11.0, late=True)
    assert got == pytest.approx(want, rel=1e-5, abs=1e-6)
    eng2.close()


@pytest.mark.parametrize("pooled", [True, False])
def test_purge_releases_device_budget(pooled):
    """Predictive cleanup of a window with device-resident blocks must
    return their bytes to the budget (regression: drop_all used to clear
    the block list before the release loop could see the m-blocks)."""
    eng = _engine(pooled=pooled)
    eng.cleanup.min_history = 10
    eng.cleanup.coverage = 0.9
    # the block pool's arena reservation is permanent by design; every
    # per-block reservation must return on purge (the pooled=False run
    # keeps the original legacy-bytes regression coverage)
    floor = eng.pool.arena_bytes if eng.pool is not None else 0
    eng.ingest(_uniform_batch(500, 0, 10, seed=91), now=0.0)
    eng.io.drain()
    assert eng.budget.used_bytes >= floor
    from repro.core.windows import WindowId
    eng.windows[WindowId(0.0, 10.0)].expired = True
    eng.cleanup.observe(np.random.default_rng(0).uniform(0.1, 1.0, 5000))
    eng.advance_watermark(1000.0, now=1000.0)   # way past the purge bound
    eng.poll(now=1000.0)
    assert eng.metrics.purged_windows == 1
    assert eng.budget.used_bytes == floor
    eng.close()


def test_block_partition_covers_each_block_once():
    """Regression for the execute-window snapshot: the (m, p) partition
    must cover every block exactly once — no block folded twice, none
    skipped — including when tiers are mixed."""
    from repro.core.buckets import Tier
    eng = _engine(budget=1 << 30)
    eng.ingest(_uniform_batch(1000, 0, 10, seed=81), now=0.0)
    from repro.core.windows import WindowId
    state = eng.windows[WindowId(0.0, 10.0)]
    assert len(state.blocks) >= 4
    # force a mixed-tier layout: destage half the device blocks
    for blk in state.m_blocks()[::2]:
        eng.io.destage_block_sync(blk)
    from repro.core.batch_exec import snapshot_block_partition
    m_snapshot, p_blocks = snapshot_block_partition(state)
    ids = [id(x) for x in m_snapshot] + [id(x) for x in p_blocks]
    assert sorted(ids) == sorted(id(x) for x in state.blocks)
    assert len(set(ids)) == len(state.blocks)
    assert all(b.tier == Tier.DEVICE for b in m_snapshot)
    # result over the partition equals the plain mean (nothing double-
    # counted, nothing dropped)
    got = eng.execute_window(WindowId(0.0, 10.0), now=1.0, late=False)
    vals = np.concatenate([blk.as_event_batch().values[:, 0]
                           for blk in state.blocks]) \
        if state.blocks else np.zeros(1)
    assert got == pytest.approx(float(np.mean(vals)), rel=1e-4, abs=1e-5)
    eng.close()


def test_host_budget_spills_to_storage(tmp_path):
    """Third tier: past-window state beyond the host budget lands in
    storage files and restages losslessly at re-execution."""
    from repro.core.buckets import Tier
    aion = AionConfig(block_size=128)
    op = make_operator("average", aion.block_size, 4)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0), operator=op, aion=aion,
        value_width=4,
        device_budget_bytes=2 << 20,
        spill_dir=tmp_path, host_budget_bytes=64 << 10,
        trigger=DeltaTTrigger(executions=1),
    )
    b = _uniform_batch(3000, 0, 10, seed=21)
    eng.ingest(b, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    eng.io.drain()
    tiers = [blk.tier for st in eng.windows.values() for blk in st.blocks]
    assert any(t == Tier.STORAGE for t in tiers)
    # the default persistent tier is the log-structured store: spills
    # landed in its value log under the spill dir
    assert eng.io.store is not None and eng.io.store.name == "log"
    assert eng.io.store.stats["bytes_written"] > 0
    assert eng.io.store.on_disk_bytes() > 0
    assert len(list(tmp_path.glob("seg-*.log"))) > 0
    # late re-execution reads back through all three tiers
    late = _uniform_batch(100, 0, 10, seed=22)
    eng.ingest(late, now=12.0)
    for t in np.linspace(12, 12 + 2 * eng.cleanup.current_bound(), 30):
        eng.poll(t)
    from repro.core.windows import WindowId
    allv = np.concatenate([b.values[:, 0], late.values[:, 0]])
    assert eng.results[WindowId(0.0, 10.0)] == pytest.approx(
        float(np.mean(allv)), rel=1e-4, abs=1e-5)
    eng.close()


def test_stock_kernel_backend_matches_jnp():
    """The segment_aggregate Pallas kernel as the engine fold."""
    eng_j = _engine(op_name="stock", num_keys=8)
    op_k = make_operator("stock", 128, 4, num_keys=8, use_kernel=True)
    eng_k = StreamEngine(
        assigner=TumblingWindows(10.0), operator=op_k,
        aion=AionConfig(block_size=128), value_width=4,
        device_budget_bytes=64 << 20,
    )
    b = _uniform_batch(800, 0, 10, seed=30, keys=8)
    for eng in (eng_j, eng_k):
        eng.ingest(b, now=0.0)
        eng.advance_watermark(10.0, 10.0)
    from repro.core.windows import WindowId
    rj = eng_j.results[WindowId(0.0, 10.0)]
    rk = eng_k.results[WindowId(0.0, 10.0)]
    np.testing.assert_allclose(rj["mean"], rk["mean"], rtol=1e-4)
    np.testing.assert_allclose(rj["min"], rk["min"], rtol=1e-5)
    np.testing.assert_allclose(rj["max"], rk["max"], rtol=1e-5)
    eng_j.close()
    eng_k.close()


def test_sliding_windows_end_to_end():
    """Every event contributes to size/slide overlapping windows."""
    from repro.core.windows import SlidingWindows, WindowId
    aion = AionConfig(block_size=128)
    op = make_operator("average", aion.block_size, 4)
    eng = StreamEngine(
        assigner=SlidingWindows(20.0, 10.0), operator=op, aion=aion,
        value_width=4, device_budget_bytes=64 << 20,
    )
    b = _uniform_batch(500, 25, 30, seed=40)     # all inside [25, 30)
    eng.ingest(b, now=0.0)
    eng.advance_watermark(40.0, 40.0)
    want = float(np.mean(b.values[:, 0]))
    got = [eng.results[w] for w in (WindowId(10.0, 30.0),
                                    WindowId(20.0, 40.0))]
    for g in got:
        assert g == pytest.approx(want, rel=1e-4, abs=1e-5)
    eng.close()


def test_punctuated_mode_stages_on_late_event():
    """Punctuated watermarks: a late event immediately plans staging."""
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", 128, 4),
        aion=AionConfig(block_size=128), value_width=4,
        device_budget_bytes=64 << 20, punctuated=True,
        trigger=DeltaTTrigger(executions=1),
    )
    eng.ingest(_uniform_batch(200, 0, 10, seed=50), now=0.0)
    eng.advance_watermark(10.0, 10.0)
    eng.ingest(_uniform_batch(50, 0, 10, seed=51), now=12.0)
    assert eng.prestage.stats["immediate"] >= 1
    eng.close()


def test_ingest_full_length_index_list_is_selected_not_aliased():
    """Regression (ISSUE 6 satellite): sub-batch selection used to take
    the WHOLE batch whenever ``len(idx) == len(batch)`` — wrong for any
    full-length index list that permutes or repeats rows. Only a
    verified identity may skip the copy."""
    from repro.core import EventBatch

    class RepeatingAssigner:
        """Assigns every batch to one window via a full-length,
        non-identity index list (row 0 twice, row 1 never)."""
        def assign(self, timestamps):
            n = len(timestamps)
            idx = np.arange(n)
            if n >= 2:
                idx[1] = 0
            from repro.core.windows import WindowId
            yield WindowId(0.0, 10.0), idx

    aion = AionConfig(block_size=32)
    eng = StreamEngine(
        assigner=RepeatingAssigner(),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1, device_budget_bytes=64 << 20,
    )
    keys = np.array([7, 3, 5], np.int64)
    vals = np.array([[1.0], [100.0], [4.0]], np.float32)
    eng.ingest(EventBatch(keys, np.array([1.0, 2.0, 3.0]), vals), now=0.0)
    st = next(iter(eng.windows.values()))
    got = st.blocks[0].as_event_batch()
    np.testing.assert_array_equal(got.keys, [7, 7, 5])      # not [7, 3, 5]
    np.testing.assert_allclose(got.values[:, 0], [1.0, 1.0, 4.0])
    eng.advance_watermark(20.0, 20.0)
    wid = next(iter(eng.results))
    assert eng.results[wid] == pytest.approx(2.0)   # mean(1, 1, 4)
    eng.close()


def test_ingest_identity_full_length_index_still_zero_copy():
    """The common case — one window takes the whole batch — must keep
    skipping the select()."""
    eng = _engine(width=1)
    b = _uniform_batch(100, 0, 10, width=1, seed=60)
    eng.ingest(b, now=0.0)
    st = next(iter(eng.windows.values()))
    assert st.total_events == 100
    eng.advance_watermark(20.0, 20.0)
    wid = next(iter(eng.results))
    assert eng.results[wid] == pytest.approx(float(np.mean(b.values[:, 0])),
                                             rel=1e-4, abs=1e-5)
    eng.close()


def test_metrics_series_bounded_by_config():
    """Regression (ISSUE 6 satellite): per-poll series grew without
    bound on long-running engines; ``AionConfig.metrics_series_max``
    now caps them while keeping plain-list semantics."""
    from repro.core.engine import BoundedSeries

    s = BoundedSeries(maxlen=8)
    for i in range(100):
        s.append(i)
    assert len(s) <= 8
    assert s[-1] == 99                     # newest entries survive shedding
    assert isinstance(s, list) and s == list(s)

    aion = AionConfig(block_size=128, metrics_series_max=16)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1, device_budget_bytes=64 << 20,
    )
    eng.ingest(_uniform_batch(64, 0, 10, width=1, seed=61), now=0.0)
    for i in range(100):
        eng.poll(now=float(i))
    assert len(eng.metrics.device_bytes_series) <= 16
    assert len(eng.metrics.host_bytes_series) <= 16
    eng.close()
