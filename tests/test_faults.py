"""Self-healing I/O path (ISSUE 9): fault injection, retry/backoff, the
degradation ladder, and the failure-unwind regressions.

Layers under test, bottom-up:
  * the error taxonomy (transient vs. permanent store failures)
  * ``FaultInjector``/``FaultyBlockStore`` determinism and crash semantics
  * ``IOScheduler._with_retries`` — budgets, backoff, shed-vs-surface
  * ``StoreHealth`` — the tick-based breaker and its transition log
  * ``StreamEngine`` ladder integration — each rung's observable shed,
    in order, and its reversal when the breaker cools
  * satellite regressions: coalesced-commit unwind is exactly-once;
    ``TransferExecutor.drain`` aggregates ALL task failures into one
    deterministic error
"""
import threading
import time

import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import (
    EventBatch, StreamEngine, TumblingWindows, make_operator,
)
from repro.core.batch_exec import BatchWorkItem
from repro.core.buckets import Block, MemoryBudget, Tier
from repro.core.health import (
    LEVEL_BACKPRESSURE, LEVEL_HEALTHY, LEVEL_SHED_PREFETCH,
    LEVEL_SHED_READAHEAD, LEVEL_SYNC_ROUNDS, MAX_LEVEL, StoreHealth,
)
from repro.core.staging import (
    IOScheduler, PRIO_STAGE, StagingError, TransferExecutor,
)
from repro.storage import (
    PermanentStoreError, TransientStoreError, is_transient_error,
    make_store,
)
from repro.testing import FaultInjector, FaultyBlockStore


def _batch(n, width=1, seed=0, lo=0.0, hi=10.0):
    rng = np.random.default_rng(seed)
    return EventBatch(rng.integers(0, 8, n), rng.uniform(lo, hi, n),
                      rng.normal(size=(n, width)).astype(np.float32))


def _filled_block(capacity=32, width=1, key=(0.0, 10.0), seed=0):
    blk = Block.new(capacity, width)
    blk.window_key = key
    blk.append(_batch(capacity, width, seed), 0)
    return blk


# ------------------------------------------------------- error taxonomy
def test_transient_vs_permanent_classification():
    assert is_transient_error(TransientStoreError("flaky"))
    assert is_transient_error(OSError("generic io"))
    assert is_transient_error(TimeoutError("slow"))
    assert is_transient_error(ConnectionError("reset"))
    assert not is_transient_error(PermanentStoreError("corrupt"))
    assert not is_transient_error(ValueError("not io at all"))
    # the permanent error is NOT an OSError subclass sneaking through
    assert not isinstance(PermanentStoreError("x"), OSError)


# --------------------------------------------------------- FaultInjector
def test_injector_is_deterministic_per_seed():
    a = FaultInjector(seed=7, rates={"get": 0.5})
    b = FaultInjector(seed=7, rates={"get": 0.5})
    seq_a = [a.should_fail("get") for _ in range(64)]
    seq_b = [b.should_fail("get") for _ in range(64)]
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)   # rate actually draws both ways


def test_injector_schedule_and_fail_next():
    inj = FaultInjector(schedule={"put": [1, 3]})
    assert [inj.should_fail("put") for _ in range(4)] == \
        [False, True, False, True]
    inj.fail_next("commit", n=2)
    assert inj.should_fail("commit") and inj.should_fail("commit")
    assert not inj.should_fail("commit")


def test_injector_max_consecutive_bounds_streaks():
    # rate 1.0 would fail forever; max_consecutive=2 forces every third
    # call through — which is what makes retry success deterministic
    inj = FaultInjector(rates={"get": 1.0}, max_consecutive=2)
    seq = [inj.should_fail("get") for _ in range(6)]
    assert seq == [True, True, False, True, True, False]


def test_injector_paused_and_poison():
    inj = FaultInjector(rates={"get": 1.0})
    with inj.paused():
        assert not inj.should_fail("get")
    with pytest.raises(TransientStoreError):
        inj.maybe_fail("get")
    inj.poison(("get",))
    with pytest.raises(PermanentStoreError):
        inj.maybe_fail("get")
    inj.heal()
    with pytest.raises(TransientStoreError):   # back to rate-driven
        inj.maybe_fail("get")
    assert inj.stats["injected"] == 3


# ------------------------------------------------------ FaultyBlockStore
def test_faulty_store_injects_and_delegates(tmp_path):
    inner = make_store("log", tmp_path)
    inj = FaultInjector()
    store = FaultyBlockStore(inner, inj)
    blk = _filled_block()
    inj.fail_next("put")
    with pytest.raises(TransientStoreError):
        store.put(blk.window_key, blk.block_id, blk.host_data, blk.fill)
    # next call goes through, and inner-store state is visible through
    # the wrapper (delegated attributes)
    store.put(blk.window_key, blk.block_id, blk.host_data, blk.fill)
    store.commit()
    assert store.current_fill(blk.window_key, blk.block_id) == blk.fill
    got = store.get(blk.window_key, blk.block_id)
    np.testing.assert_array_equal(got["keys"][:blk.fill],
                                  blk.host_data["keys"][:blk.fill])
    assert store.durable_writes            # delegated class attribute
    store.close()


def test_faulty_store_crash_torn_tail_recovers(tmp_path):
    inner = make_store("log", tmp_path)
    store = FaultyBlockStore(inner, FaultInjector())
    durable = _filled_block(seed=1)
    store.put(durable.window_key, durable.block_id,
              durable.host_data, durable.fill)
    store.commit()                         # acknowledged
    lost = _filled_block(seed=2)
    store.put(lost.window_key, lost.block_id,
              lost.host_data, lost.fill)   # never committed
    store.crash(torn_tail_bytes=7)         # kill -9 with a torn tail
    reopened = make_store("log", tmp_path)
    try:
        # WAL recovery: the acknowledged record survives byte-exact, the
        # unacknowledged tail (incl. the torn bytes) is gone
        assert reopened.current_fill(durable.window_key,
                                     durable.block_id) == durable.fill
        got = reopened.get(durable.window_key, durable.block_id)
        np.testing.assert_array_equal(
            got["values"][:durable.fill],
            durable.host_data["values"][:durable.fill])
        assert reopened.get(lost.window_key, lost.block_id) is None
    finally:
        reopened.close()


# ------------------------------------------------------- retry machinery
def test_with_retries_recovers_transient_failures():
    io = IOScheduler(MemoryBudget(1 << 20), io_retry_limit=4,
                     io_retry_backoff=0.0)
    try:
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] <= 2:
                raise TransientStoreError("flaky")
            return "ok"
        assert io._with_retries(flaky, "get") == "ok"
        assert calls["n"] == 3
        assert io.stats["retries"] == 2
        assert io.stats["gave_up"] == 0
    finally:
        io.shutdown()


def test_with_retries_exhaustion_surfaces_and_counts():
    io = IOScheduler(MemoryBudget(1 << 20), io_retry_limit=3,
                     io_retry_backoff=0.0)
    try:
        def always():
            raise TransientStoreError("dead disk")
        with pytest.raises(TransientStoreError):
            io._with_retries(always, "get")
        assert io.stats["retries"] == 3    # the full budget was spent
        assert io.stats["gave_up"] == 1    # then surfaced honestly
    finally:
        io.shutdown()


def test_with_retries_permanent_error_skips_retries():
    io = IOScheduler(MemoryBudget(1 << 20), io_retry_limit=5)
    try:
        calls = {"n": 0}

        def corrupt():
            calls["n"] += 1
            raise PermanentStoreError("bad checksum")
        with pytest.raises(PermanentStoreError):
            io._with_retries(corrupt, "get")
        assert calls["n"] == 1             # retrying corruption is futile
        assert io.stats["retries"] == 0
        assert io.stats["gave_up"] == 0    # gave_up counts transient only
    finally:
        io.shutdown()


def test_with_retries_shed_ok_sheds_instead_of_raising():
    io = IOScheduler(MemoryBudget(1 << 20), io_retry_limit=1,
                     io_retry_backoff=0.0)
    try:
        def always():
            raise TransientStoreError("sweep failed")
        assert io._with_retries(always, "readahead", shed_ok=True) is None
        assert io.stats["readahead_shed"] == 1
        assert io.stats["gave_up"] == 0    # shed, not given up
    finally:
        io.shutdown()


def test_io_retry_limit_zero_disables_retries():
    io = IOScheduler(MemoryBudget(1 << 20), io_retry_limit=0)
    try:
        with pytest.raises(TransientStoreError):
            io._with_retries(
                lambda: (_ for _ in ()).throw(TransientStoreError("x")),
                "get")
        assert io.stats["retries"] == 0
    finally:
        io.shutdown()


def test_demand_fetch_retries_through_faulty_store(tmp_path):
    """End-to-end: a block spilled to a flaky store demand-loads through
    the retry budget — no error escapes, gave_up stays 0."""
    inner = make_store("log", tmp_path)
    inj = FaultInjector(seed=3, rates={"get": 0.9}, max_consecutive=2)
    store = FaultyBlockStore(inner, inj)
    io = IOScheduler(MemoryBudget(1 << 20), store=store,
                     io_retry_limit=4, io_retry_backoff=0.0)
    try:
        blk = _filled_block()
        with inj.paused():
            io.spill_blocks_sync([blk])
        assert blk.tier == Tier.STORAGE
        for _ in range(8):                 # several flaky demand reads
            blk.tier = Tier.STORAGE if blk.host_data is None else blk.tier
            data = io.fetch_block_host(blk)
            assert data is not None
        assert io.stats["retries"] > 0
        assert io.stats["gave_up"] == 0
    finally:
        io.shutdown()


# ----------------------------------------------------------- StoreHealth
def test_health_climbs_one_rung_per_bad_tick():
    h = StoreHealth(error_threshold=4, cooldown_ticks=2)
    for expect in (1, 2, 3, 4):
        assert h.tick(10) == expect
    assert h.tick(10) == MAX_LEVEL         # clamped at the top
    assert h.transitions == [(0, 1), (1, 2), (2, 3), (3, 4)]


def test_health_cooldown_reverses_in_order():
    h = StoreHealth(error_threshold=4, cooldown_ticks=2)
    h.tick(10); h.tick(10)                 # -> level 2
    assert h.tick(0) == 2                  # 1 clean tick: not yet
    assert h.tick(0) == 1                  # 2 clean ticks: step down
    assert h.tick(3) == 1                  # sub-threshold noise: hold
    assert h.tick(0) == 1
    assert h.tick(0) == 0
    assert h.transitions == [(0, 1), (1, 2), (2, 1), (1, 0)]


def test_health_disabled_when_threshold_zero():
    h = StoreHealth(error_threshold=0)
    for _ in range(10):
        assert h.tick(1000) == LEVEL_HEALTHY
    assert h.transitions == []


# ----------------------------------------------- engine ladder integration
def _ladder_engine(tmp_path, **kw):
    kw.setdefault("breaker_error_threshold", 4)
    kw.setdefault("breaker_cooldown_ticks", 1)
    aion = AionConfig(block_size=32, pipelined_execution=True, **kw)
    return StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1, spill_dir=tmp_path)


def test_ladder_sheds_in_order_and_reverses(tmp_path):
    """The whole ladder, rung by rung: readahead sheds first, then
    prefetch, then pipelined rounds demote, then ingest backpressures —
    and clean ticks walk it all back with nothing lost."""
    eng = _ladder_engine(tmp_path)
    assert eng.health is not None and eng.round_backup is not None

    def bump(n=10):
        eng.io.stats["retries"] += n       # simulated error/retry burst

    # rung 1: speculative readahead drives shed (same poll that climbed)
    bump(); eng.poll(1.0)
    assert eng.health.level == LEVEL_SHED_READAHEAD
    assert eng.metrics.shed_readahead_drives >= 1

    # rung 2: pipelined next-round prefetch sheds
    bump(); eng.poll(1.1)
    assert eng.health.level == LEVEL_SHED_PREFETCH
    eng.ingest(_batch(64, seed=5), now=1.2)
    wid, state = next(iter(eng.windows.items()))
    for blk in list(state.blocks):         # force blocks cold (p-bucket)
        eng.io.destage_block_sync(blk)
    assert state.p_blocks()
    eng.prefetch_round([BatchWorkItem(wid, state, False)])
    assert eng.metrics.shed_prefetch_rounds == 1

    # rung 3: the watermark round folds synchronously, not pipelined
    bump(); eng.poll(1.3)
    assert eng.health.level == LEVEL_SYNC_ROUNDS
    eng.advance_watermark(10.0, now=1.4)
    assert eng.metrics.demoted_sync_rounds == 1
    assert not eng.result_futures          # nothing went to the pipeline
    assert wid in eng.results              # but the window DID fold

    # rung 4: ingest defers instead of admitting
    bump(); eng.poll(1.5)
    assert eng.health.level == LEVEL_BACKPRESSURE
    late = _batch(48, seed=6)
    assert eng.ingest(late, now=1.6) == len(late)
    assert eng.metrics.deferred_events == len(late)
    ingested_before = eng.metrics.ingested

    # recovery: clean ticks walk back down; the first sub-top poll
    # readmits everything that was deferred
    eng.poll(1.7)
    assert eng.health.level == LEVEL_SYNC_ROUNDS
    assert eng.metrics.readmitted_events == len(late)
    assert eng.metrics.ingested == ingested_before + len(late)
    for t in (1.8, 1.9, 2.0):
        eng.poll(t)
    assert eng.health.level == LEVEL_HEALTHY

    # the transition log IS the shed-order evidence
    assert eng.metrics.ladder_transitions[:4] == \
        [(0, 1), (1, 2), (2, 3), (3, 4)]
    assert eng.metrics.ladder_transitions[-1] == (1, 0)
    assert eng.io.stats["gave_up"] == 0
    eng.close()


def test_backpressure_trickles_at_top_rung(tmp_path):
    """Sustained pressure must not starve deferred events forever: one
    oldest batch readmits per poll even while the rung holds."""
    eng = _ladder_engine(tmp_path)
    for t in (0.1, 0.2, 0.3, 0.4):         # climb to the top rung
        eng.io.stats["retries"] += 10
        eng.poll(t)
    assert eng.health.level == LEVEL_BACKPRESSURE
    b1, b2 = _batch(16, seed=1), _batch(16, seed=2)
    eng.ingest(b1, now=0.5)
    eng.ingest(b2, now=0.5)
    assert eng.metrics.deferred_events == 32
    eng.io.stats["retries"] += 10          # pressure persists
    eng.poll(0.6)
    assert eng.health.level == LEVEL_BACKPRESSURE
    assert eng.metrics.readmitted_events == 16      # b1 trickled through
    eng.flush_deferred()                   # drain barrier gets the rest
    assert eng.metrics.readmitted_events == 32
    assert eng.metrics.ingested == 32
    eng.close()


def test_close_flushes_deferred_ingest(tmp_path):
    eng = _ladder_engine(tmp_path)
    for t in (0.1, 0.2, 0.3, 0.4):
        eng.io.stats["retries"] += 10
        eng.poll(t)
    b = _batch(24, seed=9)
    assert eng.ingest(b, now=0.5) == 24
    eng.close()                            # must fold, not drop
    assert eng.metrics.ingested == 24
    assert eng.metrics.readmitted_events == 24


def test_ladder_disabled_by_config(tmp_path):
    eng = _ladder_engine(tmp_path, breaker_error_threshold=0)
    assert eng.health is None
    eng.io.stats["retries"] += 1000
    eng.poll(1.0)
    assert eng.metrics.degradation_level == 0
    assert eng.ingest(_batch(8), now=1.1) == 0     # never defers
    eng.close()


# --------------------------------------------- pipeline round retry (ISSUE 9)
def test_pipeline_round_retries_once_and_wins(tmp_path):
    """A transiently-failing fold round retries through the backup
    executor and succeeds — the futures resolve with results, not
    errors, and close() sees a clean pipeline."""
    aion = AionConfig(block_size=32, pipelined_execution=True)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1, spill_dir=tmp_path)
    assert eng.pipeline is not None and eng.round_backup is not None
    real = eng.batch_exec.execute
    state = {"fails": 1}

    def flaky_execute(items, now):
        if state["fails"] > 0:
            state["fails"] -= 1
            raise IOError("injected transient fold failure")
        return real(items, now)
    eng.batch_exec.execute = flaky_execute
    eng.ingest(_batch(64, seed=4), now=1.0)
    eng.advance_watermark(10.0, now=2.0)
    assert eng.pipeline.drain(timeout=30.0, raise_on_error=True)
    assert eng.pipeline.stats["round_retries"] == 1
    assert eng.pipeline.stats["round_retry_wins"] == 1
    for fut in eng.result_futures.values():
        assert fut.result(timeout=5.0) is not None
    eng.batch_exec.execute = real
    eng.close()


# ---------------------------------------- satellite: coalescer unwind
def test_failed_coalesced_commits_requeue_exactly_once(tmp_path):
    """Two failing coalesced flushes over the same blocks must re-queue
    each host copy exactly once: no double-registered ``_host_bytes``,
    no duplicate spill-LRU entries — and after the store heals the same
    blocks spill through cleanly."""
    inner = make_store("log", tmp_path)
    inj = FaultInjector()
    store = FaultyBlockStore(inner, inj)
    io = IOScheduler(MemoryBudget(1 << 20), store=store,
                     host_budget_bytes=0, wal_coalesce=True,
                     io_retry_limit=2, io_retry_backoff=0.0)
    try:
        assert io._coalescer is not None
        blocks = [_filled_block(seed=s, key=(0.0, 10.0)) for s in (1, 2)]
        for b in blocks:
            io._account_host(b)
        expected_bytes = sum(b.nbytes for b in blocks)
        assert io._host_bytes == expected_bytes

        inj.poison(("commit",))            # flushes fail, permanently
        for _ in range(2):                 # two failing flush cycles
            io._maybe_spill()              # pops candidates, queues flush
            assert io.drain(timeout=10.0)
            assert io._host_bytes == expected_bytes        # not doubled
            lru = list(io._host_lru)
            for b in blocks:
                assert lru.count(b) == 1                   # exactly once
                assert b.tier == Tier.HOST                 # copy kept
        assert io._pending_spill_bytes == 0

        inj.heal()
        io._maybe_spill()
        assert io.drain(timeout=10.0)
        for b in blocks:
            assert b.tier == Tier.STORAGE
        assert io._host_bytes == 0
        assert not io._host_lru
    finally:
        io.shutdown()


# ------------------------------------- satellite: aggregate drain errors
def test_drain_aggregates_all_failures_deterministically():
    ex = TransferExecutor(sequential_io=True)
    try:
        for msg in ("err-c", "err-a", "err-b"):
            ex.submit(0, lambda m=msg: (_ for _ in ()).throw(IOError(m)))
        ex.submit(0, lambda: None)         # a clean task changes nothing
        with pytest.raises(StagingError) as ei:
            ex.drain(timeout=10.0, raise_on_error=True)
        text = str(ei.value)
        assert "3 I/O task(s) failed" in text
        # sorted -> deterministic across thread interleavings
        assert text.index("err-a") < text.index("err-b") < \
            text.index("err-c")
        # failures reported once: a second raising drain is clean
        ex.drain(timeout=10.0, raise_on_error=True)
    finally:
        ex.shutdown()


def test_drain_aggregates_failures_pooled_mode():
    ex = TransferExecutor(sequential_io=False, max_pool_workers=4)
    try:
        for i in range(4):
            ex.submit(0, lambda i=i: (_ for _ in ()).throw(
                IOError(f"pool-err-{i}")))
        with pytest.raises(StagingError, match="4 I/O task"):
            ex.drain(timeout=10.0, raise_on_error=True)
    finally:
        ex.shutdown()


# --------------------------------------------- executor dispatch hook
def test_executor_fault_hook_injects_dispatch_failures():
    ex = TransferExecutor(sequential_io=True)
    try:
        inj = FaultInjector(schedule={"executor": [0]})
        ex.fault_hook = inj.executor_hook
        ran = []
        h1 = ex.submit(0, lambda: ran.append(1))
        assert h1.wait(5.0)
        assert isinstance(h1.error, TransientStoreError)
        assert not ran                     # body never ran: hook fired first
        h2 = ex.submit(0, lambda: ran.append(2))
        assert h2.wait_checked(5.0)
        assert ran == [2]
        assert ex.stats["errors"] == 1
    finally:
        ex.shutdown()
