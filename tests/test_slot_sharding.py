"""Slot-sharded multi-device batched fold: parity + placement + edges.

The sharded path must be result-identical to the unsharded batched path
and the per-window reference path. Multi-device cases run under
``make verify-multidevice`` (XLA_FLAGS=--xla_force_host_platform_device_count=8);
on a single-device host they skip and the single-device fallbacks (mesh
None, sharding a safe no-op) are exercised instead.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import AionConfig
from repro.core import StreamEngine, TumblingWindows
from repro.core.batch_exec import plan_slot_placement
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.distributed.sharding import make_slot_mesh
from repro.kernels import segment_aggregate_batched
from repro.kernels.segment_aggregate import (
    next_pow2, pack_rows_shard_major,
)

NDEV = len(jax.devices())
multidevice = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices (make verify-multidevice)")

WINDOW = 10.0
N_WINDOWS = 12


# ------------------------------------------------------------- placement
def test_plan_slot_placement_round_robins_device_ranges():
    slot_of, num_slots, slots_per = plan_slot_placement(10, 4)
    # 10 windows over 4 devices -> ceil(10/4)=3 -> padded to 4 per device
    assert slots_per == 4 and num_slots == 16
    # window i -> device i % 4, local slot i // 4
    assert slot_of == [0, 4, 8, 12, 1, 5, 9, 13, 2, 6]
    # every device's slots stay inside its own contiguous range
    for i, s in enumerate(slot_of):
        d = i % 4
        assert d * slots_per <= s < (d + 1) * slots_per
    # slots are unique (disjoint windows -> disjoint slots)
    assert len(set(slot_of)) == len(slot_of)


def test_plan_slot_placement_single_device_identity():
    slot_of, num_slots, slots_per = plan_slot_placement(5, 1)
    assert slot_of == [0, 1, 2, 3, 4]
    assert num_slots == slots_per == 8          # pow2 shape bucketing


def test_pack_rows_shard_major_groups_and_pads():
    slots = np.array([0, 3, 0, 7, 2, 3])        # slots_per=2, 4 devices
    per, rows = pack_rows_shard_major(slots, 4, 2)
    # shard of row = slot // 2 -> shards [0, 1, 0, 3, 1, 1]
    assert [list(p) for p in per] == [[0, 2], [1, 4, 5], [], [3]]
    assert rows == 4                            # max shard size 3 -> pow2
    per, rows = pack_rows_shard_major(np.array([0, 0, 0]), 2, 2)
    assert rows == 4                            # 3 rows -> padded to 4


def test_make_slot_mesh_single_device_is_none():
    assert make_slot_mesh(1) is None
    if NDEV < 2:
        assert make_slot_mesh(0) is None
    else:
        mesh = make_slot_mesh(0)
        assert mesh is not None and mesh.size == NDEV


# ------------------------------------------------------------ empty batch
def test_batch_executor_empty_items_is_noop():
    eng = _make_engine("average", batched=True, sharded=False)
    before = (eng.metrics.batch_executions, eng.metrics.live_executions,
              eng.metrics.late_executions, eng.metrics.exec_seconds)
    assert eng.batch_exec.execute([], now=0.0) == {}
    after = (eng.metrics.batch_executions, eng.metrics.live_executions,
             eng.metrics.late_executions, eng.metrics.exec_seconds)
    assert before == after
    eng.close()


def test_batched_kernel_empty_batch_is_identity():
    out = segment_aggregate_batched(
        jnp.zeros((0, 16, 2), jnp.float32), jnp.zeros((0, 16), jnp.int32),
        4)
    assert out["sum"].shape == (0, 4, 2)
    assert out["count"].shape == (0, 4)
    out = segment_aggregate_batched(
        jnp.zeros((0, 16, 2), jnp.float32), jnp.zeros((0, 16), jnp.int32),
        4, slot_ids=jnp.zeros((0,), jnp.int32), num_slots=8)
    assert out["sum"].shape == (8, 4, 2)
    assert float(out["sum"].sum()) == 0.0
    assert float(out["count"].sum()) == 0.0
    assert bool(jnp.all(jnp.isinf(out["min"]))) \
        and bool(jnp.all(out["min"] > 0))
    assert bool(jnp.all(jnp.isinf(out["max"]))) \
        and bool(jnp.all(out["max"] < 0))


def test_ref_batched_empty_batch_is_identity():
    from repro.kernels import ref as R
    out = R.ref_segment_aggregate_batched(
        jnp.zeros((0, 8, 1), jnp.float32), jnp.zeros((0, 8), jnp.int32),
        3, slot_ids=jnp.zeros((0,), jnp.int32), num_slots=4)
    assert out["sum"].shape == (4, 3, 1)
    assert float(out["count"].sum()) == 0.0


# ----------------------------------------------------------- engine parity
def _make_engine(op_name: str, batched: bool, sharded: bool,
                 block: int = 64, width: int = 2,
                 num_keys: int = 8, **aion_kw) -> StreamEngine:
    aion = AionConfig(block_size=block, batched_execution=batched,
                      slot_sharding=sharded, **aion_kw)
    kw = {}
    if op_name == "stock":
        kw = {"num_keys": num_keys}
    elif op_name == "lrb":
        kw = {"num_segments": num_keys}
    op = make_operator(op_name, block, width, **kw)
    return StreamEngine(
        assigner=TumblingWindows(WINDOW), operator=op, aion=aion,
        value_width=width, device_budget_bytes=64 << 20,
        trigger=DeltaTTrigger(executions=2),
    )


def _late_heavy_run(eng: StreamEngine, seed: int = 7):
    rng = np.random.default_rng(seed)
    horizon = N_WINDOWS * WINDOW
    n = 3600
    b = EventBatch(rng.integers(0, 8, n), rng.uniform(0, horizon, n),
                   rng.normal(size=(n, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(horizon, now=horizon)
    nl = 1000
    late = EventBatch(rng.integers(0, 8, nl),
                      rng.uniform(0, horizon - WINDOW, nl),
                      rng.normal(size=(nl, 2)).astype(np.float32))
    eng.ingest(late, now=horizon + 1.0)
    for t in np.linspace(horizon + 1,
                         horizon + 1 + 2 * eng.cleanup.current_bound(), 25):
        eng.poll(t)
    results = dict(eng.results)
    metrics = eng.metrics
    eng.close()
    return results, metrics


def _assert_equal_results(got, want, tag):
    assert set(got) == set(want)
    for wid in want:
        g, w = got[wid], want[wid]
        if isinstance(w, dict):
            for k in w:
                np.testing.assert_allclose(
                    np.asarray(g[k], np.float64),
                    np.asarray(w[k], np.float64), rtol=1e-4, atol=1e-5,
                    err_msg=f"{tag} {wid} field {k!r}")
        else:
            assert g == pytest.approx(w, rel=1e-4, abs=1e-5), f"{tag} {wid}"


@multidevice
@pytest.mark.parametrize("op_name", ["average", "stock", "lrb"])
def test_sharded_matches_unsharded_and_reference(op_name):
    got_s, m_s = _late_heavy_run(_make_engine(op_name, True, True))
    got_u, m_u = _late_heavy_run(_make_engine(op_name, True, False))
    want, m_r = _late_heavy_run(_make_engine(op_name, False, False))
    _assert_equal_results(got_s, got_u, f"{op_name} sharded-vs-unsharded")
    _assert_equal_results(got_s, want, f"{op_name} sharded-vs-reference")
    # the sharded run really ran sharded; the others never did
    assert m_s.sharded_batch_executions >= 1
    assert m_s.batch_executions == m_u.batch_executions
    assert m_u.sharded_batch_executions == 0
    assert m_r.batch_executions == 0
    assert m_s.live_executions == m_u.live_executions \
        == m_r.live_executions == N_WINDOWS


def test_slot_sharding_is_safe_noop_on_single_device():
    """slot_sharding=True clamped to one device (1-device host, or
    slot_shard_devices=1) silently uses the unsharded batched path —
    same results, no mesh."""
    eng = _make_engine("average", True, True, slot_shard_devices=1)
    got, m = _late_heavy_run(eng)
    want, _ = _late_heavy_run(_make_engine("average", True, False))
    assert m.sharded_batch_executions == 0
    assert m.batch_executions >= 1
    _assert_equal_results(got, want, "single-device noop")


@multidevice
def test_sharded_more_windows_than_slots_per_device():
    """More due windows than devices: several windows share each device's
    slot range and the padded layout still folds correctly."""
    eng = _make_engine("average", True, True)
    rng = np.random.default_rng(3)
    n_win = max(2 * NDEV + 3, N_WINDOWS)
    horizon = n_win * WINDOW
    n = 4000
    b = EventBatch(rng.integers(0, 8, n), rng.uniform(0, horizon, n),
                   rng.normal(size=(n, 2)).astype(np.float32))
    eng.ingest(b, now=0.0)
    eng.advance_watermark(horizon, now=horizon)
    assert eng.metrics.batch_executions == 1
    assert eng.metrics.sharded_batch_executions == 1
    assert eng.metrics.live_executions == n_win
    ts = b.timestamps
    from repro.core.windows import WindowId
    for i in range(n_win):
        sel = (ts >= i * WINDOW) & (ts < (i + 1) * WINDOW)
        if not sel.any():
            continue
        want = float(np.mean(b.values[sel, 0]))
        assert eng.results[WindowId(i * WINDOW, (i + 1) * WINDOW)] == \
            pytest.approx(want, rel=1e-4, abs=1e-5)
    eng.close()


# ---------------------------------------------------- device-side stacking
@pytest.mark.parametrize("sharded", [False, True])
def test_device_stacking_matches_host_stacking(sharded):
    """The device concat gather and the PR-1 host np.stack gather fold to
    identical results (hot m-blocks consumed in place vs pulled back)."""
    if sharded and NDEV < 2:
        pytest.skip("sharded variant needs >= 2 devices")
    results = {}
    for device_stacking in (True, False):
        aion = AionConfig(block_size=64, batched_execution=True,
                          slot_sharding=sharded,
                          device_stacking=device_stacking)
        eng = StreamEngine(
            assigner=TumblingWindows(WINDOW),
            operator=make_operator("stock", 64, 2, num_keys=8),
            aion=aion, value_width=2, device_budget_bytes=64 << 20,
            trigger=DeltaTTrigger(executions=2),
        )
        got, m = _late_heavy_run(eng, seed=13)
        assert m.batch_executions >= 1
        results[device_stacking] = got
    _assert_equal_results(results[True], results[False],
                          f"device-vs-host stack (sharded={sharded})")


# -------------------------------------------------------- kernel laylout
@multidevice
@pytest.mark.parametrize("num_devices", [2, 4, 8])
def test_sharded_kernel_parity_all_backends(num_devices):
    if num_devices > NDEV:
        pytest.skip(f"needs {num_devices} devices, have {NDEV}")
    rng = np.random.default_rng(num_devices)
    slots_per, rows_per, n, w, s = 2, 4, 48, 2, 5
    num_slots = num_devices * slots_per
    b = num_devices * rows_per
    slots = np.concatenate([
        rng.integers(d * slots_per, (d + 1) * slots_per, rows_per)
        for d in range(num_devices)]).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(b, n, w)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, s, (b, n)), jnp.int32)
    fills = rng.integers(0, n + 1, b)            # includes all-invalid rows
    valid = jnp.asarray(np.arange(n)[None, :] < fills[:, None])
    mesh = make_slot_mesh(num_devices)
    kw = dict(valid=valid, slot_ids=jnp.asarray(slots),
              num_slots=num_slots)
    out_s = segment_aggregate_batched(vals, ids, s, mesh=mesh, **kw)
    out_u = segment_aggregate_batched(vals, ids, s, **kw)
    from repro.kernels import ref as R
    ref = R.ref_segment_aggregate_batched(vals, ids, s, **kw)
    for k in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(out_s[k], out_u[k], rtol=1e-6,
                                   atol=1e-6, err_msg=f"{k} vs unsharded")
        a, bb = np.asarray(out_s[k]), np.asarray(ref[k])
        m = np.isfinite(bb)
        assert np.array_equal(np.isfinite(a), m), k
        np.testing.assert_allclose(a[m], bb[m], rtol=1e-5, atol=1e-5,
                                   err_msg=f"{k} vs ref")


@multidevice
def test_sharded_kernel_rejects_indivisible_layout():
    mesh = make_slot_mesh(2)
    from repro.kernels.segment_aggregate import (
        segment_aggregate_batched_sharded,
    )
    with pytest.raises(ValueError, match="divide"):
        segment_aggregate_batched_sharded(
            jnp.zeros((3, 8, 1)), jnp.zeros((3, 8), jnp.int32), 2,
            slot_ids=jnp.zeros((3,), jnp.int32), num_slots=4, mesh=mesh)


@multidevice
def test_sharded_kernel_masks_misplaced_rows():
    """A row whose slot lives on another shard contributes nothing rather
    than corrupting a resident slot (defensive ownership mask)."""
    from repro.kernels.segment_aggregate import (
        segment_aggregate_batched_sharded,
    )
    mesh = make_slot_mesh(2)
    # 2 devices x 1 row; row 0 claims slot 1 which device 1 owns
    vals = jnp.ones((2, 8, 1), jnp.float32)
    ids = jnp.zeros((2, 8), jnp.int32)
    slots = jnp.asarray([1, 1], jnp.int32)
    out = segment_aggregate_batched_sharded(
        vals, ids, 1, slot_ids=slots, num_slots=2, mesh=mesh)
    # only device 1's own row lands in slot 1; device 0's misplaced row
    # is masked out instead of folding into device 0's slot 0
    assert float(out["count"][0, 0]) == 0.0
    assert float(out["count"][1, 0]) == 8.0
    assert float(out["sum"][1, 0, 0]) == 8.0


def test_next_pow2():
    assert [next_pow2(i) for i in (0, 1, 2, 3, 5, 8, 9)] == \
        [1, 1, 2, 4, 8, 8, 16]
