"""Per-kernel sweeps: interpret-mode Pallas vs the pure-jnp oracle,
across shapes and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import (
    decode_attention_paged, flash_attention, segment_aggregate,
    segment_aggregate_batched, segment_aggregate_block_table,
    segment_aggregate_block_table_splitk, ssd_chunk_scan,
)
from repro.kernels import ref as R
from repro.kernels.segment_aggregate import (
    merge_partials, pack_rows_shard_major,
)

RNG = np.random.default_rng(42)


def _assert_aggs_close(out, ref, stats=("sum", "count", "min", "max")):
    if "sum" in stats:
        np.testing.assert_allclose(out["sum"], ref["sum"], rtol=1e-5,
                                   atol=1e-5)
    if "count" in stats:
        np.testing.assert_allclose(out["count"], ref["count"], rtol=0,
                                   atol=0)
    for k in ("min", "max"):
        if k not in stats:
            continue
        a, b = np.asarray(out[k]), np.asarray(ref[k])
        m = np.isfinite(b)
        assert np.array_equal(np.isfinite(a), m), k
        np.testing.assert_allclose(a[m], b[m], rtol=1e-6)


# ------------------------------------------------------------ segment agg
@pytest.mark.parametrize("n,w,s,block_n", [
    (64, 1, 4, 32), (1000, 8, 37, 128), (4096, 16, 128, 512),
    (130, 3, 5, 64),
])
def test_segment_aggregate_sweep(n, w, s, block_n):
    vals = jnp.asarray(RNG.normal(size=(n, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, n), jnp.int32)
    valid = jnp.asarray(RNG.random(n) > 0.2)
    out = segment_aggregate(vals, ids, s, valid=valid, backend="interpret",
                            block_n=block_n)
    ref = R.ref_segment_aggregate(vals, ids, s, valid=valid)
    np.testing.assert_allclose(out["sum"], ref["sum"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out["count"], ref["count"], rtol=0, atol=0)
    for k in ("min", "max"):
        a, b = np.asarray(out[k]), np.asarray(ref[k])
        m = np.isfinite(b)
        assert np.array_equal(np.isfinite(a), m)
        np.testing.assert_allclose(a[m], b[m], rtol=1e-6)


def test_segment_aggregate_all_invalid():
    vals = jnp.ones((64, 2), jnp.float32)
    ids = jnp.zeros((64,), jnp.int32)
    valid = jnp.zeros((64,), bool)
    out = segment_aggregate(vals, ids, 4, valid=valid, backend="interpret")
    assert float(out["count"].sum()) == 0.0
    assert float(out["sum"].sum()) == 0.0


# ------------------------------------------------- batched segment agg
@pytest.mark.parametrize("b,n,w,s,num_slots,block_n", [
    (6, 64, 1, 4, 3, 64),           # blocks sharing slots
    (8, 128, 4, 16, 8, 128),        # one block per slot
    (5, 100, 2, 7, 5, 512),         # ragged block_n vs n
])
def test_segment_aggregate_batched_ragged_fills(b, n, w, s, num_slots,
                                                block_n):
    """The extended multi-window kernel vs the jnp oracle with ragged
    fills: each block row is only partially valid, and several rows may
    map onto the same window slot."""
    vals = jnp.asarray(RNG.normal(size=(b, n, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (b, n)), jnp.int32)
    fills = RNG.integers(1, n + 1, b)                  # ragged fills
    valid = jnp.asarray(np.arange(n)[None, :] < fills[:, None])
    slots = jnp.asarray(np.sort(RNG.integers(0, num_slots, b)), jnp.int32)
    out = segment_aggregate_batched(vals, ids, s, valid=valid,
                                    slot_ids=slots, num_slots=num_slots,
                                    backend="interpret", block_n=block_n)
    ref = R.ref_segment_aggregate_batched(vals, ids, s, valid=valid,
                                          slot_ids=slots,
                                          num_slots=num_slots)
    assert out["sum"].shape == (num_slots, s, w)
    assert out["count"].shape == (num_slots, s)
    np.testing.assert_allclose(out["sum"], ref["sum"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out["count"], ref["count"], rtol=0, atol=0)
    for k in ("min", "max"):
        a, bb = np.asarray(out[k]), np.asarray(ref[k])
        m = np.isfinite(bb)
        assert np.array_equal(np.isfinite(a), m)
        np.testing.assert_allclose(a[m], bb[m], rtol=1e-6)


@pytest.mark.parametrize("num_devices", [d for d in (1, 2, 4, 8)
                                         if d <= len(jax.devices())])
def test_segment_aggregate_batched_sharded_sweep(num_devices):
    """Slot-sharded kernel vs unsharded vs oracle, on the executor's
    shard-major layout (1-device count = unsharded fallback; higher
    counts run under make verify-multidevice)."""
    from repro.distributed.sharding import make_slot_mesh
    from repro.kernels import ref as R2
    slots_per, rows_per, n, w, s = 3, 5, 40, 2, 7
    num_slots = num_devices * slots_per
    b = num_devices * rows_per
    slots = np.concatenate([
        RNG.integers(d * slots_per, (d + 1) * slots_per, rows_per)
        for d in range(num_devices)]).astype(np.int32)
    vals = jnp.asarray(RNG.normal(size=(b, n, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (b, n)), jnp.int32)
    fills = RNG.integers(0, n + 1, b)           # ragged incl. empty rows
    valid = jnp.asarray(np.arange(n)[None, :] < fills[:, None])
    kw = dict(valid=valid, slot_ids=jnp.asarray(slots),
              num_slots=num_slots)
    mesh = make_slot_mesh(num_devices)
    out = segment_aggregate_batched(vals, ids, s, mesh=mesh, **kw)
    out_u = segment_aggregate_batched(vals, ids, s, **kw)
    ref = R2.ref_segment_aggregate_batched(vals, ids, s, **kw)
    assert out["sum"].shape == (num_slots, s, w)
    for k in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(out[k], out_u[k], rtol=1e-6, atol=1e-6)
        a, bb = np.asarray(out[k]), np.asarray(ref[k])
        m = np.isfinite(bb)
        assert np.array_equal(np.isfinite(a), m), k
        np.testing.assert_allclose(a[m], bb[m], rtol=1e-5, atol=1e-5)


def test_segment_aggregate_batched_empty_batch_no_launch():
    """B == 0 returns fold identities with the right shapes instead of
    launching a degenerate [0, ...] kernel (regression: empty batch)."""
    out = segment_aggregate_batched(
        jnp.zeros((0, 32, 3), jnp.float32), jnp.zeros((0, 32), jnp.int32),
        5, slot_ids=jnp.zeros((0,), jnp.int32), num_slots=4)
    assert out["sum"].shape == (4, 5, 3)
    assert out["count"].shape == (4, 5)
    assert float(jnp.abs(out["sum"]).sum()) == 0.0
    assert bool(jnp.all(jnp.isposinf(out["min"])))
    assert bool(jnp.all(jnp.isneginf(out["max"])))


def test_segment_aggregate_batched_equals_per_window_calls():
    """Folding N windows in one batched launch == N single-window kernel
    calls (the engine-level parity claim, at the kernel level)."""
    b, n, w, s = 6, 64, 2, 5
    vals = jnp.asarray(RNG.normal(size=(b, n, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (b, n)), jnp.int32)
    fills = RNG.integers(1, n + 1, b)
    valid = jnp.asarray(np.arange(n)[None, :] < fills[:, None])
    out = segment_aggregate_batched(vals, ids, s, valid=valid,
                                    backend="interpret", block_n=64)
    for i in range(b):
        one = segment_aggregate(vals[i], ids[i], s, valid=valid[i],
                                backend="interpret", block_n=64)
        np.testing.assert_allclose(out["sum"][i], one["sum"],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(out["count"][i], one["count"],
                                   rtol=0, atol=0)


# ------------------------------------------------- block-table segment agg
@pytest.mark.parametrize("backend", ["dense", "interpret"])
@pytest.mark.parametrize("p,cap,w,s,r,num_slots", [
    (8, 32, 1, 4, 6, 4),           # fewer rows than pool slots
    (16, 64, 3, 7, 16, 8),         # repeated pool slots across rows
    (4, 128, 2, 16, 8, 2),         # many rows per slot
])
def test_segment_aggregate_block_table_sweep(backend, p, cap, w, s, r,
                                             num_slots):
    """The zero-copy pool-gather fold vs the take-then-reduce oracle:
    random tables (with repeats — several rows referencing the same
    arena slot), ragged fills, shared window slots."""
    arena = jnp.asarray(RNG.normal(size=(p, cap, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (r, cap)), jnp.int32)
    table = jnp.asarray(RNG.integers(0, p, r), jnp.int32)
    fills = RNG.integers(0, cap + 1, r)            # ragged incl. empty
    valid = jnp.asarray(np.arange(cap)[None, :] < fills[:, None])
    slots = jnp.asarray(RNG.integers(0, num_slots, r), jnp.int32)
    out = segment_aggregate_block_table(
        arena, ids, table, s, valid=valid, slot_ids=slots,
        num_slots=num_slots, backend=backend)
    ref = R.ref_segment_aggregate_block_table(
        arena, ids, table, s, valid=valid, slot_ids=slots,
        num_slots=num_slots)
    assert out["sum"].shape == (num_slots, s, w)
    _assert_aggs_close(out, ref)


def test_segment_aggregate_block_table_equals_stacked():
    """Referencing rows through the table == stacking the same rows: the
    pooled and device-concat engine paths must be interchangeable."""
    p, cap, w, s, r = 12, 48, 2, 5, 7
    arena = jnp.asarray(RNG.normal(size=(p, cap, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (r, cap)), jnp.int32)
    table = jnp.asarray(RNG.integers(0, p, r), jnp.int32)
    fills = RNG.integers(1, cap + 1, r)
    valid = jnp.asarray(np.arange(cap)[None, :] < fills[:, None])
    slots = jnp.asarray(RNG.integers(0, 4, r), jnp.int32)
    bt = segment_aggregate_block_table(
        arena, ids, table, s, valid=valid, slot_ids=slots, num_slots=4,
        backend="interpret")
    stacked = segment_aggregate_batched(
        jnp.take(arena, table, axis=0), ids, s, valid=valid,
        slot_ids=slots, num_slots=4, backend="interpret")
    _assert_aggs_close(bt, stacked)


def test_segment_aggregate_block_table_empty_table():
    out = segment_aggregate_block_table(
        jnp.zeros((4, 16, 2), jnp.float32), jnp.zeros((0, 16), jnp.int32),
        jnp.zeros((0,), jnp.int32), 3,
        slot_ids=jnp.zeros((0,), jnp.int32), num_slots=2)
    assert out["sum"].shape == (2, 3, 2)
    assert float(jnp.abs(out["sum"]).sum()) == 0.0
    assert bool(jnp.all(jnp.isposinf(out["min"])))


# -------------------------------------------- split-K block-table fold
def _splitk_case(p=16, cap=48, w=2, s=5, r=11, num_slots=4):
    arena = jnp.asarray(RNG.normal(size=(p, cap, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (r, cap)), jnp.int32)
    table = jnp.asarray(RNG.integers(1, p, r), jnp.int32)  # never slot 0
    fills = RNG.integers(0, cap + 1, r)
    valid = jnp.asarray(np.arange(cap)[None, :] < fills[:, None])
    slots = jnp.asarray(RNG.integers(0, num_slots, r), jnp.int32)
    return arena, ids, table, valid, slots, s, num_slots


@pytest.mark.parametrize("backend", ["dense", "interpret", "ref"])
@pytest.mark.parametrize("chunk", [1, 3, 4, 11, 16])
def test_segment_aggregate_block_table_splitk_sweep(backend, chunk):
    """Chunked partial-accumulator fold vs both oracles: the unchunked
    block-table reference (loose — different fp associativity) and the
    chunked reference at the same chunk size (tight)."""
    arena, ids, table, valid, slots, s, ns = _splitk_case()
    out = segment_aggregate_block_table_splitk(
        arena, ids, table, s, chunk, valid=valid, slot_ids=slots,
        num_slots=ns, backend=backend)
    plain = R.ref_segment_aggregate_block_table(
        arena, ids, table, s, valid=valid, slot_ids=slots, num_slots=ns)
    assert out["sum"].shape == (ns, s, arena.shape[-1])
    _assert_aggs_close(out, plain)
    chunked = R.ref_segment_aggregate_block_table_splitk(
        arena, ids, table, s, chunk, valid=valid, slot_ids=slots,
        num_slots=ns)
    _assert_aggs_close(out, chunked)


@pytest.mark.parametrize("backend", ["dense", "interpret", "ref"])
@pytest.mark.parametrize("chunk", [3, 4])
def test_splitk_padding_rows_are_bit_exact_inert(backend, chunk):
    """Deliberate padding rows (masked-invalid, pointing at a poisoned
    arena slot) must not perturb ANY stat — sum/count and the identity-
    sensitive min/max — bit-for-bit, across all three backends.

    Covers the accumulator-identity bug class: a padded row leaking a
    poisoned value into slot 0 / the min/max identity lanes."""
    arena, ids, table, valid, slots, s, ns = _splitk_case(r=8)
    poisoned = arena.at[0].set(1e30)       # no real row references it
    out = segment_aggregate_block_table_splitk(
        arena, ids, table, s, chunk, valid=valid, slot_ids=slots,
        num_slots=ns, backend=backend)
    # (a) internal pad-to-chunk rows read arena slot 0: poison it
    pois = segment_aggregate_block_table_splitk(
        poisoned, ids, table, s, chunk, valid=valid, slot_ids=slots,
        num_slots=ns, backend=backend)
    # (b) explicit all-invalid padding rows aimed at the poisoned slot
    r_pad = 4
    table2 = jnp.concatenate([table, jnp.zeros(r_pad, jnp.int32)])
    ids2 = jnp.concatenate([ids, jnp.zeros((r_pad, ids.shape[1]),
                                           jnp.int32)])
    valid2 = jnp.concatenate(
        [valid, jnp.zeros((r_pad, valid.shape[1]), bool)])
    slots2 = jnp.concatenate([slots, jnp.zeros(r_pad, jnp.int32)])
    pad = segment_aggregate_block_table_splitk(
        poisoned, ids2, table2, s, chunk, valid=valid2, slot_ids=slots2,
        num_slots=ns, backend=backend)
    for k in ("sum", "count", "min", "max"):
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(pois[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(pad[k]), err_msg=k)


@pytest.mark.parametrize("backend", ["dense", "interpret", "ref"])
def test_splitk_empty_and_zero_slot_guards(backend):
    """B==0 / num_slots==0 guards on the split-K path: identity arrays
    of the right shape, no kernel launch, no NaNs."""
    arena = jnp.zeros((4, 16, 2), jnp.float32)
    out = segment_aggregate_block_table_splitk(
        arena, jnp.zeros((0, 16), jnp.int32), jnp.zeros((0,), jnp.int32),
        3, 4, slot_ids=jnp.zeros((0,), jnp.int32), num_slots=2,
        backend=backend)
    assert out["sum"].shape == (2, 3, 2)
    assert float(jnp.abs(out["sum"]).sum()) == 0.0
    assert bool(jnp.all(jnp.isposinf(out["min"])))
    assert bool(jnp.all(jnp.isneginf(out["max"])))
    empty_slots = segment_aggregate_block_table_splitk(
        arena, jnp.zeros((2, 16), jnp.int32), jnp.zeros((2,), jnp.int32),
        3, 4, slot_ids=jnp.zeros((2,), jnp.int32), num_slots=0,
        backend=backend)
    assert empty_slots["sum"].shape == (0, 3, 2)
    with pytest.raises(ValueError):
        segment_aggregate_block_table_splitk(
            arena, jnp.zeros((2, 16), jnp.int32),
            jnp.zeros((2,), jnp.int32), 3, 0, num_slots=1,
            backend=backend)


def test_splitk_all_rows_invalid_yields_identity():
    """A window whose every row demoted mid-round: all-invalid rows fold
    to the empty-batch identity (0 sum/count, +/-inf min/max)."""
    arena, ids, table, valid, slots, s, ns = _splitk_case(r=6)
    none = jnp.zeros_like(valid)
    for backend in ("dense", "interpret", "ref"):
        out = segment_aggregate_block_table_splitk(
            arena, ids, table, s, 4, valid=none, slot_ids=slots,
            num_slots=ns, backend=backend)
        assert float(jnp.abs(out["sum"]).sum()) == 0.0
        assert int(out["count"].sum()) == 0
        assert bool(jnp.all(jnp.isposinf(out["min"])))
        assert bool(jnp.all(jnp.isneginf(out["max"])))


def test_merge_partials_identity_and_roundtrip():
    """merge_partials(k=0) returns the fold identity; merging unmerged
    per-chunk partials equals the merged kernel output."""
    from repro.kernels.segment_aggregate import (
        segment_aggregate_block_table_splitk_pallas)
    empty = merge_partials({
        "sum": jnp.zeros((0, 2, 3, 1)), "count": jnp.zeros((0, 2, 3)),
        "min": jnp.zeros((0, 2, 3, 1)), "max": jnp.zeros((0, 2, 3, 1))})
    assert bool(jnp.all(jnp.isposinf(empty["min"])))
    assert bool(jnp.all(jnp.isneginf(empty["max"])))
    assert float(jnp.abs(empty["sum"]).sum()) == 0.0
    arena, ids, table, valid, slots, s, ns = _splitk_case()
    parts = segment_aggregate_block_table_splitk_pallas(
        arena, ids, table, s, 4, valid=valid, slot_ids=slots,
        num_slots=ns, merge=False)
    assert parts["sum"].shape[0] == 3          # ceil(11 / 4) chunks
    merged = merge_partials(parts)
    whole = segment_aggregate_block_table_splitk(
        arena, ids, table, s, 4, valid=valid, slot_ids=slots,
        num_slots=ns, backend="interpret")
    for k in ("sum", "count", "min", "max"):
        np.testing.assert_array_equal(np.asarray(merged[k]),
                                      np.asarray(whole[k]), err_msg=k)


def test_pack_rows_shard_major_balance():
    """balance=True deals row indices round-robin so every device gets
    |rows|/D +- 1 regardless of slot skew."""
    slots = np.array([0] * 9 + [1, 2], np.int32)     # heavy skew to 0
    per, rows_per = pack_rows_shard_major(slots, 4, 1, balance=True)
    assert sorted(len(p) for p in per) == [2, 3, 3, 3]
    assert rows_per == 4                              # next_pow2(3)
    assert sorted(np.concatenate(
        [np.asarray(p) for p in per]).tolist()) == list(range(11))
    # ownership mode would serialize: everything on slot 0's shard
    own, _ = pack_rows_shard_major(slots, 4, 1, balance=False)
    assert len(own[0]) == 9


@pytest.mark.parametrize("num_devices", [d for d in (2, 4, 8)
                                         if d <= len(jax.devices())])
def test_segment_aggregate_batched_splitk_sharded(num_devices):
    """Row-balanced sharded fold (split-K over devices): full per-slot
    partials per device merged after the shard_map — vs the unsharded
    oracle. num_slots deliberately does NOT divide the mesh (runs under
    make verify-splitk; skipped on one device)."""
    from repro.distributed.sharding import make_slot_mesh
    b, n, w, s, ns = 4 * num_devices, 64, 2, 5, 6
    vals = jnp.asarray(RNG.normal(size=(b, n, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (b, n)), jnp.int32)
    fills = RNG.integers(0, n + 1, b)
    valid = jnp.asarray(np.arange(n)[None, :] < fills[:, None])
    slots = jnp.asarray(RNG.integers(0, ns, b), jnp.int32)
    mesh = make_slot_mesh(num_devices)
    out = segment_aggregate_batched(vals, ids, s, valid=valid,
                                    slot_ids=slots, num_slots=ns,
                                    mesh=mesh, splitk=1)
    ref = segment_aggregate_batched(vals, ids, s, valid=valid,
                                    slot_ids=slots, num_slots=ns)
    _assert_aggs_close(out, ref)


@pytest.mark.parametrize("num_devices", [d for d in (2, 4, 8)
                                         if d <= len(jax.devices())])
@pytest.mark.parametrize("backend", ["dense", "interpret"])
def test_segment_aggregate_block_table_sharded(num_devices, backend):
    """Sharded block-table fold: the arena partitions over the mesh and
    each shard gathers only from its own tile — vs the unsharded oracle
    (runs under make verify-multidevice; skipped on one device)."""
    from repro.distributed.sharding import make_slot_mesh
    p_per, slots_per, rows_per, cap, w, s = 4, 2, 3, 32, 2, 5
    p = num_devices * p_per
    num_slots = num_devices * slots_per
    r = num_devices * rows_per
    arena = jnp.asarray(RNG.normal(size=(p, cap, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, (r, cap)), jnp.int32)
    # shard-major rows: row block d references pool range / slot range d
    table = np.concatenate([
        RNG.integers(d * p_per, (d + 1) * p_per, rows_per)
        for d in range(num_devices)]).astype(np.int32)
    slots = np.concatenate([
        RNG.integers(d * slots_per, (d + 1) * slots_per, rows_per)
        for d in range(num_devices)]).astype(np.int32)
    fills = RNG.integers(0, cap + 1, r)
    valid = jnp.asarray(np.arange(cap)[None, :] < fills[:, None])
    kw = dict(valid=valid, slot_ids=jnp.asarray(slots),
              num_slots=num_slots)
    mesh = make_slot_mesh(num_devices)
    out = segment_aggregate_block_table(arena, ids, jnp.asarray(table), s,
                                        mesh=mesh, backend=backend, **kw)
    ref = R.ref_segment_aggregate_block_table(arena, ids,
                                              jnp.asarray(table), s, **kw)
    _assert_aggs_close(out, ref)


@pytest.mark.parametrize("num_devices", [d for d in (2, 4, 8)
                                         if d <= len(jax.devices())])
def test_bigram_segment_count_sharded_matches_flat(num_devices):
    """The big-vocab bigram scatter path shards like the dense kernel:
    shard-major rows, slot-local scatters, psum-free — vs the flat
    single-device scatter (runs under make verify-multidevice)."""
    from repro.core.operators import _bigram_segment_count
    from repro.distributed.sharding import make_slot_mesh
    vocab, slots_per, rows_per, pairs = 64, 2, 3, 40
    num_slots = num_devices * slots_per
    b = num_devices * rows_per
    ids = jnp.asarray(RNG.integers(0, vocab * vocab, (b, pairs)),
                      jnp.int32)
    pval = jnp.asarray(RNG.random((b, pairs)) > 0.3)
    slots = jnp.asarray(np.concatenate([
        RNG.integers(d * slots_per, (d + 1) * slots_per, rows_per)
        for d in range(num_devices)]), jnp.int32)
    mesh = make_slot_mesh(num_devices)
    got = _bigram_segment_count(ids, pval, slots, num_slots, vocab, mesh)
    want = _bigram_segment_count(ids, pval, slots, num_slots, vocab, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


@pytest.mark.parametrize("stats", [("sum", "count"), ("count",),
                                   ("min", "max"), ("sum",)])
def test_segment_aggregate_stats_selection_pallas(stats):
    """stats threads through the Pallas out_shapes: only the requested
    aggregates come back, and they equal the full-run values (single,
    batched, and block-table entry points)."""
    n, w, s = 96, 2, 6
    vals = jnp.asarray(RNG.normal(size=(n, w)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, s, n), jnp.int32)
    sel = segment_aggregate(vals, ids, s, backend="interpret", stats=stats)
    full = segment_aggregate(vals, ids, s, backend="interpret")
    assert set(sel) == set(stats)
    _assert_aggs_close(sel, full, stats=stats)

    b, cap = 4, 24
    bvals = jnp.asarray(RNG.normal(size=(b, cap, w)), jnp.float32)
    bids = jnp.asarray(RNG.integers(0, s, (b, cap)), jnp.int32)
    bsel = segment_aggregate_batched(bvals, bids, s, backend="interpret",
                                     stats=stats)
    bfull = segment_aggregate_batched(bvals, bids, s, backend="interpret")
    assert set(bsel) == set(stats)
    _assert_aggs_close(bsel, bfull, stats=stats)

    table = jnp.asarray(RNG.integers(0, b, 5), jnp.int32)
    tsel = segment_aggregate_block_table(
        bvals, jnp.take(bids, table, axis=0), table, s,
        slot_ids=jnp.zeros((5,), jnp.int32), num_slots=1,
        backend="interpret", stats=stats)
    tfull = segment_aggregate_block_table(
        bvals, jnp.take(bids, table, axis=0), table, s,
        slot_ids=jnp.zeros((5,), jnp.int32), num_slots=1,
        backend="interpret")
    assert set(tsel) == set(stats)
    _assert_aggs_close(tsel, tfull, stats=stats)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,sq,sk,h,hkv,d,causal,window", [
    (1, 128, 128, 2, 2, 64, True, 0),
    (2, 256, 256, 4, 2, 64, True, 0),
    (2, 256, 256, 4, 1, 128, False, 0),
    (1, 512, 512, 2, 2, 64, True, 128),
    (1, 128, 384, 2, 2, 64, False, 0),      # cross-attention shape
])
def test_flash_attention_sweep(b, sq, sk, h, hkv, d, causal, window):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, sk, hkv, d)), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        backend="interpret", block_q=128, block_k=128)
    r = R.ref_flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(o, r, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    o = flash_attention(q, k, v, backend="interpret", block_q=64, block_k=64)
    r = R.ref_flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=0.05,
                               atol=0.05)


def test_flash_matches_model_blocked_attention():
    """The model's XLA blocked attention and the Pallas kernel agree."""
    from repro.models.attention import blocked_attention
    q = jnp.asarray(RNG.normal(size=(2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 256, 2, 64)), jnp.float32)
    a = blocked_attention(q, k, v, causal=True, block_q=128, block_k=128)
    b = flash_attention(q, k, v, causal=True, backend="interpret",
                        block_q=128, block_k=128)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------ paged decode attn
@pytest.mark.parametrize("b,h,hkv,d,pages,page,pps", [
    (2, 4, 2, 64, 8, 16, 3),
    (3, 8, 2, 64, 16, 32, 4),
    (1, 8, 8, 128, 8, 64, 2),
])
def test_decode_attention_paged_sweep(b, h, hkv, d, pages, page, pps):
    q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(pages, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(pages, page, hkv, d)), jnp.float32)
    table = np.full((b, pps), -1, np.int32)
    lens = np.zeros((b,), np.int32)
    perm = RNG.permutation(pages)
    c = 0
    for i in range(b):
        used = RNG.integers(1, pps + 1)
        table[i, :used] = perm[c:c + used]
        c += used
        lens[i] = RNG.integers(1, used * page + 1)
    o = decode_attention_paged(q, kp, vp, jnp.asarray(table),
                               jnp.asarray(lens), backend="interpret")
    r = R.ref_decode_attention_paged(q, kp, vp, jnp.asarray(table),
                                     jnp.asarray(lens))
    np.testing.assert_allclose(o, r, rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,s,h,p,n,chunk,hb", [
    (1, 128, 4, 32, 16, 64, 4),
    (2, 256, 8, 32, 16, 64, 4),
    (2, 256, 8, 64, 32, 128, 8),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk, hb):
    xdt = jnp.asarray(RNG.normal(size=(b, s, h, p)) * 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h))) * 0.1, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    y = ssd_chunk_scan(xdt, a, B, C, chunk=chunk, head_block=hb,
                       backend="interpret")
    yr, _ = R.ref_ssd_chunk_scan(xdt, a, B, C, chunk)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)


def test_ssd_model_scan_matches_sequential_oracle():
    """The model's chunked SSD equals the token-by-token recurrence."""
    from repro.models.ssm import ssd_scan as model_ssd
    b, s, h, p, n = 2, 192, 4, 16, 8
    xdt = jnp.asarray(RNG.normal(size=(b, s, h, p)) * 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h))) * 0.1, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    y, state = model_ssd(xdt, a, B, C, 64)
    yr, state_r = R.ref_ssd_chunk_scan(xdt, a, B, C, 64)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(state, state_r, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- flash attention bwd
@pytest.mark.parametrize("causal,window,hkv", [
    (True, 0, 2), (False, 0, 4), (True, 64, 2), (True, 0, 1),
])
def test_flash_attention_vjp_grads_match_ref(causal, window, hkv):
    """The Pallas backward (recompute-from-lse) equals autodiff through the
    materialized reference, including GQA group-gradient summation."""
    from repro.kernels import flash_attention_vjp
    B, Sq, Sk, H, D = 2, 128, 128, 4, 64
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Sk, hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Sk, hkv, D)), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention_vjp(q, k, v, causal, window,
                                           64, 64) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(R.ref_flash_attention(q, k, v, causal=causal,
                                             window=window) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_flash_forward_lse_is_correct():
    from repro.kernels.flash_attention import flash_attention_pallas
    B, S, H, D = 1, 128, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    o, lse = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                    block_k=64, return_lse=True)
    # reference lse
    import math
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(D)
    ref_lse = jax.nn.logsumexp(s, axis=-1)           # [B,H,S]
    got = lse.reshape(B, H, 1, S)[:, :, 0]
    np.testing.assert_allclose(got, ref_lse, rtol=1e-5, atol=1e-5)
