# NOTE: no XLA_FLAGS here — tests and benches run on the single real CPU
# device; only launch/dryrun.py forces the 512-device placeholder platform.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
