"""Per-arch smoke tests: REDUCED same-family configs, one forward/train
step on CPU, asserting output shapes + no NaNs (full configs are exercised
only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.distributed.sharding import pad_vocab
from repro.models import build_model
from repro.train import OptConfig, make_train_step
from repro.train.train_step import TrainState, init_train_state

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                               jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.family in ("audio", "encdec"):
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(model.train_logits)(params, batch)
    b, s = batch["tokens"].shape
    exp_s = s + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, pad_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_loss_finite_and_params_update(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = make_train_step(model, OptConfig(lr=1e-3, warmup_steps=1))
    batch = _batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # at least one parameter moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert moved
    assert int(new_state.opt["step"]) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    full, _ = jax.jit(model.train_logits)(params, batch)
    pre = {k: (v[:, :-1] if k == "tokens" else v)
           for k, v in batch.items() if k != "targets"}
    cap = s + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    _, cache = jax.jit(lambda p, x: model.prefill(p, x, max_len=cap))(
        params, pre)
    dl, cache2 = jax.jit(model.decode_step)(
        params, batch["tokens"][:, -1:], cache)
    a = np.asarray(full[:, -1, :cfg.vocab_size], np.float32)
    d = np.asarray(dl[:, 0, :cfg.vocab_size], np.float32)
    assert (a.argmax(-1) == d.argmax(-1)).all()
    exp_pos = s + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert int(cache2["pos"]) == exp_pos


def test_loss_decreases_over_steps():
    """Tiny overfit sanity: repeated steps on one batch reduce the loss."""
    cfg = reduced(ARCHS["starcoder2-7b"])
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, OptConfig(lr=3e-3, warmup_steps=1,
                                                    weight_decay=0.0)))
    batch = _batch(cfg, b=2, s=16, seed=3)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_microbatched_step_matches_flat_grads():
    """Gradient accumulation must be numerically consistent with the flat
    step (same data, same update)."""
    cfg = reduced(ARCHS["mamba2-780m"])
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(1))
    batch = _batch(cfg, b=4, s=16, seed=7)
    s_flat, m_flat = jax.jit(make_train_step(model))(state, batch)
    s_mu, m_mu = jax.jit(make_train_step(model, num_microbatches=2))(
        state, batch)
    np.testing.assert_allclose(float(m_flat["loss"]), float(m_mu["loss"]),
                               rtol=2e-2)
    for a, b in zip(jax.tree.leaves(s_flat.params),
                    jax.tree.leaves(s_mu.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.05, atol=1e-4)


def test_int8_kv_cache_decode_parity():
    """SPerf variant: the int8 KV cache changes bytes, not answers."""
    cfg = reduced(ARCHS["command-r-35b"])
    m16 = build_model(cfg)
    m8 = build_model(cfg, kv_cache_bits=8)
    params = m16.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    pre = {"tokens": toks[:, :-1]}
    _, c16 = jax.jit(lambda p, x: m16.prefill(p, x, max_len=24))(params, pre)
    _, c8 = jax.jit(lambda p, x: m8.prefill(p, x, max_len=24))(params, pre)
    assert c8["layers"]["k"].dtype == jnp.int8
    l16, _ = jax.jit(m16.decode_step)(params, toks[:, -1:], c16)
    l8, _ = jax.jit(m8.decode_step)(params, toks[:, -1:], c8)
    a = np.asarray(l16[:, 0, :cfg.vocab_size], np.float32)
    b = np.asarray(l8[:, 0, :cfg.vocab_size], np.float32)
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.99
    np.testing.assert_allclose(a, b, atol=0.35, rtol=0.1)


def test_causal_skip_matches_baseline_attention():
    """SPerf variant: causal-skip scheduling is numerically identical."""
    from repro.models.attention import blocked_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 64)), jnp.float32)
    base = blocked_attention(q, k, v, causal=True, block_q=64, block_k=64)
    skip = blocked_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             causal_skip=True)
    np.testing.assert_allclose(base, skip, rtol=1e-5, atol=1e-5)


def test_ssm_streaming_prefill_matches_full():
    """Chunked prefill with carried SSM state == one-shot prefill (the
    long_500k ingestion path)."""
    cfg = reduced(ARCHS["mamba2-780m"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    full_logits, full_cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=65))(params,
                                                      {"tokens": toks})
    sl, scache = model.prefill_streaming(params, {"tokens": toks}, chunk=16)
    a = np.asarray(full_logits[:, 0, :cfg.vocab_size], np.float32)
    b = np.asarray(sl[:, 0, :cfg.vocab_size], np.float32)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    np.testing.assert_allclose(a, b, atol=0.08, rtol=0.05)
    # carried state matches the one-shot state
    np.testing.assert_allclose(
        np.asarray(scache["layers"]["ssm"], np.float32),
        np.asarray(full_cache["layers"]["ssm"], np.float32),
        rtol=2e-2, atol=2e-2)
    # and decoding continues identically
    nxt = toks[:, :1]
    d_full, _ = jax.jit(model.decode_step)(params, nxt, full_cache)
    d_str, _ = jax.jit(model.decode_step)(params, nxt, scache)
    af = np.asarray(d_full[:, 0, :cfg.vocab_size], np.float32)
    as_ = np.asarray(d_str[:, 0, :cfg.vocab_size], np.float32)
    assert (af.argmax(-1) == as_.argmax(-1)).all()
