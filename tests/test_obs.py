"""Unified observability layer (ISSUE 10).

Tentpole: the shared metrics registry behind every legacy ``.stats`` /
``EngineMetrics`` surface, structured tracing with EXPLICIT parent
handoff across threads, and the one-call ``engine.observability()``
snapshot + Prometheus/JSON exporters.

Satellites pinned here:
  1. ``TransferExecutor.stats`` under concurrent hammering — counts are
     exact (the old dict read-modify-write lost increments).
  2. Every unbounded metrics list is capped (``StoreHealth.transitions``
     via ``AionConfig.health_transitions_max``).
  3. Cross-thread trace propagation: the pipelined fold-round span
     parents back to the watermark-advance span, and a retried I/O task
     span records each backoff attempt — asserted on the JSON-lines
     export, not internal state.
"""
import json
import threading

import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import (
    EventBatch, InMemoryPolicy, StreamEngine, TumblingWindows,
    make_operator,
)
from repro.core.health import StoreHealth
from repro.core.pipeline import MultiTenantEngine, TenantSpec
from repro.core.staging import TransferExecutor
from repro.obs import (
    BoundedSeries, MetricsRegistry, NULL_SPAN, StatsMap, Tracer,
    to_json, to_prometheus,
)
from repro.testing.faults import FaultInjector, FaultyBlockStore


def _batch(n, width=1, seed=0, lo=0.0, hi=10.0, keys=8):
    rng = np.random.default_rng(seed)
    return EventBatch(rng.integers(0, keys, n), rng.uniform(lo, hi, n),
                      rng.normal(size=(n, width)).astype(np.float32))


def _engine(tmp_path, store=None, **aion_kw):
    aion = AionConfig(block_size=32, **aion_kw)
    return StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1,
        spill_dir=None if store is not None else tmp_path, store=store)


# ============================================================= registry
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("obs_test_ops", "ops")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)                          # counters only go up
    g = reg.gauge("obs_test_level")
    g.set(3)
    g.set(1)
    assert g.value == 1
    h = reg.histogram("obs_test_lat", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    snap = h.default.snapshot()
    assert snap["count"] == 3
    assert snap["sum"] == pytest.approx(5.55)


def test_labels_are_distinct_children():
    reg = MetricsRegistry()
    fam = reg.counter("obs_test_tasks", labelnames=("tenant",))
    fam.labels("a").inc(2)
    fam.labels("b").inc(5)
    assert fam.labels("a").value == 2
    assert fam.labels("b").value == 5
    # get-or-create: same labels -> same child
    assert fam.labels("a") is fam.labels("a")


def test_registry_rejects_kind_and_label_conflicts():
    reg = MetricsRegistry()
    reg.counter("obs_test_x")
    with pytest.raises(TypeError):
        reg.gauge("obs_test_x")
    reg.counter("obs_test_y", labelnames=("tenant",))
    with pytest.raises(ValueError):
        reg.counter("obs_test_y", labelnames=("shard",))


def test_bounded_series_caps_and_stays_a_list():
    s = BoundedSeries(maxlen=8)
    for i in range(100):
        s.append(i)
    assert len(s) <= 8
    assert s[-1] == 99
    assert isinstance(s, list)
    unbounded = BoundedSeries(0)
    unbounded.extend(range(100))
    assert len(unbounded) == 100


def test_statsmap_behaves_like_the_legacy_dict():
    reg = MetricsRegistry()
    st = StatsMap(reg, "obs_test_io")
    st.register_many(["staged", "errors"])
    st.register_raw("last_error")
    st["staged"] += 3                      # legacy read-modify-write
    st.inc("staged")
    assert st["staged"] == 4
    st["last_error"] = "disk on fire"      # non-numeric -> raw slot
    assert "disk on fire" in st["last_error"]
    st.update({"new_counter": 7})          # unknown key auto-registers
    assert st["new_counter"] == 7
    assert st.get("missing", 42) == 42
    snap = st.copy()
    assert isinstance(snap, dict) and snap["staged"] == 4
    assert st == snap                      # Mapping equality both ways
    # and the registry sees the same numbers under the prefix
    assert reg.snapshot()["obs_test_io_staged"] == 4


# ===================================== satellite 1: executor stat races
def test_executor_stats_exact_under_concurrent_hammering():
    """16 threads x 50 tasks (half of them failing) through the pooled
    executor: ``executed``/``errors`` must be exact. The legacy plain
    dict ``stats["executed"] += 1`` lost increments under this load."""
    ex = TransferExecutor(sequential_io=False, max_pool_workers=8)
    threads, per_thread = 16, 50
    try:
        handles = []
        hlock = threading.Lock()

        def hammer(k):
            for i in range(per_thread):
                if (k + i) % 2:
                    h = ex.submit(0, lambda: None)
                else:
                    def boom():
                        raise IOError("injected")
                    h = ex.submit(0, boom)
                with hlock:
                    handles.append(h)
        ts = [threading.Thread(target=hammer, args=(k,))
              for k in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert ex.drain(timeout=60)
        total = threads * per_thread
        fails = sum(1 for k in range(threads)
                    for i in range(per_thread) if not (k + i) % 2)
        assert ex.stats["executed"] == total
        assert ex.stats["errors"] == fails
    finally:
        ex.shutdown()


# ==================================== satellite 2: bounded metrics lists
def test_health_transitions_bounded():
    h = StoreHealth(error_threshold=1, cooldown_ticks=1,
                    max_transitions=16)
    for _ in range(200):                   # flap hard
        h.tick(5)
        h.tick(0)
        h.tick(0)
    assert len(h.transitions) <= 16
    assert isinstance(h.transitions, BoundedSeries)


def test_engine_wires_health_transitions_cap(tmp_path):
    eng = _engine(tmp_path, breaker_error_threshold=2,
                  health_transitions_max=8)
    assert eng.health is not None
    assert eng.health.transitions.maxlen == 8
    # the metrics field aliases the breaker's log (single source of truth)
    assert eng.metrics.ladder_transitions is eng.health.transitions
    eng.close()


# =============================================================== tracing
def test_sample_rate_zero_records_nothing(tmp_path):
    eng = _engine(tmp_path)                # trace_sample_rate defaults 0
    eng.ingest(_batch(64), now=1.0)
    eng.advance_watermark(10.0, now=2.0)
    eng.poll(3.0)
    eng.close()
    assert eng.tracer.records() == []
    assert eng.tracer.stats()["spans_started"] == 0
    assert not eng.tracer.root("x").sampled     # NULL span on the path


def test_trace_ring_is_bounded():
    tr = Tracer(sample_rate=1.0, capacity=4)
    for i in range(10):
        tr.root(f"s{i}").end()
    st = tr.stats()
    assert st["ring_len"] == 4
    assert st["spans_dropped"] == 6


def test_fold_round_span_parents_watermark_advance_across_threads(
        tmp_path):
    """Satellite 3a: the fold runs on the pipeline worker thread; its
    span must still parent back to the submitting watermark-advance
    span via the EXPLICIT handoff (no thread-locals to lose it)."""
    eng = _engine(tmp_path, trace_sample_rate=1.0,
                  pipelined_execution=True)
    eng.ingest(_batch(600, hi=40.0), now=1.0)
    eng.advance_watermark(50.0, now=2.0)
    assert eng.pipeline.drain(timeout=30.0)
    eng.close()
    recs = {r["span"]: r for r in eng.tracer.records()}
    folds = [r for r in recs.values() if r["name"] == "fold_round"]
    assert folds, "no fold_round span recorded"
    for f in folds:
        parent = recs[f["parent"]]
        assert parent["name"] == "watermark_advance"
        assert f["thread"] != parent["thread"]      # crossed a thread
        assert f["trace"] == parent["trace"]
        assert f["attrs"]["windows"] >= 1
        assert any(e["name"] == "emit" for e in f["events"])


def test_retried_io_span_records_each_backoff_attempt(tmp_path):
    """Satellite 3b: a transiently failing store ``get`` retries with
    backoff; the demand-stage span must carry one ``retry`` event per
    attempt — asserted on the JSON-lines export."""
    from repro.storage import make_store
    inj = FaultInjector(seed=0)
    store = FaultyBlockStore(
        make_store("log", tmp_path / "store"), inj)
    eng = _engine(tmp_path, store=store, trace_sample_rate=1.0,
                  io_retry_limit=4, io_retry_backoff=0.001)
    eng.ingest(_batch(256), now=1.0)
    state = next(iter(eng.windows.values()))
    for blk in list(state.blocks):
        eng.io.destage_block_sync(blk)
    # push the host copies all the way to the persistent tier so the
    # demand stage must call store.get (where the injector lives)
    eng.io.spill_blocks_sync(list(state.blocks))
    inj.fail_next("get", 2)                # two failures, then success
    root = eng.tracer.root("test_demand")
    h = eng.io.request_stage(state, demand=True, parent=root)
    assert h.wait_checked(30.0)
    root.end()
    assert eng.io.drain(timeout=30)
    eng.close()
    lines = [json.loads(l)
             for l in eng.tracer.export_jsonl().splitlines()]
    stages = [r for r in lines if r["name"] == "io.demand_stage"]
    assert stages, "no demand-stage span exported"
    retries = [e for r in stages for e in r["events"]
               if e["name"] == "retry"]
    assert len(retries) == 2
    assert [e["attempt"] for e in retries] == [1, 2]
    for e in retries:
        assert e["op"] == "get"
        assert e["delay"] > 0
        assert "Transient" in e["error"]


def test_late_event_path_reconstructs_from_jsonl(tmp_path):
    """Acceptance: one sampled trace follows a late event end to end —
    ingest -> late write (I/O thread) and ingest -> watermark advance ->
    pipelined fold (worker thread) share the ingest span's trace id."""
    eng = _engine(tmp_path, trace_sample_rate=1.0,
                  pipelined_execution=True)
    eng.ingest(_batch(600, hi=40.0), now=1.0)
    eng.advance_watermark(50.0, now=2.0)
    assert eng.pipeline.drain(timeout=30.0)
    # late arrivals into already-expired windows
    eng.ingest(_batch(64, seed=3, hi=10.0), now=3.0)
    eng.poll(200.0)
    assert eng.pipeline.drain(timeout=30.0)
    assert eng.io.drain(timeout=30)
    eng.close()
    recs = [json.loads(l)
            for l in eng.tracer.export_jsonl().splitlines()]
    by_span = {r["span"]: r for r in recs}
    ingests = [r for r in recs if r["name"] == "ingest"
               and r["attrs"].get("late", 0) > 0]
    assert ingests, "no late ingest span"
    trace_id = ingests[-1]["trace"]
    family = [r for r in recs if r["trace"] == trace_id]
    names = {r["name"] for r in family}
    assert "io.late_write" in names        # persistence hop
    for r in family:
        if r["name"] == "io.late_write":
            assert by_span[r["parent"]]["name"] == "ingest"
            assert r["thread"] != by_span[r["parent"]]["thread"]


# ======================================================== observability
def test_observability_matches_legacy_surfaces(tmp_path):
    """Parity soak: the snapshot must agree with every legacy counter
    surface it replaced — same numbers, one call."""
    eng = _engine(tmp_path, breaker_error_threshold=4)
    for i in range(6):
        eng.ingest(_batch(200, seed=i, hi=40.0), now=float(i))
    eng.advance_watermark(50.0, now=7.0)
    eng.poll(8.0)
    eng.poll(60.0)
    assert eng.io.drain(timeout=30)
    snap = eng.observability()
    assert snap["engine"]["ingested"] == eng.metrics.ingested
    assert snap["engine"]["live_executions"] == \
        eng.metrics.live_executions
    assert snap["io"] == eng.io.stats.copy()
    assert snap["executor"] == eng.io.executor.stats.copy()
    assert snap["store"] == eng.store.stats.copy()
    assert snap["health"]["level"] == eng.health.level
    assert snap["trace"]["sample_rate"] == 0.0
    if eng.pool is not None:
        assert snap["pool"]["pool_slots"] == eng.pool.pool_slots
    assert "cache_size" in snap["fold"]
    eng.close()


def test_prometheus_export_format(tmp_path):
    eng = _engine(tmp_path)
    eng.ingest(_batch(64), now=1.0)
    eng.advance_watermark(10.0, now=2.0)
    eng.poll(3.0)
    text = eng.observability(export="prometheus")
    lines = text.splitlines()
    assert any(l.startswith("# TYPE aion_engine_ingested_total counter")
               for l in lines)
    assert any(l.startswith('aion_engine_ingested_total{tenant="default"}')
               for l in lines)
    # histograms expose cumulative buckets + sum/count
    assert any("aion_fold_round_seconds_bucket" in l and 'le="+Inf"' in l
               for l in lines)
    assert any(l.startswith("aion_fold_round_seconds_count") for l in lines)
    # json export parses and carries the same counter
    js = json.loads(eng.observability(export="json"))
    assert js['aion_engine_ingested{tenant="default"}'] == 64
    with pytest.raises(ValueError):
        eng.observability(export="xml")
    eng.close()


def test_pool_occupancy_via_registry_callback(tmp_path):
    eng = _engine(tmp_path)
    if eng.pool is None:
        eng.close()
        pytest.skip("no pool on this configuration")
    snap = json.loads(eng.observability(export="json"))
    assert snap["aion_pool_slots"] == eng.pool.pool_slots
    assert snap["aion_pool_free_slots"] == eng.pool.free_slots()
    eng.close()


def test_multitenant_observability_covers_everything(tmp_path):
    aion = AionConfig(block_size=32)
    mt = MultiTenantEngine(
        [TenantSpec(name="a", assigner=TumblingWindows(10.0),
                    operator=make_operator("average", 32, 1)),
         TenantSpec(name="b", assigner=TumblingWindows(10.0),
                    operator=make_operator("average", 32, 1))],
        spill_dir=tmp_path, aion=aion)
    mt.ingest("a", _batch(128, seed=1), now=1.0)
    mt.ingest("b", _batch(64, seed=2), now=1.0)
    mt.advance_watermark(20.0, now=2.0)
    mt.poll(3.0)
    snap = mt.observability()
    assert set(snap["tenants"]) == {"a", "b"}
    assert snap["tenants"]["a"]["engine"]["ingested"] == 128
    assert snap["tenants"]["b"]["engine"]["ingested"] == 64
    assert "tenant_fairness" in snap and "executor" in snap
    # per-tenant label children in ONE shared registry
    reg = snap["registry"]
    assert reg['aion_engine_ingested{tenant="a"}'] == 128
    assert reg['aion_engine_ingested{tenant="b"}'] == 64
    prom = mt.observability(export="prometheus")
    assert 'tenant="a"' in prom and 'tenant="b"' in prom
    mt.close()


def test_tracing_overhead_disabled_is_free(tmp_path):
    """With sampling off the hot path must allocate nothing: every span
    handed out is THE NullSpan singleton."""
    eng = _engine(tmp_path)
    assert eng.tracer.root("a") is NULL_SPAN
    assert eng.tracer.child(NULL_SPAN, "b") is NULL_SPAN
    assert eng.tracer.child(None, "c") is NULL_SPAN
    eng.close()
