import numpy as np
import pytest

from repro.core.buckets import WindowState
from repro.core.cleanup import LatenessHistogram, PredictiveCleanup
from repro.core.proactive import PrestageScheduler, StagingCostModel
from repro.core.windows import WindowId


def test_histogram_cdf_quantiles(rng):
    h = LatenessHistogram(min_delay=1e-3, max_delay=1e4)
    delays = rng.lognormal(0, 1, 20000) * 10
    h.update(delays)
    assert h.total == 20000
    # log-spaced histogram quantiles within a bin width of the truth
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        true = np.quantile(delays, q)
        assert 0.8 * true <= est <= 1.3 * true


def test_cleanup_bound_covers_target(rng):
    c = PredictiveCleanup(coverage=0.99, confidence=0.95, min_history=100)
    delays = rng.lognormal(0, 1, 50000) * 10
    c.observe(delays)
    bound = c.current_bound()
    actual_coverage = np.mean(delays <= bound)
    assert actual_coverage >= 0.99      # DKW band makes this conservative


def test_cleanup_conservative_until_history():
    c = PredictiveCleanup(initial_bound=1234.0, min_history=200)
    c.observe(np.array([1.0, 2.0]))
    assert c.current_bound() == 1234.0  # not enough history yet


def test_cleanup_bound_tightens_with_data(rng):
    c = PredictiveCleanup(coverage=0.9, confidence=0.95, min_history=50,
                          initial_bound=1e6)
    c.observe(rng.uniform(0, 10, 10000))
    b1 = c.current_bound()
    assert b1 < 1e6 and b1 >= np.quantile(np.linspace(0, 10, 100), 0.9) * 0.8


def test_should_purge_threshold(rng):
    c = PredictiveCleanup(coverage=0.9, confidence=0.9, min_history=10)
    c.observe(rng.uniform(0, 10, 1000))
    bound = c.current_bound()
    assert not c.should_purge(window_end=100.0, watermark=100.0 + bound / 2)
    assert c.should_purge(window_end=100.0, watermark=100.0 + bound * 2)


def test_staging_cost_model_ewma():
    m = StagingCostModel(alpha=0.5)
    m.observe(1.0, 1000)      # 1ms/event
    assert m.seconds_per_event == pytest.approx(1e-3)
    m.observe(3.0, 1000)
    assert m.seconds_per_event == pytest.approx(2e-3)
    assert m.delta_t(500) == pytest.approx(1.0)


def _observed_model(seconds_per_event: float) -> StagingCostModel:
    m = StagingCostModel()
    m.observe(seconds_per_event * 1000, 1000)
    return m


def test_prestage_scheduler_plans_delta_t_ahead():
    sched = PrestageScheduler(_observed_model(1e-3))
    st = WindowState(0, 10, width=1, block_capacity=8)
    from repro.core.events import EventBatch
    st.append_events(EventBatch(np.zeros(80, np.int32),
                                np.zeros(80), np.zeros((80, 1))), late=True)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=0.0)
    # 80 p-events * 1ms = 0.08s ahead of exec
    assert sched.due(99.0) == []
    assert sched.due(99.95) == [wid]


def test_prestage_first_lead_is_pessimistic():
    """Before ANY staging observation delta_t is +inf (paper §3.2: the
    first pre-staging starts as early as the plan allows), so an
    unobserved model must schedule staging immediately, not 0s ahead."""
    m = StagingCostModel(seconds_per_event=1e-3)     # never observed
    assert m.delta_t(80) == float("inf")
    sched = PrestageScheduler(m)
    st = WindowState(0, 10, width=1, block_capacity=8)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=0.0)
    assert sched.due(0.0) == [wid]        # stage_at clamped to now


def test_staging_cost_floor_guards_zero_event_plans():
    """observe() ignores zero-event stagings, but a window whose
    p-bucket is empty at plan time must still get a nonzero lead — the
    floor, not delta_t(0) == 0 collapsing the margin to min_margin."""
    m = _observed_model(1e-3)
    assert m.delta_t(0) == pytest.approx(m.floor_seconds)
    m.observe(0.5, 0)                     # ignored: no events
    assert m.observations == 1
    sched = PrestageScheduler(m)
    st = WindowState(0, 10, width=1, block_capacity=8)   # empty p-bucket
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=0.0)
    assert sched.due(100.0 - 2 * m.floor_seconds) == []
    assert sched.due(100.0) == [wid]


def test_prestage_punctuated_immediate():
    sched = PrestageScheduler(punctuated=True)
    st = WindowState(0, 10, width=1, block_capacity=8)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=5.0)
    assert sched.due(5.0) == [wid]        # stages as soon as late event seen


def test_prestage_punctuated_late_event_dedup():
    """Punctuated mode: repeated late events at the same instant arm one
    staging, a later instant re-arms (satellite: punctuated coverage)."""
    sched = PrestageScheduler(punctuated=True)
    st = WindowState(0, 10, width=1, block_capacity=8)
    wid = WindowId(0, 10)
    sched.on_late_event(wid, st, now=5.0)
    sched.on_late_event(wid, st, now=5.0)          # deduped
    assert sched.stats["immediate"] == 1
    assert sched.due(5.0) == [wid]
    sched.on_late_event(wid, st, now=6.0)          # re-arms after due
    assert sched.due(6.0) == [wid]


def test_upcoming_hint_rearms_after_replanning():
    """upcoming() hints each planned staging once; re-planning to an
    earlier deadline re-arms the hint (the readahead must re-issue for
    the new, earlier sweep)."""
    sched = PrestageScheduler(_observed_model(1e-3))
    st = WindowState(0, 10, width=1, block_capacity=8)
    from repro.core.events import EventBatch
    st.append_events(EventBatch(np.zeros(80, np.int32),
                                np.zeros(80), np.zeros((80, 1))), late=True)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=0.0)
    assert sched.upcoming(99.5, 1.0) == [wid]
    assert sched.upcoming(99.5, 1.0) == []         # hinted once
    sched.plan(wid, st, exec_time=50.0, now=0.0)   # earlier: supersedes
    assert sched.upcoming(49.5, 1.0) == [wid]      # re-armed
    # the superseded (later) entry is a tombstone, not a due staging
    assert sched.due(49.95) == [wid]
    assert sched.due(101.0) == []


def test_prestage_cancel_removes_plan():
    sched = PrestageScheduler(_observed_model(1e-3))
    st = WindowState(0, 10, width=1, block_capacity=8)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=0.0)
    assert sched.planned_stage_at(wid) is not None
    sched.cancel(wid)
    assert sched.planned_stage_at(wid) is None
    assert sched.due(200.0) == []
    assert sched.upcoming(0.0, 1e6) == []


def test_prestage_heap_compacts_dead_entries():
    """Superseded and cancelled plans leave tombstones in the heap; once
    they dominate, the heap is rebuilt from the live plan map instead of
    growing forever (satellite: heap-growth fix)."""
    sched = PrestageScheduler(_observed_model(1e-3))
    st = WindowState(0, 10, width=1, block_capacity=8)
    for i in range(200):
        wid = WindowId(i * 10.0, (i + 1) * 10.0)
        # each re-plan to an earlier time supersedes the previous entry
        sched.plan(wid, st, exec_time=1e6 - i, now=0.0)
        sched.plan(wid, st, exec_time=1e5 - i, now=0.0)
        sched.plan(wid, st, exec_time=1e4 - i, now=0.0)
    assert sched.stats["heap_compactions"] > 0
    # bounded: proportional to live plans, not all plans ever made
    assert len(sched._heap) < 2 * 200 + 32
    # cancel the lot: the heap compacts toward empty, due() stays clean
    for i in range(200):
        sched.cancel(WindowId(i * 10.0, (i + 1) * 10.0))
    assert sched.due(1e7) == []
    assert len(sched._heap) <= 32
