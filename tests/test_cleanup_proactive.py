import numpy as np
import pytest

from repro.core.buckets import WindowState
from repro.core.cleanup import LatenessHistogram, PredictiveCleanup
from repro.core.proactive import PrestageScheduler, StagingCostModel
from repro.core.windows import WindowId


def test_histogram_cdf_quantiles(rng):
    h = LatenessHistogram(min_delay=1e-3, max_delay=1e4)
    delays = rng.lognormal(0, 1, 20000) * 10
    h.update(delays)
    assert h.total == 20000
    # log-spaced histogram quantiles within a bin width of the truth
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        true = np.quantile(delays, q)
        assert 0.8 * true <= est <= 1.3 * true


def test_cleanup_bound_covers_target(rng):
    c = PredictiveCleanup(coverage=0.99, confidence=0.95, min_history=100)
    delays = rng.lognormal(0, 1, 50000) * 10
    c.observe(delays)
    bound = c.current_bound()
    actual_coverage = np.mean(delays <= bound)
    assert actual_coverage >= 0.99      # DKW band makes this conservative


def test_cleanup_conservative_until_history():
    c = PredictiveCleanup(initial_bound=1234.0, min_history=200)
    c.observe(np.array([1.0, 2.0]))
    assert c.current_bound() == 1234.0  # not enough history yet


def test_cleanup_bound_tightens_with_data(rng):
    c = PredictiveCleanup(coverage=0.9, confidence=0.95, min_history=50,
                          initial_bound=1e6)
    c.observe(rng.uniform(0, 10, 10000))
    b1 = c.current_bound()
    assert b1 < 1e6 and b1 >= np.quantile(np.linspace(0, 10, 100), 0.9) * 0.8


def test_should_purge_threshold(rng):
    c = PredictiveCleanup(coverage=0.9, confidence=0.9, min_history=10)
    c.observe(rng.uniform(0, 10, 1000))
    bound = c.current_bound()
    assert not c.should_purge(window_end=100.0, watermark=100.0 + bound / 2)
    assert c.should_purge(window_end=100.0, watermark=100.0 + bound * 2)


def test_staging_cost_model_ewma():
    m = StagingCostModel(alpha=0.5)
    m.observe(1.0, 1000)      # 1ms/event
    assert m.seconds_per_event == pytest.approx(1e-3)
    m.observe(3.0, 1000)
    assert m.seconds_per_event == pytest.approx(2e-3)
    assert m.delta_t(500) == pytest.approx(1.0)


def test_prestage_scheduler_plans_delta_t_ahead():
    sched = PrestageScheduler(StagingCostModel(seconds_per_event=1e-3))
    st = WindowState(0, 10, width=1, block_capacity=8)
    from repro.core.events import EventBatch
    st.append_events(EventBatch(np.zeros(80, np.int32),
                                np.zeros(80), np.zeros((80, 1))), late=True)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=0.0)
    # 80 p-events * 1ms = 0.08s ahead of exec
    assert sched.due(99.0) == []
    assert sched.due(99.95) == [wid]


def test_prestage_punctuated_immediate():
    sched = PrestageScheduler(punctuated=True)
    st = WindowState(0, 10, width=1, block_capacity=8)
    wid = WindowId(0, 10)
    sched.plan(wid, st, exec_time=100.0, now=5.0)
    assert sched.due(5.0) == [wid]        # stages as soon as late event seen
