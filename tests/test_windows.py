import numpy as np
import pytest

from repro.core.windows import (
    CountWindows, SessionWindows, SlidingWindows, TumblingWindows, WindowId,
)


def test_tumbling_assignment():
    ts = np.array([0.5, 9.9, 10.0, 19.9, 20.1])
    out = TumblingWindows(10.0).assign(ts)
    windows = {w: set(i.tolist()) for w, i in out}
    assert windows[WindowId(0.0, 10.0)] == {0, 1}
    assert windows[WindowId(10.0, 20.0)] == {2, 3}
    assert windows[WindowId(20.0, 30.0)] == {4}


def test_tumbling_covers_all_events():
    ts = np.random.default_rng(0).uniform(0, 1000, 5000)
    out = TumblingWindows(7.0).assign(ts)
    seen = np.concatenate([i for _, i in out])
    assert sorted(seen.tolist()) == list(range(5000))


def test_sliding_overlap():
    ts = np.array([12.0])
    out = SlidingWindows(10.0, 5.0).assign(ts)
    starts = sorted(w.start for w, _ in out)
    assert starts == [5.0, 10.0]          # event at 12 in [5,15) and [10,20)
    for w, idx in out:
        assert idx.tolist() == [0]


def test_sliding_event_in_size_over_slide_windows():
    ts = np.random.default_rng(1).uniform(100, 200, 300)
    out = SlidingWindows(30.0, 10.0).assign(ts)
    counts = np.zeros(300, int)
    for w, idx in out:
        for i in idx:
            assert w.start <= ts[i] < w.end
            counts[i] += 1
    assert (counts == 3).all()            # size/slide = 3 windows per event


def test_session_windows_split_on_gap():
    ts = np.array([0.0, 1.0, 2.0, 50.0, 51.0])
    out = SessionWindows(gap=10.0).assign(ts)
    assert len(out) == 2
    sizes = sorted(len(i) for _, i in out)
    assert sizes == [2, 3]


def test_count_windows_running_offset():
    cw = CountWindows(count=4)
    out1 = cw.assign(np.zeros(6))
    out2 = cw.assign(np.zeros(6))
    sizes1 = [len(i) for _, i in out1]
    sizes2 = [len(i) for _, i in out2]
    assert sizes1 == [4, 2]
    assert sizes2 == [2, 4]               # continues the partial window
