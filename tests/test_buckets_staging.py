import time
from pathlib import Path

import numpy as np
import pytest

from repro.core.buckets import Block, MemoryBudget, Tier, WindowState
from repro.core.events import EventBatch
from repro.core.staging import (
    IOScheduler, PRIO_DESTAGE, PRIO_LATE_WRITE, PRIO_STAGE,
)


def _batch(n, width=2, seed=0):
    rng = np.random.default_rng(seed)
    return EventBatch(rng.integers(0, 8, n), rng.uniform(0, 100, n),
                      rng.normal(size=(n, width)).astype(np.float32))


def test_block_append_and_view():
    blk = Block.new(capacity=10, width=2)
    b = _batch(7)
    taken = blk.append(b, 0)
    assert taken == 7 and blk.fill == 7 and not blk.full
    view = blk.as_event_batch()
    np.testing.assert_array_equal(view.keys, b.keys)


def test_window_state_appends_across_blocks():
    st = WindowState(0.0, 10.0, width=2, block_capacity=16)
    st.append_events(_batch(40), late=False)
    assert st.total_events == 40
    assert len(st.blocks) == 3
    assert [b.fill for b in st.blocks] == [16, 16, 8]
    # append fills the partial tail block first
    st.append_events(_batch(10, seed=1), late=True)
    assert [b.fill for b in st.blocks][:3] == [16, 16, 16]
    assert st.late_events == 10


def test_memory_budget_accounting():
    mb = MemoryBudget(1000)
    assert mb.try_reserve(600)
    assert not mb.try_reserve(600)
    mb.release(600)
    assert mb.try_reserve(600)
    assert mb.peak_bytes == 600


def test_stage_destage_roundtrip():
    budget = MemoryBudget(10 << 20)
    io = IOScheduler(budget, sequential_io=True)
    st = WindowState(0, 10, width=2, block_capacity=32)
    st.append_events(_batch(100), late=False)
    ref = [b.as_event_batch().values.copy() for b in st.blocks]

    io.request_stage(st).wait(5)
    assert all(b.tier == Tier.DEVICE for b in st.blocks)
    assert budget.used_bytes == sum(b.nbytes for b in st.blocks)

    io.request_destage(st).wait(5)
    io.drain()
    assert all(b.tier == Tier.HOST for b in st.blocks)
    assert budget.used_bytes == 0
    for b, r in zip(st.blocks, ref):
        np.testing.assert_array_equal(
            b.as_event_batch().values, r[:b.fill])
    io.shutdown()


def test_destage_keeps_bootstrap_blocks():
    budget = MemoryBudget(10 << 20)
    io = IOScheduler(budget)
    st = WindowState(0, 10, width=1, block_capacity=16)
    st.append_events(_batch(64, width=1), late=False)
    io.request_stage(st).wait(5)
    io.request_destage(st, keep_bootstrap=2).wait(5)
    io.drain()
    tiers = [b.tier for b in st.blocks]
    assert tiers.count(Tier.DEVICE) == 2          # rho_min bootstrap set
    assert tiers[:2] == [Tier.DEVICE, Tier.DEVICE]  # initial events kept
    io.shutdown()


def test_priority_order_stage_before_destage():
    """Staging requests queued after a destage must run first."""
    budget = MemoryBudget(100 << 20)
    io = IOScheduler(budget, chunk_blocks=1)
    order = []
    io.submit(PRIO_DESTAGE, lambda: (time.sleep(0.02), order.append("d1")))
    io.submit(PRIO_DESTAGE, lambda: order.append("d2"))
    io.submit(PRIO_LATE_WRITE, lambda: order.append("w"))
    io.submit(PRIO_STAGE, lambda: order.append("s"))
    io.drain()
    # d1 was already running; among the queued rest: stage > write > destage
    assert order.index("s") < order.index("w") < order.index("d2")
    io.shutdown()


def test_storage_spill_roundtrip(tmp_path):
    budget = MemoryBudget(10 << 20)
    io = IOScheduler(budget, spill_dir=tmp_path)
    st = WindowState(0, 10, width=3, block_capacity=32)
    st.append_events(_batch(32, width=3), late=False)
    blk = st.blocks[0]
    ref = blk.as_event_batch().values.copy()
    io.spill_block_sync(blk)
    assert blk.tier == Tier.STORAGE and blk.host_data is None
    assert blk.storage_path is not None and blk.storage_path.exists()
    np.testing.assert_array_equal(blk.as_event_batch().values, ref)
    io.shutdown()


def test_drop_removes_storage_file(tmp_path):
    budget = MemoryBudget(10 << 20)
    io = IOScheduler(budget, spill_dir=tmp_path)
    st = WindowState(0, 10, width=1, block_capacity=16)
    st.append_events(_batch(16, width=1), late=False)
    blk = st.blocks[0]
    io.spill_block_sync(blk)
    path = blk.storage_path
    freed, device_bytes = st.drop_all()
    assert freed > 0 and not path.exists()
    assert device_bytes == 0          # block was in storage, not on device
    io.shutdown()
