import numpy as np

from repro.core.time import PeriodicWatermarkGenerator, WatermarkTracker


def test_tracker_monotonic():
    t = WatermarkTracker()
    assert t.advance(10.0)
    assert not t.advance(5.0)             # never regresses
    assert t.watermark == 10.0


def test_lateness_classification():
    t = WatermarkTracker()
    t.advance(100.0)
    ts = np.array([50.0, 99.9, 100.0, 150.0])
    assert t.is_late(ts).tolist() == [True, True, False, False]
    np.testing.assert_allclose(t.lateness_of(ts)[:2], [50.0, 0.1])


def test_periodic_emission():
    g = PeriodicWatermarkGenerator(period=5.0, slack=1.0)
    g.observe(np.array([10.0, 20.0]))
    assert g.maybe_emit(0.0) == 19.0      # max_ts - slack
    assert g.maybe_emit(2.0) is None      # period not elapsed
    g.observe(np.array([30.0]))
    assert g.maybe_emit(5.0) == 29.0
