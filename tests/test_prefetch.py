"""Learned prefetch subsystem (``repro.prefetch`` + the storage/staging
seams it drives): lateness model CDFs, segment-granular sweep planning,
``LogBlockStore`` segment queries / sweeps / coalescing, WAL commit
coalescing across I/O tasks, and the fixed-vs-learned engine
integration with readahead hit accounting.
"""
import threading

import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import StreamEngine, TumblingWindows
from repro.core.buckets import Block, MemoryBudget, Tier, WindowState
from repro.core.engine import PeriodicWatermarkGenerator
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.staging import (
    IOScheduler, PRIO_DEMAND_STAGE, PRIO_DESTAGE, PRIO_LATE_WRITE,
    PRIO_READAHEAD, PRIO_STAGE,
)
from repro.core.windows import WindowId
from repro.prefetch import (
    LatenessModel, LearnedCostModel, SegmentPrefetchPlanner,
    LearnedPrestageScheduler,
)
from repro.storage import LogBlockStore

W1 = (0.0, 10.0)
W2 = (10.0, 20.0)


def _arrays(fill, cap=64, width=1, seed=0):
    rng = np.random.default_rng(seed)
    a = {
        "keys": np.zeros((cap,), np.int32),
        "timestamps": np.zeros((cap,), np.float64),
        "values": np.zeros((cap, width), np.float32),
    }
    a["keys"][:fill] = rng.integers(0, 99, fill)
    a["timestamps"][:fill] = rng.uniform(0.0, 100.0, fill)
    a["values"][:fill] = rng.normal(size=(fill, width))
    return a


# --------------------------------------------------------------- model
def test_lateness_model_survival_declines_with_age(rng):
    m = LatenessModel(num_classes=4)
    wid = WindowId(0.0, 10.0)
    m.observe(wid, rng.integers(0, 100, 500),
              rng.lognormal(0.0, 1.0, 500) * 5.0)
    p_young = m.reexec_probability(wid, 0.1)
    p_mid = m.reexec_probability(wid, 5.0)
    p_old = m.reexec_probability(wid, 1e4)
    assert p_young > p_mid > p_old
    assert p_old == pytest.approx(0.0, abs=1e-6)


def test_lateness_model_pessimistic_without_samples():
    m = LatenessModel()
    assert m.reexec_probability(WindowId(0.0, 10.0), 3.0) == 1.0


def test_lateness_model_separates_key_classes(rng):
    """Keys hash to classes with distinct lateness behaviour: a window
    fed only short-delay keys stops being prefetch-worthy much sooner
    than one fed only long-delay keys."""
    m = LatenessModel(num_classes=2, refit_every=1)
    short_keys = np.zeros(400, np.int64)       # class 0
    long_keys = np.ones(400, np.int64)         # class 1
    m.observe(None, short_keys, rng.uniform(0.01, 1.0, 400))
    m.observe(None, long_keys, rng.uniform(50.0, 100.0, 400))
    w_short, w_long = WindowId(0.0, 10.0), WindowId(10.0, 20.0)
    m.observe(w_short, short_keys[:8], rng.uniform(0.01, 1.0, 8))
    m.observe(w_long, long_keys[:8], rng.uniform(50.0, 100.0, 8))
    age = 5.0         # beyond every short delay, before every long one
    assert m.reexec_probability(w_short, age) < 0.1
    assert m.reexec_probability(w_long, age) > 0.9


def test_lateness_model_forget_and_bounds(rng):
    m = LatenessModel(num_classes=2, max_windows=8)
    for i in range(32):
        m.observe(WindowId(i * 10.0, (i + 1) * 10.0),
                  rng.integers(0, 9, 4), rng.uniform(0.1, 2.0, 4))
    assert len(m._window_classes) <= 8         # LRU-bounded
    wid = WindowId(310.0, 320.0)
    m.forget(wid)
    assert wid not in m._window_classes


def test_learned_cost_model_keeps_fixed_contract():
    """Drop-in for StagingCostModel: pessimistic +inf before the first
    observation, EWMA with a floor after — plus the bandwidth view."""
    c = LearnedCostModel(prior_bandwidth_bytes_per_s=1e6)
    assert c.delta_t(100) == float("inf")
    c.observe(1.0, 1000)
    assert c.delta_t(500) == pytest.approx(0.5)
    assert c.delta_t(0) == pytest.approx(c.floor_seconds)
    assert c.delta_t_bytes(2_000_000) == pytest.approx(2.0)
    c.observe_bytes(1.0, 4_000_000)            # measured sweep: 4 MB/s
    assert c.bandwidth_bytes_per_s == pytest.approx(4e6)
    assert c.delta_t_bytes(2_000_000) == pytest.approx(0.5)


# -------------------------------------------------------------- planner
def _store_with_blocks(tmp_path, n_windows=3, blocks_per_window=4):
    st = LogBlockStore(tmp_path, segment_bytes=1 << 20)
    keys_by_window = {}
    bid = 0
    for r in range(blocks_per_window):         # interleave: scattered
        for w in range(n_windows):
            wk = (w * 10.0, (w + 1) * 10.0)
            st.put(wk, bid, _arrays(48, seed=bid), 48)
            keys_by_window.setdefault(wk, []).append((wk, bid))
            bid += 1
    st.commit()
    return st, keys_by_window


def test_planner_merges_windows_into_segment_sweeps(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    cost = LearnedCostModel()
    planner = SegmentPrefetchPlanner(cost, budget_bytes=64 << 20)
    wants = [(WindowId(*wk), 100.0 + i, keys, 1.0)
             for i, (wk, keys) in enumerate(by_w.items())]
    res = planner.plan(st, wants, now=99.9)
    # one segment -> ONE merged sweep covering all three windows
    assert len(res.sweeps) == 1
    sw = res.sweeps[0]
    assert len(sw.windows) == 3
    assert sw.deadline == 100.0                # earliest contributor
    assert sw.span_bytes >= sw.record_bytes > 0
    assert not res.deferred_windows
    st.close()


def test_planner_defers_far_out_sweeps_over_budget(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    cost = LearnedCostModel(prior_bandwidth_bytes_per_s=1e12)
    planner = SegmentPrefetchPlanner(cost, budget_bytes=1)
    # huge slack (deadline far out) + tiny budget -> deferred
    wants = [(WindowId(*wk), 1e6, keys, 1.0)
             for wk, keys in by_w.items()]
    res = planner.plan(st, wants, now=0.0)
    assert not res.sweeps
    assert res.deferred_windows == {WindowId(*wk) for wk in by_w}
    # imminent deadline (slack below safety x estimated read time):
    # the first sweep issues regardless of the byte budget
    slow = LearnedCostModel(prior_bandwidth_bytes_per_s=1e3)
    planner2 = SegmentPrefetchPlanner(slow, budget_bytes=1)
    wants = [(WindowId(*wk), 0.5, keys, 1.0) for wk, keys in by_w.items()]
    res = planner2.plan(st, wants, now=0.0)
    assert len(res.sweeps) == 1
    st.close()


def test_planner_picks_scattered_hot_windows_for_coalescing(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    cost = LearnedCostModel()
    planner = SegmentPrefetchPlanner(cost, coalesce_probability=0.5)
    wk_hot = (0.0, 10.0)
    wants = [(WindowId(*wk), 100.0, keys,
              0.9 if wk == wk_hot else 0.1)    # only one window is hot
             for wk, keys in by_w.items()]
    res = planner.plan(st, wants, now=99.0)
    assert res.coalesce == [WindowId(*wk_hot)]
    # coalesce-once: a second plan round does not re-request
    res2 = planner.plan(st, wants, now=99.0)
    assert res2.coalesce == []
    st.close()


# ------------------------------------------------- logstore: segments
def test_segments_for_is_index_only(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    read_before = st.stats["bytes_read"]
    placement = st.segments_for([k for ks in by_w.values() for k in ks])
    assert st.stats["bytes_read"] == read_before       # no payload reads
    assert sum(len(v) for v in placement.values()) == 12
    for items in placement.values():
        offs = [off for _, off, _ in items]
        assert offs == sorted(offs)
        assert all(length > 0 for _, _, length in items)
    # unknown keys are simply absent
    assert st.segments_for([((99.0, 100.0), 7)]) == {}
    st.close()


def test_readahead_segments_sweeps_and_counts_hits(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    all_keys = [k for ks in by_w.values() for k in ks]
    placement = st.segments_for(all_keys)
    for sid, items in placement.items():
        cached = st.readahead_segments(sid, [k for k, _, _ in items])
        assert cached == len(items)
    assert st.stats["segment_sweeps"] == len(placement)
    assert st.stats["sweep_bytes_read"] > 0
    for wk, bid in all_keys:                   # all demand reads hit
        assert st.get(wk, bid) is not None
    assert st.stats["readahead_hits"] == len(all_keys)
    assert st.stats["readahead_misses"] == 0
    st.close()


def test_readahead_segments_skips_stale_plan_entries(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    keys = by_w[(0.0, 10.0)]
    placement = st.segments_for(keys)
    (sid, items), = placement.items()
    # supersede one record after planning: its live copy moves
    wk, bid = keys[0]
    st.put(wk, bid, _arrays(48, seed=77), 48)
    st.commit()
    cached = st.readahead_segments(sid, [k for k, _, _ in items])
    assert cached == len(items)     # current index entries, incl. moved
    got = st.get(wk, bid)
    np.testing.assert_array_equal(got["keys"][:48],
                                  _arrays(48, seed=77)["keys"][:48])
    st.close()


def test_window_scatter_and_coalesce(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    wk = (0.0, 10.0)
    records, segs, span, rec_bytes = st.window_scatter(wk)
    assert records == 4 and segs == 1
    assert span > 1.5 * rec_bytes              # interleaved: scattered
    assert st.coalesce_windows([wk]) == 1
    records2, _segs2, span2, rec_bytes2 = st.window_scatter(wk)
    assert records2 == records and rec_bytes2 == rec_bytes
    assert span2 <= 1.5 * rec_bytes2           # now dense
    # idempotent: a dense window is never rewritten again
    assert st.coalesce_windows([wk]) == 0
    assert st.stats["coalesced_windows"] == 1
    # data intact after the rewrite
    for (w, bid) in by_w[wk]:
        got = st.get(w, bid)
        np.testing.assert_array_equal(
            got["keys"][:48], _arrays(48, seed=bid)["keys"][:48])
    st.close()


def test_coalesce_survives_recovery(tmp_path):
    st, by_w = _store_with_blocks(tmp_path)
    wk = (0.0, 10.0)
    assert st.coalesce_windows([wk]) == 1
    st.close()
    st2 = LogBlockStore(tmp_path, segment_bytes=1 << 20)
    for (w, bid) in by_w[wk]:
        got = st2.get(w, bid)
        assert got is not None
        np.testing.assert_array_equal(
            got["keys"][:48], _arrays(48, seed=bid)["keys"][:48])
    # the rewrite's dead copies are reclaimable, not load-bearing
    st2.delete(*by_w[wk][0])
    st2.commit()
    st2.compact_if_needed(1.0)
    assert st2.get(*by_w[wk][0]) is None
    assert st2.get(*by_w[wk][1]) is not None
    st2.close()


def test_npz_store_reports_no_segments(tmp_path):
    from repro.storage import NpzBlockStore
    s = NpzBlockStore(tmp_path)
    s.put(W1, 0, _arrays(8), 8)
    assert s.segments_for([(W1, 0)]) == {}
    assert s.readahead_segments(0, [(W1, 0)]) == 0
    assert s.window_scatter(W1) == (0, 0, 0, 0)
    assert s.coalesce_windows([W1]) == 0


# ------------------------------------------------ staging: new requests
def _host_block(cap=32, width=1, seed=0):
    st = WindowState(0, 10, width=width, block_capacity=cap)
    rng = np.random.default_rng(seed)
    st.append_events(EventBatch(
        rng.integers(0, 99, cap).astype(np.int32),
        rng.uniform(0, 10, cap), rng.normal(size=(cap, width)).astype(
            np.float32)), late=False)
    return st


def test_priority_lattice_readahead_between_stage_and_late_write():
    assert PRIO_DEMAND_STAGE < PRIO_STAGE < PRIO_READAHEAD \
        < PRIO_LATE_WRITE < PRIO_DESTAGE


def test_request_segment_readahead_feeds_bandwidth_model(tmp_path):
    store = LogBlockStore(tmp_path / "s", segment_bytes=1 << 20)
    io = IOScheduler(MemoryBudget(1 << 20), store=store)
    st = _host_block()
    blk = st.blocks[0]
    io.spill_block_sync(blk)
    observed = []
    placement = store.segments_for([(blk.window_key, blk.block_id)])
    (sid, items), = placement.items()
    h = io.request_segment_readahead(
        sid, [k for k, _, _ in items],
        on_swept=lambda sec, nb: observed.append((sec, nb)))
    assert h.wait(5.0)
    assert observed and observed[0][1] > 0
    assert store.stats["segment_sweeps"] == 1
    io.shutdown()


def test_request_coalesce_runs_in_background(tmp_path):
    store = LogBlockStore(tmp_path / "s", segment_bytes=1 << 20)
    io = IOScheduler(MemoryBudget(1 << 20), store=store)
    # two scattered windows (interleaved appends)
    for r in range(3):
        for w, wk in enumerate((W1, W2)):
            store.put(wk, r * 2 + w, _arrays(32, seed=r), 32)
    store.commit()
    h = io.request_coalesce([W1, W2])
    assert h.wait(5.0)
    assert io.stats.get("coalesced_windows") == 2
    _, segs, span, rec = store.window_scatter(W1)
    assert span <= 1.5 * rec
    io.shutdown()


# ----------------------------------------------- WAL commit coalescing
def test_wal_coalesced_spills_share_one_commit(tmp_path):
    store = LogBlockStore(tmp_path / "s", segment_bytes=1 << 20)
    io = IOScheduler(MemoryBudget(1 << 20), store=store,
                     host_budget_bytes=1, wal_coalesce=True)
    assert io._coalescer is not None
    states = [_host_block(seed=i) for i in range(6)]
    commits_before = store.stats["commits"]

    def destage_all():
        for st in states:
            blk = st.blocks[0]
            io._account_host(blk)
        io._maybe_spill()
    h = io.submit(PRIO_DESTAGE, destage_all)
    assert h.wait(5.0)
    assert io.drain(10.0)
    # every block spilled...
    for st in states:
        assert st.blocks[0].tier == Tier.STORAGE
        assert st.blocks[0].host_data is None
    # ...under coalesced commits: fewer commits than spill batches
    assert io._coalescer.stats["coalesced_commits"] >= 1
    assert io._coalescer.stats["joined_tasks"] >= \
        io._coalescer.stats["coalesced_commits"]
    assert store.stats["commits"] - commits_before \
        <= io._coalescer.stats["joined_tasks"]
    assert io._pending_spill_bytes == 0
    io.shutdown()


def test_wal_coalesce_commit_failure_keeps_host_copies(tmp_path):
    store = LogBlockStore(tmp_path / "s", segment_bytes=1 << 20)
    io = IOScheduler(MemoryBudget(1 << 20), store=store,
                     host_budget_bytes=1, wal_coalesce=True)
    st = _host_block(seed=3)
    blk = st.blocks[0]
    boom = RuntimeError("commit blew up")
    orig_commit = store.commit

    def failing_commit():
        raise boom
    store.commit = failing_commit
    io._account_host(blk)
    h = io.submit(PRIO_DESTAGE, io._maybe_spill)
    assert h.wait(5.0)
    assert io.drain(10.0)
    # durability was NOT achieved: the host copy must survive and the
    # deferred accounting must unwind
    assert blk.tier == Tier.HOST and blk.host_data is not None
    assert io._pending_spill_bytes == 0
    assert io.executor.stats["errors"] >= 1
    store.commit = orig_commit
    io.shutdown()


def test_direct_spill_calls_stay_synchronous(tmp_path):
    """Only the budget-pressure path coalesces; spill_block_sync keeps
    its synchronous STORAGE-tier-on-return contract."""
    store = LogBlockStore(tmp_path / "s", segment_bytes=1 << 20)
    io = IOScheduler(MemoryBudget(1 << 20), store=store,
                     wal_coalesce=True)
    st = _host_block(seed=4)
    blk = st.blocks[0]
    io.spill_block_sync(blk)
    assert blk.tier == Tier.STORAGE and blk.host_data is None
    io.shutdown()


# --------------------------------------------------- engine integration
def _lnorm_engine_run(backend, spill_dir, *, steps=240, seed=7):
    aion = AionConfig(block_size=64, batched_execution=True,
                      prefetch_backend=backend,
                      store_segment_bytes=64 << 10)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion,
        watermark_gen=PeriodicWatermarkGenerator(period=1.0),
        device_budget_bytes=1 << 19, host_budget_bytes=1 << 15,
        spill_dir=spill_dir)
    rng = np.random.default_rng(seed)
    for step in range(steps):
        now = step * 0.25
        n = 60
        late = rng.random(n) < 0.4
        ts = np.full(n, now) - late * rng.lognormal(0, 1, n) * 8.0
        eng.ingest(EventBatch(
            rng.integers(0, 64, n).astype(np.int32),
            np.maximum(ts, 0.0),
            np.ones((n, 1), np.float32)), now)
        eng.poll(now)
    eng.close()
    return eng


def test_learned_backend_constructs_and_prefetches(tmp_path):
    eng = _lnorm_engine_run("learned", tmp_path / "learned")
    assert isinstance(eng.prestage, LearnedPrestageScheduler)
    s = eng.store.stats
    assert s["segment_sweeps"] > 0             # sweeps actually issued
    hits, misses = s["readahead_hits"], s["readahead_misses"]
    assert hits > 0
    assert hits / max(hits + misses, 1) > 0.9  # acceptance: >90% hit rate
    assert eng.prestage.model.samples > 0      # lateness samples flowed


def test_fixed_backend_unchanged_default(tmp_path):
    eng = _lnorm_engine_run("fixed", tmp_path / "fixed")
    from repro.core.proactive import PrestageScheduler
    assert type(eng.prestage) is PrestageScheduler
    assert eng.store.stats["segment_sweeps"] == 0


def test_fixed_and_learned_agree_on_results(tmp_path):
    """Differential: prefetch backends must not change WHAT is computed,
    only how its I/O is scheduled."""
    e_fixed = _lnorm_engine_run("fixed", tmp_path / "f", steps=160)
    e_learned = _lnorm_engine_run("learned", tmp_path / "l", steps=160)
    assert set(e_fixed.results) == set(e_learned.results)
    for wid, res in e_fixed.results.items():
        np.testing.assert_allclose(
            np.asarray(res, np.float64),
            np.asarray(e_learned.results[wid], np.float64),
            rtol=1e-5, atol=1e-6)
