"""Failure injection for the I/O executor (ISSUE 6 satellites 1/2/5).

The old executor swallowed task exceptions (a bare ``except`` around
``task.fn()`` with only a counter bump) and ``drain()`` returned ``None``
on timeout. These tests pin the new contract: errors land on the task's
``TaskHandle`` and re-raise for demand waiters, ``drain`` reports
timeouts as ``False``, and the close/checkpoint paths refuse to proceed
past a failed drain.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import (
    EventBatch, StreamEngine, TumblingWindows, make_operator,
)
from repro.core.buckets import MemoryBudget
from repro.core.staging import (
    IOScheduler, PRIO_DEMAND_STAGE, PRIO_STAGE, StagingError, TaskHandle,
    TransferExecutor,
)


def _batch(n, width=1, seed=0, lo=0.0, hi=10.0):
    rng = np.random.default_rng(seed)
    return EventBatch(rng.integers(0, 8, n), rng.uniform(lo, hi, n),
                      rng.normal(size=(n, width)).astype(np.float32))


# ------------------------------------------------------------ TaskHandle
def test_task_handle_check_raises_staging_error():
    h = TaskHandle()
    h.error = ValueError("disk on fire")
    h.set()
    with pytest.raises(StagingError, match="disk on fire"):
        h.check()
    with pytest.raises(StagingError):
        h.wait_checked(1.0)


def test_task_handle_clean_completion():
    h = TaskHandle()
    h.set()
    h.check()                              # no error -> no raise
    assert h.wait_checked(1.0) is True


# ------------------------------------------- executor error surfacing
def test_executor_records_task_exception_sequential():
    ex = TransferExecutor(sequential_io=True)
    try:
        def boom():
            raise IOError("short read")
        h = ex.submit(0, boom)
        assert h.wait(5.0)
        assert isinstance(h.error, IOError)
        with pytest.raises(StagingError, match="short read"):
            h.check()
        assert ex.stats["errors"] == 1
        assert "short read" in ex.stats["last_error"]
        # the worker thread survived the exception
        h2 = ex.submit(0, lambda: None)
        assert h2.wait_checked(5.0)
        assert ex.stats["executed"] == 2
    finally:
        ex.shutdown()


def test_executor_records_task_exception_pooled():
    # the no-sqntl-io ablation path must surface failures the same way
    ex = TransferExecutor(sequential_io=False, max_pool_workers=2)
    try:
        def boom():
            raise RuntimeError("pool boom")
        h = ex.submit(0, boom)
        assert h.wait(5.0)
        with pytest.raises(StagingError, match="pool boom"):
            h.check()
        assert ex.stats["errors"] == 1
    finally:
        ex.shutdown()


def test_executor_on_error_callback_feeds_scheduler_stats():
    budget = MemoryBudget(1 << 20)
    io = IOScheduler(budget)
    try:
        def boom():
            raise OSError("stage failed")
        h = io.submit(PRIO_STAGE, boom)
        assert h.wait(5.0)
        assert io.stats["errors"] == 1
        assert "stage failed" in io.last_error
        assert "stage failed" in io.executor.stats["last_error"]
    finally:
        io.shutdown()


def test_drain_returns_false_on_timeout_and_true_after():
    ex = TransferExecutor(sequential_io=True)
    try:
        release = threading.Event()
        ex.submit(0, lambda: release.wait(10.0))
        time.sleep(0.05)                   # let the worker pick it up
        assert ex.drain(timeout=0.2) is False
        release.set()
        assert ex.drain(timeout=5.0) is True
    finally:
        release.set()
        ex.shutdown()


def test_ioscheduler_drain_propagates_bool():
    budget = MemoryBudget(1 << 20)
    io = IOScheduler(budget)
    try:
        release = threading.Event()
        io.submit(PRIO_STAGE, lambda: release.wait(10.0))
        time.sleep(0.05)
        assert io.drain(timeout=0.2) is False
        release.set()
        assert io.drain(timeout=5.0) is True
    finally:
        release.set()
        io.shutdown()


# ------------------------------------------------- engine-level contract
def _small_engine(tmp_path, **aion_kw):
    aion = AionConfig(block_size=32, **aion_kw)
    return StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1, spill_dir=tmp_path)


def test_engine_close_raises_on_failed_drain(tmp_path):
    eng = _small_engine(tmp_path)
    eng.ingest(_batch(64), now=1.0)
    release = threading.Event()
    eng.io.submit(PRIO_STAGE, lambda: release.wait(10.0))
    time.sleep(0.05)
    with pytest.raises(RuntimeError, match="drain"):
        eng.close(drain_timeout=0.2)
    release.set()
    eng.close()                            # second attempt drains cleanly


def test_checkpoint_manifest_raises_on_failed_drain(tmp_path):
    eng = _small_engine(tmp_path)
    eng.ingest(_batch(64), now=1.0)
    release = threading.Event()
    eng.io.submit(PRIO_STAGE, lambda: release.wait(10.0))
    time.sleep(0.05)
    with pytest.raises(RuntimeError, match="drain"):
        eng.checkpoint_state(include_stored_data=False, drain_timeout=0.2)
    release.set()
    eng.close()


def test_demand_stage_failure_reaches_execute_window(tmp_path):
    """A stage task that raises mid-batch must abort the fold loudly,
    not emit a result computed from missing rows."""
    eng = _small_engine(tmp_path)
    eng.ingest(_batch(200, seed=3), now=1.0)
    wid, st = next(iter(eng.windows.items()))
    # destage everything so execution needs a real demand stage
    for blk in list(st.blocks):
        eng.io.destage_block_sync(blk)
    assert st.p_blocks()

    def failing_stage(block, *a, **kw):
        raise IOError("injected stage failure")
    eng.io.stage_block_sync = failing_stage
    with pytest.raises((StagingError, IOError)):
        eng.execute_window(wid, now=2.0, late=False)
    assert eng.io.stats["errors"] >= 1
    assert "injected stage failure" in eng.io.last_error
    del eng.io.stage_block_sync            # restore so close() can drain
    eng.close()


# ---------------------------------------------------- WRR fairness order
def test_weighted_round_robin_within_priority_class():
    ex = TransferExecutor(sequential_io=True)
    try:
        ex.set_weight("A", 2)
        ex.set_weight("B", 1)
        order = []
        gate = threading.Event()
        # hold the worker on a low-priority task while we enqueue the
        # contended class, so pops happen from a fully-loaded queue
        ex.submit(0, lambda: gate.wait(10.0))
        time.sleep(0.05)
        for i in range(4):
            ex.submit(5, lambda t="A": order.append(t), tenant="A")
            ex.submit(5, lambda t="B": order.append(t), tenant="B")
        gate.set()
        assert ex.drain(timeout=5.0)
        # weight-2 tenant gets two consecutive slots per cycle
        assert order[:6] == ["A", "A", "B", "A", "A", "B"]
        assert ex.stats["tenant_executed"]["A"] == 4
        assert ex.stats["tenant_executed"]["B"] == 4
    finally:
        ex.shutdown()


def test_priority_classes_still_dominate_fairness():
    """Cross-class the lattice rules: any lower-numbered class runs
    before WRR even looks at the higher-numbered one."""
    ex = TransferExecutor(sequential_io=True)
    try:
        order = []
        gate = threading.Event()
        ex.submit(0, lambda: gate.wait(10.0))
        time.sleep(0.05)
        ex.submit(5, lambda: order.append("low"), tenant="A")
        ex.submit(PRIO_DEMAND_STAGE,
                  lambda: order.append("demand"), tenant="B")
        gate.set()
        assert ex.drain(timeout=5.0)
        assert order == ["demand", "low"]
    finally:
        ex.shutdown()
