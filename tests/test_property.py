"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); the rest of tier-1 runs without it")
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs.base import AionConfig
from repro.core import (
    PeriodicWatermarkGenerator, StreamEngine, TumblingWindows,
)
from repro.core.buckets import WindowState
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.staleness import (
    deltaev_times, max_staleness_of, minimize_max_staleness,
)
from repro.core.windows import SlidingWindows, TumblingWindows as TW


@given(st.lists(st.floats(0, 1e4, allow_nan=False), min_size=1,
                max_size=300),
       st.floats(0.5, 50))
@settings(max_examples=50, deadline=None)
def test_tumbling_partition_property(ts, size):
    """Every event lands in exactly one tumbling window that contains it."""
    ts = np.asarray(ts)
    out = TW(size).assign(ts)
    counts = np.zeros(len(ts), int)
    for w, idx in out:
        for i in idx:
            assert w.start <= ts[i] < w.end + 1e-9
            counts[i] += 1
    assert (counts == 1).all()


@given(st.integers(1, 400), st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_bucket_block_accounting(n, cap, width):
    """total_events equals the sum of block fills; no block over capacity."""
    st_ = WindowState(0, 10, width=width, block_capacity=cap)
    rng = np.random.default_rng(0)
    st_.append_events(EventBatch(
        rng.integers(0, 4, n), rng.uniform(0, 10, n),
        rng.normal(size=(n, width)).astype(np.float32)), late=False)
    assert st_.total_events == n
    assert sum(b.fill for b in st_.blocks) == n
    assert all(b.fill <= b.capacity for b in st_.blocks)


@given(st.integers(0, 2**31 - 1), st.integers(2, 40))
@settings(max_examples=30, deadline=None)
def test_engine_result_invariant_to_arrival_order(seed, nlate):
    """The amended window result equals the mean over ALL events, no
    matter how they are split between on-time and late arrivals."""
    rng = np.random.default_rng(seed)
    n = 60
    vals = rng.normal(size=(n, 1)).astype(np.float32)
    ts = rng.uniform(0, 10, n)
    split = n - nlate

    from repro.core.triggers import DeltaTTrigger
    aion = AionConfig(block_size=16)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", 16, 1),
        aion=aion, value_width=1,
        device_budget_bytes=8 << 20,
        trigger=DeltaTTrigger(executions=1),
    )
    eng.ingest(EventBatch(np.zeros(split, np.int32), ts[:split],
                          vals[:split]), now=0.0)
    eng.advance_watermark(10.0, now=10.0)
    if nlate:
        eng.ingest(EventBatch(np.zeros(n - split, np.int32), ts[split:],
                              vals[split:]), now=11.0)
        for t in np.linspace(11, 11 + 2 * eng.cleanup.current_bound(), 20):
            eng.poll(t)
    from repro.core.windows import WindowId
    res = eng.results[WindowId(0.0, 10.0)]
    assert res == pytest.approx(float(np.mean(vals)), rel=1e-4, abs=1e-5)
    eng.close()


@given(st.integers(0, 2**31 - 1), st.integers(2, 12))
@settings(max_examples=20, deadline=None)
def test_aion_trigger_never_worse_than_deltaev(seed, k):
    """The optimizer is seeded at the deltaev placement, so it can only
    improve on it — for any lateness distribution."""
    rng = np.random.default_rng(seed)
    T = 50.0
    mix = rng.random()
    delays = np.concatenate([
        rng.lognormal(0, 1, 500) * (T / 20),
        rng.uniform(0, T, int(500 * mix) + 1),
    ])
    delays = np.clip(delays, 0, T)
    aion = minimize_max_staleness(delays, T, k).max_staleness
    de = max_staleness_of(deltaev_times(delays, T, k), delays, T)
    assert aion <= de + 1e-7


# device counts available in this process: {1} on the tier-1 single-CPU
# container, {1, 2, 4, 8} under `make verify-multidevice`
_SHARD_DEVICE_COUNTS = [d for d in (1, 2, 4, 8)
                        if d <= len(jax.devices())]


@pytest.mark.parametrize("num_devices", _SHARD_DEVICE_COUNTS)
@given(st.data())
@settings(max_examples=15, deadline=None)
def test_sharded_batched_fold_matches_unsharded_and_ref(num_devices, data):
    """segment_aggregate_batched parity: sharded == unsharded == ref for
    ragged slot_ids, duplicate slots, and all-invalid rows, on any
    shard-major row layout the executor's placement can produce."""
    from repro.distributed.sharding import make_slot_mesh
    from repro.kernels import segment_aggregate_batched
    from repro.kernels import ref as R

    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    rows_per = data.draw(st.integers(1, 6), label="rows_per_shard")
    slots_per = data.draw(st.integers(1, 4), label="slots_per_shard")
    n = data.draw(st.sampled_from([8, 24, 48]), label="events_per_block")
    w = data.draw(st.integers(1, 3), label="width")
    s = data.draw(st.integers(1, 6), label="num_segments")
    all_invalid = data.draw(st.booleans(), label="all_invalid")
    rng = np.random.default_rng(seed)
    b = num_devices * rows_per
    num_slots = num_devices * slots_per
    # shard-major layout: rows of shard d draw (duplicate, ragged) slots
    # from d's own contiguous range — exactly what the executor's
    # round-robin placement + pack_rows_shard_major produce
    slots = np.concatenate([
        rng.integers(d * slots_per, (d + 1) * slots_per, rows_per)
        for d in range(num_devices)]).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(b, n, w)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, s, (b, n)), jnp.int32)
    fills = rng.integers(0, n + 1, b)
    if all_invalid:
        fills[:] = 0
    valid = jnp.asarray(np.arange(n)[None, :] < fills[:, None])
    kw = dict(valid=valid, slot_ids=jnp.asarray(slots),
              num_slots=num_slots)
    mesh = make_slot_mesh(num_devices)
    out_s = segment_aggregate_batched(vals, ids, s, mesh=mesh, **kw)
    out_u = segment_aggregate_batched(vals, ids, s, **kw)
    ref = R.ref_segment_aggregate_batched(vals, ids, s, **kw)
    for k in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(out_s[k], out_u[k], rtol=1e-6,
                                   atol=1e-6, err_msg=f"{k} vs unsharded")
        a, bb = np.asarray(out_s[k]), np.asarray(ref[k])
        m = np.isfinite(bb)
        assert np.array_equal(np.isfinite(a), m), k
        np.testing.assert_allclose(a[m], bb[m], rtol=1e-5, atol=1e-5,
                                   err_msg=f"{k} vs ref")


@given(st.integers(1, 1000))
@settings(max_examples=20, deadline=None)
def test_key_partition_is_a_partition(n):
    rng = np.random.default_rng(n)
    b = EventBatch(rng.integers(0, 1000, n), rng.uniform(0, 10, n),
                   rng.normal(size=(n, 1)).astype(np.float32))
    shards = b.partition_by_shard(8)
    assert sum(len(s) for s in shards) == n
    # same key always goes to the same shard
    for s in shards:
        for other in shards:
            if s is not other and len(s) and len(other):
                assert not (set(s.keys.tolist()) & set(other.keys.tolist()))
