"""Sharding-profile unit tests: divisibility-driven TP decisions for every
(arch x shape x mesh) cell, without touching device state."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_SHAPES, ARCHS, applicable_shapes, skipped_cells
from repro.configs.base import MULTI_POD_MESH, SINGLE_POD_MESH
from repro.distributed import sharding as shd


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [SINGLE_POD_MESH, MULTI_POD_MESH],
                         ids=["single", "multi"])
def test_profiles_well_formed(arch, mesh):
    cfg = ARCHS[arch]
    for shape in applicable_shapes(cfg):
        prof = shd.sharding_profile(cfg, mesh, shape.global_batch,
                                    shape.seq_len, shape.kind)
        model_axis = dict(zip(mesh.axes, mesh.shape)).get("model", 1)
        if prof.attn_tp:
            assert cfg.num_heads % model_axis == 0
            stored = cfg.num_kv_heads * prof.kv_repeat
            if shape.kind != "decode":
                assert stored % model_axis == 0
        if prof.mlp_tp:
            assert cfg.d_ff % model_axis == 0
        if prof.expert_tp:
            assert cfg.moe.num_experts % model_axis == 0
        # batch axes always divide the global batch
        n = 1
        for ax in prof.batch_axes:
            n *= dict(zip(mesh.axes, mesh.shape))[ax]
        if prof.batch_axes:
            assert shape.global_batch % n == 0
        if shape.kind == "decode" and prof.kv_seq_shard:
            assert shape.seq_len % model_axis == 0


def test_known_fallbacks():
    """hymba (25H) and starcoder2 (36H) can't head-TP on a 16-wide axis."""
    for arch in ("hymba-1.5b", "starcoder2-7b"):
        prof = shd.sharding_profile(ARCHS[arch], SINGLE_POD_MESH, 256,
                                    4096, "train")
        assert not prof.attn_tp
        assert prof.mlp_tp               # TP-MLP hybrid fallback
    prof = shd.sharding_profile(ARCHS["granite-34b"], SINGLE_POD_MESH, 256,
                                4096, "train")
    assert prof.attn_tp and prof.kv_repeat == 16     # MQA: 1 -> 16


def test_decode_uses_seq_sharding_not_repeat():
    prof = shd.sharding_profile(ARCHS["mistral-large-123b"],
                                SINGLE_POD_MESH, 128, 32768, "decode")
    assert prof.kv_seq_shard and prof.kv_repeat == 1


def test_logical_to_pspec_trims_trailing_nones():
    rules = {"batch": ("data",), "mlp": "model"}
    spec = shd.logical_to_pspec(("batch", None, "mlp"), rules)
    assert spec == P(("data",), None, "model")
    spec = shd.logical_to_pspec(("batch", None, None), rules)
    assert spec == P(("data",))


def test_skip_list_is_exactly_full_attention_long_500k():
    skips = skipped_cells()
    assert all(s[1] == "long_500k" for s in skips)
    skipped_archs = {s[0] for s in skips}
    assert "mamba2-780m" not in skipped_archs
    assert "hymba-1.5b" not in skipped_archs
    assert len(skips) == 8


def test_vocab_padding():
    assert shd.pad_vocab(50280) % 256 == 0
    assert shd.pad_vocab(50280) >= 50280
    assert shd.pad_vocab(256) == 256


def test_cell_count_is_32():
    from repro.configs import all_cells
    assert len(all_cells()) == 32


def test_kv_repeat_preserves_attention_semantics():
    """Repeating stored KV heads for TP divisibility must not change the
    attention output (group mapping stays aligned)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.models.attention import _repeat_kv, blocked_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
    base = blocked_attention(q, k, v, causal=True, block_q=32, block_k=32)
    rep = blocked_attention(q, _repeat_kv(k, 2), _repeat_kv(v, 2),
                            causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(base, rep, rtol=1e-5, atol=1e-5)


def test_moe_shard_map_path_matches_local():
    """The expert-parallel shard_map path (psum-combine) equals the local
    dispatch on a trivial 1x1 mesh — the code path the 512-chip dry-run
    lowers, validated numerically."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import ARCHS, reduced
    from repro.configs.base import MeshConfig
    from repro.models.moe import moe_init, moe_forward

    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"])
    params, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.1, jnp.float32)

    y_local, aux_local = moe_forward(params, x, cfg)

    mesh_cfg = MeshConfig((1, 1), ("data", "model"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = shd.make_rules(cfg, mesh_cfg, 2)
    prof = shd.sharding_profile(cfg, mesh_cfg, 2)
    assert prof.expert_tp                 # 8 experts % 1 == 0
    with shd.use_ctx(shd.ShardCtx(mesh=mesh, rules=rules, profile=prof)):
        y_sharded, aux_sharded = moe_forward(params, x, cfg)

    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_sharded),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_local), float(aux_sharded),
                               rtol=1e-5)
