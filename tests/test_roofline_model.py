"""Validate the analytic FLOPs model against XLA cost_analysis on
loop-free programs (the reason the model exists: cost_analysis cannot see
through while-loop trip counts, so we check the per-component constants on
programs without loops)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.analytic import layer_flops_per_token
from repro.configs import ARCHS, reduced


def _hlo_flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost["flops"])


def test_mlp_flops_formula():
    cfg = reduced(ARCHS["mistral-large-123b"])
    d, f = cfg.d_model, cfg.d_ff
    b, s = 2, 64
    w1 = jnp.zeros((d, f), jnp.bfloat16)
    w2 = jnp.zeros((d, f), jnp.bfloat16)
    w3 = jnp.zeros((f, d), jnp.bfloat16)
    x = jnp.zeros((b, s, d), jnp.bfloat16)

    def mlp(x, w1, w2, w3):
        return jax.nn.silu(x @ w1) * (x @ w2) @ w3

    measured = _hlo_flops(mlp, x, w1, w2, w3)
    analytic = 2 * 3 * d * f * b * s          # the model's 'mlp' term
    assert measured == pytest.approx(analytic, rel=0.05)


def test_attention_proj_flops_formula():
    cfg = reduced(ARCHS["mistral-large-123b"])
    d, h, hkv, dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.resolved_head_dim)
    b, s = 2, 64
    x = jnp.zeros((b, s, d), jnp.bfloat16)
    wq = jnp.zeros((d, h * dh), jnp.bfloat16)
    wk = jnp.zeros((d, hkv * dh), jnp.bfloat16)
    wv = jnp.zeros((d, hkv * dh), jnp.bfloat16)
    wo = jnp.zeros((h * dh, d), jnp.bfloat16)

    def proj(x, wq, wk, wv, wo):
        return (x @ wq) @ wo.T @ wo + (x @ wk).sum() + (x @ wv).sum()

    # simpler: measure the four projections separately
    def qkvo(x, wq, wk, wv, wo):
        q = x @ wq
        k = x @ wk
        v = x @ wv
        o = q @ wo
        return q.sum() + k.sum() + v.sum() + o.sum()

    measured = _hlo_flops(qkvo, x, wq, wk, wv, wo)
    comp = layer_flops_per_token(cfg, s, causal_full=True, kind="train")
    analytic = comp["attn_proj"] * b * s
    assert measured == pytest.approx(analytic, rel=0.05)


def test_attention_score_flops_formula():
    cfg = reduced(ARCHS["mistral-large-123b"])
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    b, s = 2, 128
    q = jnp.zeros((b, h, s, dh), jnp.bfloat16)
    k = jnp.zeros((b, h, s, dh), jnp.bfloat16)
    v = jnp.zeros((b, h, s, dh), jnp.bfloat16)

    def attn(q, k, v):
        p = jnp.einsum("bhqd,bhkd->bhqk", q, k)
        return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(p, -1), v)

    measured = _hlo_flops(attn, q, k, v)
    comp = layer_flops_per_token(cfg, s, causal_full=True, kind="train")
    # model counts 4*h*dh*S per token = both einsums, full (unmasked) tiles
    analytic = comp["attn_score_computed"] * b * s \
        * (h / cfg.num_heads)                     # same head count here
    # softmax flops are extra in HLO; allow 15% slack
    assert measured == pytest.approx(analytic, rel=0.15)


def test_unembed_flops_formula():
    from repro.distributed.sharding import pad_vocab
    cfg = reduced(ARCHS["mamba2-780m"])
    d, vp = cfg.d_model, pad_vocab(cfg.vocab_size)
    b, s = 2, 64
    x = jnp.zeros((b, s, d), jnp.bfloat16)
    w = jnp.zeros((d, vp), jnp.bfloat16)
    measured = _hlo_flops(lambda x, w: x @ w, x, w)
    analytic = 2 * d * vp * b * s
    assert measured == pytest.approx(analytic, rel=0.02)


def test_cell_costs_monotonic_in_shape():
    """Sanity: executed FLOPs grow with seq and batch; decode << train."""
    from benchmarks.analytic import cell_costs
    from repro.configs import SHAPES_BY_NAME
    from repro.configs.base import SINGLE_POD_MESH
    from repro.distributed import sharding as shd
    cfg = ARCHS["granite-34b"]
    prof_t = shd.sharding_profile(cfg, SINGLE_POD_MESH, 256, 4096, "train")
    prof_d = shd.sharding_profile(cfg, SINGLE_POD_MESH, 128, 32768, "decode")
    train = cell_costs(cfg, SHAPES_BY_NAME["train_4k"], SINGLE_POD_MESH,
                       prof_t, mu=8)
    dec = cell_costs(cfg, SHAPES_BY_NAME["decode_32k"], SINGLE_POD_MESH,
                     prof_d)
    assert train.flops_per_device > 100 * dec.flops_per_device
    assert train.useful_flops_per_device < train.flops_per_device
    assert dec.hbm_bytes_per_device > 0


def test_variant_knobs_move_terms():
    from benchmarks.analytic import cell_costs
    from repro.configs import SHAPES_BY_NAME
    from repro.configs.base import SINGLE_POD_MESH
    from repro.distributed import sharding as shd
    cfg = ARCHS["mistral-large-123b"]
    shape = SHAPES_BY_NAME["decode_32k"]
    prof = shd.sharding_profile(cfg, SINGLE_POD_MESH, 128, 32768, "decode")
    base = cell_costs(cfg, shape, SINGLE_POD_MESH, prof)
    kv8 = cell_costs(cfg, shape, SINGLE_POD_MESH, prof,
                     variant={"kv_bits": 8})
    bf16 = cell_costs(cfg, shape, SINGLE_POD_MESH, prof,
                      variant={"kv_bits": 8, "param_dtype": "bfloat16"})
    assert kv8.hbm_bytes_per_device < base.hbm_bytes_per_device
    assert bf16.hbm_bytes_per_device < kv8.hbm_bytes_per_device

    shape_t = SHAPES_BY_NAME["train_4k"]
    prof_t = shd.sharding_profile(cfg, SINGLE_POD_MESH, 256, 4096, "train")
    base_t = cell_costs(cfg, shape_t, SINGLE_POD_MESH, prof_t, mu=16,
                        remat_group=11)
    cskip = cell_costs(cfg, shape_t, SINGLE_POD_MESH, prof_t, mu=16,
                       remat_group=11, variant={"causal_skip": True})
    assert cskip.flops_per_device < base_t.flops_per_device
