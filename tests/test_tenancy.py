"""Multi-tenant multiplexing (ISSUE 6 tentpole): N keyed streams on one
engine's worth of shared resources — parity with standalone engines,
per-tenant budget caps, I/O fairness accounting, and the declarative
profile table in ``configs.workloads``.
"""
import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.configs.workloads import TENANT_PROFILES, get_tenant_profile
from repro.core import (
    EventBatch, MultiTenantEngine, StreamEngine, TenantSpec,
    TumblingWindows, make_operator,
)
from repro.core.batch_exec import BatchWorkItem
from repro.core.buckets import MemoryBudget, TenantBudget


def _stream(tenant_seed, n, width, lo, hi):
    rng = np.random.default_rng(tenant_seed)
    return EventBatch(rng.integers(0, 8, n), rng.uniform(lo, hi, n),
                      rng.normal(size=(n, width)).astype(np.float32))


def _specs(aion):
    return [
        TenantSpec(name="alpha", assigner=TumblingWindows(10.0),
                   operator=make_operator("average", aion.block_size, 1),
                   value_width=1, weight=2,
                   device_budget_bytes=32 << 20),
        TenantSpec(name="beta", assigner=TumblingWindows(5.0),
                   operator=make_operator("average", aion.block_size, 2),
                   value_width=2, weight=1,
                   device_budget_bytes=32 << 20),
        TenantSpec(name="gamma", assigner=TumblingWindows(20.0),
                   operator=make_operator("average", aion.block_size, 1),
                   value_width=1, weight=1,
                   device_budget_bytes=32 << 20),
    ]


def _drive_one(eng, seed, width, n_rounds=10):
    rng = np.random.default_rng(seed)
    now = 0.0
    for _ in range(n_rounds):
        n = 120
        ts = rng.uniform(max(now - 8, 0), now + 1, n)
        eng.ingest(EventBatch(rng.integers(0, 6, n), ts,
                              rng.normal(size=(n, width))
                              .astype(np.float32)), now)
        eng.advance_watermark(now - 3, now)
        eng.poll(now)
        now += 2.5
    eng.advance_watermark(now + 100, now)
    return now


def _final_results(eng, now):
    if eng.pipeline is not None:
        assert eng.pipeline.drain()
    assert eng.io.drain()
    items = [BatchWorkItem(wid=wid, state=st, late=True)
             for wid, st in sorted(eng.windows.items())]
    return dict(eng.batch_exec.execute(items, now))


@pytest.mark.parametrize("pipelined", [False, True])
def test_multi_tenant_parity_with_standalone(pipelined, tmp_path):
    aion = AionConfig(block_size=64, pipelined_execution=pipelined)
    mt = MultiTenantEngine(_specs(aion), device_budget_bytes=256 << 20,
                           spill_dir=tmp_path / "mt", aion=aion)
    widths = {"alpha": 1, "beta": 2, "gamma": 1}
    seeds = {"alpha": 21, "beta": 22, "gamma": 23}
    ends = {}
    for name in mt.engines:
        ends[name] = _drive_one(mt.engine(name), seeds[name], widths[name])
    mt_results = {name: _final_results(mt.engine(name), ends[name])
                  for name in mt.engines}

    # reference: one standalone synchronous engine per tenant
    ref_aion = AionConfig(block_size=64)
    for spec in _specs(ref_aion):
        ref = StreamEngine(assigner=spec.assigner, operator=spec.operator,
                           aion=ref_aion, value_width=spec.value_width,
                           spill_dir=tmp_path / f"ref_{spec.name}")
        end = _drive_one(ref, seeds[spec.name], widths[spec.name])
        ref_results = _final_results(ref, end)
        got = mt_results[spec.name]
        assert set(got) == set(ref_results)
        for wid in ref_results:
            np.testing.assert_allclose(got[wid], ref_results[wid],
                                       atol=1e-4)
        ref.close()
    assert mt.executor.stats["errors"] == 0
    mt.close()


def test_tenant_budget_caps_inside_shared_parent():
    parent = MemoryBudget(1000)
    a = TenantBudget(parent, 400)
    b = TenantBudget(parent, 800)
    # own cap binds before the parent does
    assert a.try_reserve(400)
    assert not a.try_reserve(1)
    # parent pool is shared: b sees what a consumed
    assert b.try_reserve(600)
    assert not b.try_reserve(200)          # parent exhausted, cap not
    assert parent.used_bytes == 1000
    a.release(400)
    assert b.try_reserve(200)              # a's release refills the parent
    b.release(800)
    assert parent.used_bytes == 0
    assert a.used_bytes == 0 and b.used_bytes == 0


def test_tenant_budget_rolls_back_own_on_parent_failure():
    parent = MemoryBudget(100)
    a = TenantBudget(parent, 500)
    assert parent.try_reserve(80)          # someone else took the room
    assert not a.try_reserve(50)
    assert a.used_bytes == 0               # failed reserve left no residue


def test_fairness_stats_count_per_tenant_io(tmp_path):
    aion = AionConfig(block_size=64)
    mt = MultiTenantEngine(_specs(aion)[:2],
                           device_budget_bytes=128 << 20,
                           spill_dir=tmp_path, aion=aion)
    widths = {"alpha": 1, "beta": 2}
    for name, eng in mt.engines.items():
        eng.ingest(_stream(31, 300, widths[name], 0.0, 9.9), now=1.0)
        # force tenant-tagged I/O through the shared executor
        eng.io.request_destage(next(iter(eng.windows.values())))
    assert mt.executor.drain(timeout=10.0)
    stats = mt.fairness_stats()
    assert stats.get("alpha", 0) > 0
    assert stats.get("beta", 0) > 0
    mt.close()


def test_duplicate_tenant_names_rejected():
    aion = AionConfig(block_size=64)
    specs = _specs(aion)[:1] * 2
    with pytest.raises(ValueError, match="duplicate"):
        MultiTenantEngine(specs, aion=aion)


# ------------------------------------------------------------- profiles
def test_tenant_profiles_table_is_well_formed():
    names = [p.name for p in TENANT_PROFILES]
    assert len(names) == 10 and len(set(names)) == 10
    assert abs(sum(p.device_budget_frac for p in TENANT_PROFILES)
               - 1.0) < 1e-9
    assert abs(sum(p.host_budget_frac for p in TENANT_PROFILES)
               - 1.0) < 1e-9
    assert all(p.weight >= 1 for p in TENANT_PROFILES)
    assert get_tenant_profile("mistral_large_123b").weight == 4
    with pytest.raises(KeyError):
        get_tenant_profile("nonexistent_model")


def test_from_profiles_builds_and_streams(tmp_path):
    aion = AionConfig(block_size=64)
    profiles = [get_tenant_profile("mamba2_780m"),
                get_tenant_profile("qwen3_moe_30b")]
    mt = MultiTenantEngine.from_profiles(
        profiles, device_budget_bytes=256 << 20,
        host_budget_bytes=256 << 20, spill_dir=tmp_path, aion=aion)
    for p in profiles:
        eng = mt.engine(p.name)
        width = p.workload.resolved_value_width()
        mt.ingest(p.name, _stream(41, 200, width, 0.0,
                                  p.workload.window_duration - 0.1),
                  now=1.0)
        assert eng.metrics.ingested == 200
    mt.advance_watermark(1e6, now=2.0, tenant="mamba2_780m")
    mt.poll(now=2.0)
    assert len(mt.results("mamba2_780m")) >= 1
    assert mt.results("qwen3_moe_30b") == {}   # other tenant untouched
    mt.close()
