import numpy as np
import pytest

from repro.core.staleness import (
    deltaev_times, deltat_times, empirical_cdf, executions_for_bound,
    max_staleness_of, minimize_max_staleness, staleness_profile,
)

T = 100.0


def _lnorm(n=20000, seed=0):
    rng = np.random.default_rng(seed)
    return np.clip(rng.lognormal(0, 1, n) * 10, 0, T)


def test_staleness_profile_sums_to_bound():
    """With a single execution at T, st = 1*1 = total mass * total time."""
    delays = _lnorm()
    import jax.numpy as jnp
    grid, F = empirical_cdf(delays, T)
    st = staleness_profile(jnp.asarray([T]), jnp.asarray(grid),
                           jnp.asarray(F), T)
    assert float(st[0]) == pytest.approx(1.0, rel=1e-2)


@pytest.mark.parametrize("k", [2, 4, 8, 16])
def test_aion_beats_baseline_triggers(k):
    delays = _lnorm()
    aion = minimize_max_staleness(delays, T, k).max_staleness
    dt = max_staleness_of(deltat_times(T, k), delays, T)
    de = max_staleness_of(deltaev_times(delays, T, k), delays, T)
    assert aion <= dt + 1e-9
    assert aion <= de + 1e-9


def test_aion_improves_with_more_executions():
    delays = _lnorm()
    vals = [minimize_max_staleness(delays, T, k).max_staleness
            for k in (2, 4, 8, 16)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_times_monotone_and_end_at_horizon():
    delays = _lnorm()
    res = minimize_max_staleness(delays, T, 8)
    assert np.all(np.diff(res.times) >= -1e-9)
    assert res.times[-1] == pytest.approx(T)
    assert np.all(res.times > 0)


@pytest.mark.parametrize("dist", ["lnorm", "unif", "norm", "bursts"])
def test_fewer_executions_for_bound_all_distributions(dist):
    """Paper Fig. 9 (right): AION reaches each bound with <= the baseline
    triggers' executions, across all four lateness distributions."""
    from repro.data.generators import lateness_delays
    rng = np.random.default_rng(1)
    delays = lateness_delays(dist, 20000, T, rng)
    for bound in (0.1, 0.05):
        ka = executions_for_bound(
            lambda k: minimize_max_staleness(delays, T, k).times,
            delays, T, bound, k_max=40)
        kt = executions_for_bound(lambda k: deltat_times(T, k),
                                  delays, T, bound, k_max=40)
        ke = executions_for_bound(lambda k: deltaev_times(delays, T, k),
                                  delays, T, bound, k_max=40)
        assert ka is not None
        if kt is not None:
            assert ka <= kt
        if ke is not None:
            assert ka <= ke


def test_paper_q4_headline_lognormal():
    """Paper: at bound 0.05 under lognormal lateness, AION needs roughly a
    third of the baselines' executions (31%/27% reported)."""
    delays = _lnorm()
    bound = 0.05
    ka = executions_for_bound(
        lambda k: minimize_max_staleness(delays, T, k).times,
        delays, T, bound, k_max=64)
    kt = executions_for_bound(lambda k: deltat_times(T, k), delays, T,
                              bound, k_max=64)
    ke = executions_for_bound(lambda k: deltaev_times(delays, T, k),
                              delays, T, bound, k_max=64)
    assert ka / kt <= 0.55 and ka / ke <= 0.55
