"""Engine crash recovery over the log-structured store, plus the
cleanup-driven compaction bound under soak.

The crash matrix (acceptance criteria of the storage subsystem):

* **after an acknowledged group commit** — the engine is SIGKILL-style
  stopped (no close, no final flush) right after a manifest checkpoint;
  every record the WAL acknowledged must survive the reopen.
* **mid-segment** — same stop, but with a torn partial record appended
  past the last WAL ack and an unacknowledged put buffered (a spill that
  died mid-write): recovery must truncate the tail, keep everything
  acknowledged, and the restored engine must reach oracle parity.

Both paths restore from a *manifest* checkpoint
(``checkpoint_state(include_stored_data=False)``) so spilled blocks come
back through the recovered value log, not from inline snapshot arrays —
that is the recovery actually being exercised. The differential oracle is
the same trivially-correct numpy group-by the soak uses.
"""
import numpy as np
import pytest

from repro.configs.base import AionConfig
from repro.core import StreamEngine, TumblingWindows
from repro.core.batch_exec import BatchWorkItem
from repro.core.cleanup import PredictiveCleanup
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.core.windows import WindowId

WINDOW = 10.0
N_EVENTS = 12_000
CHUNK = 500
MAX_LATE = 25.0
SEED = 77


class _NoPurgeCleanup(PredictiveCleanup):
    def should_purge(self, window_end, watermark):
        return False


def _make_engine(spill_dir, purge_bound=None,
                 host_budget=1 << 19) -> StreamEngine:
    aion = AionConfig(block_size=256, store_backend="log",
                      store_segment_bytes=32 << 10)
    cleanup = (_NoPurgeCleanup(initial_bound=60.0, min_history=1 << 62)
               if purge_bound is None else
               PredictiveCleanup(initial_bound=purge_bound,
                                 min_history=1 << 62))
    return StreamEngine(
        assigner=TumblingWindows(WINDOW),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1,
        cleanup=cleanup,
        trigger=DeltaTTrigger(executions=2),
        device_budget_bytes=1 << 20,
        host_budget_bytes=host_budget,      # sustained spill pressure
        spill_dir=spill_dir,
    )


def _sigkill(eng: StreamEngine) -> None:
    """SIGKILL-style stop: stop the executor thread and drop the store's
    file handles WITHOUT the final group commit a clean close performs —
    anything unacknowledged must behave as lost."""
    io = eng.io
    io.executor.shutdown()             # stop + join, no drain, no commit
    store = io.store
    if store._active_f is not None:
        store._active_f.close()
        store._active_f = None
    if store._wal_f is not None:
        store._wal_f.close()
        store._wal_f = None


def _batches(rng, width=1):
    now, wm, emitted = 0.0, 0.0, 0
    while emitted < N_EVENTS:
        n = min(CHUNK, N_EVENTS - emitted)
        u = rng.random(n)
        delay = np.where(
            u < 0.6, rng.uniform(0.0, 2.0, n),
            rng.uniform(0.0, MAX_LATE, n))
        ts = np.maximum(now - delay, 0.0)
        batch = EventBatch(rng.integers(0, 8, n), ts,
                           rng.normal(size=(n, width)).astype(np.float32))
        emitted += n
        advance = rng.random() < 0.7
        wm = max(wm, now - rng.uniform(0.0, 5.0)) if advance else wm
        yield batch, now, (wm if advance else None)
        now += rng.uniform(1.0, 4.0)


def _oracle_average(keys, ts, vals):
    wstart = np.floor(ts / WINDOW) * WINDOW
    out = {}
    for s in np.unique(wstart):
        sel = wstart == s
        out[WindowId(float(s), float(s) + WINDOW)] = \
            float(np.mean(vals[sel, 0], dtype=np.float64))
    return out


def _final_sweep(eng, now):
    eng.io.drain()
    items = [BatchWorkItem(wid, eng.windows[wid], True)
             for wid in sorted(eng.windows)]
    if eng.batching_enabled and len(items) > 1:
        eng.batch_exec.execute(items, now)
    else:
        for it in items:
            eng.execute_window(it.wid, now, late=True)


@pytest.mark.parametrize("injection", ["after_commit", "mid_segment"])
def test_crash_recovery_to_oracle_parity(tmp_path, injection):
    rng = np.random.default_rng(SEED)
    eng = _make_engine(tmp_path)
    all_events = []
    feed = _batches(rng)
    crashed = False
    snap = None
    last_now = 0.0
    for i, (batch, now, wm) in enumerate(feed):
        all_events.append((batch.keys.copy(), batch.timestamps.copy(),
                           batch.values.copy()))
        eng.ingest(batch, now)
        if wm is not None:
            eng.advance_watermark(wm, now)
        eng.poll(now)
        last_now = now

        if not crashed and (i + 1) * CHUNK >= N_EVENTS // 2:
            crashed = True
            eng.io.drain()
            # manifest checkpoint: spilled blocks reference the value
            # log instead of carrying inline arrays
            snap = eng.checkpoint_state(include_stored_data=False)
            stored_refs = sum(
                1 for w in snap["windows"] for b in w["blocks"]
                if b.get("stored"))
            assert stored_refs > 0, \
                "checkpoint exercised no store-backed manifests"
            if injection == "mid_segment":
                # a spill dying mid-write: an unacknowledged record plus
                # a torn tail past the last WAL ack
                store = eng.io.store
                junk = {
                    "keys": np.arange(256, dtype=np.int32),
                    "timestamps": np.zeros(256, np.float64),
                    "values": np.ones((256, 1), np.float32),
                }
                store.put((999.0, 1009.0), 999_999, junk, 256)  # unacked
                with open(store.active_segment_path(), "ab") as f:
                    f.write(b"\xba\xad" * 33)                   # torn
            _sigkill(eng)

            eng = _make_engine(tmp_path)          # store reopens + WAL
            if injection == "mid_segment":
                assert eng.io.store.stats["recovery_truncated_bytes"] > 0
                assert eng.io.store.current_fill((999.0, 1009.0),
                                                 999_999) is None
            # restore pulls manifest blocks from the recovered log;
            # a lost acknowledged record would raise KeyError here
            eng.restore_state(snap)

    assert crashed and snap is not None
    wm = last_now + MAX_LATE
    eng.advance_watermark(wm, last_now)
    for t in np.linspace(last_now, last_now + 70.0, 6):
        eng.poll(t)
    _final_sweep(eng, last_now + 70.0)
    results = dict(eng.results)
    eng.close()

    keys = np.concatenate([k for k, _, _ in all_events])
    tss = np.concatenate([t for _, t, _ in all_events])
    vals = np.concatenate([v for _, _, v in all_events])
    want = _oracle_average(keys, tss, vals)
    assert set(results) == set(want)
    for wid in want:
        assert results[wid] == pytest.approx(want[wid], rel=2e-4,
                                             abs=2e-4), wid


def test_restore_rejects_missing_store_record(tmp_path):
    """A manifest checkpoint against a store that lost the record (here:
    a fresh directory) must fail loudly, not silently drop data."""
    eng = _make_engine(tmp_path / "a", host_budget=8 << 10)
    rng = np.random.default_rng(3)
    batch = EventBatch(rng.integers(0, 8, 3000),
                       rng.uniform(0.0, 10.0, 3000),
                       rng.normal(size=(3000, 1)).astype(np.float32))
    eng.ingest(batch, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    eng.poll(10.0)
    eng.io.drain()
    snap = eng.checkpoint_state(include_stored_data=False)
    assert any(b.get("stored") for w in snap["windows"]
               for b in w["blocks"])
    eng.close()
    eng2 = _make_engine(tmp_path / "fresh")
    with pytest.raises(KeyError):
        eng2.restore_state(snap)
    eng2.close()


def test_npz_checkpoints_never_write_manifests(tmp_path):
    """The npz fallback loses fill/window metadata across a reopen, so
    manifest checkpoints must inline its blocks (regression: a stored
    reference against a reopened npz store was unrestorable)."""
    aion = AionConfig(block_size=256, store_backend="npz")
    eng = StreamEngine(
        assigner=TumblingWindows(WINDOW),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1,
        cleanup=_NoPurgeCleanup(initial_bound=60.0, min_history=1 << 62),
        trigger=DeltaTTrigger(executions=2),
        device_budget_bytes=1 << 20, host_budget_bytes=8 << 10,
        spill_dir=tmp_path)
    rng = np.random.default_rng(9)
    batch = EventBatch(rng.integers(0, 8, 3000),
                       rng.uniform(0.0, 10.0, 3000),
                       rng.normal(size=(3000, 1)).astype(np.float32))
    eng.ingest(batch, now=0.0)
    eng.advance_watermark(10.0, 10.0)
    eng.poll(10.0)
    eng.io.drain()
    snap = eng.checkpoint_state(include_stored_data=False)
    blocks = [b for w in snap["windows"] for b in w["blocks"]]
    assert blocks and not any(b.get("stored") for b in blocks)
    assert all(b["data"] for b in blocks)    # everything inlined
    eng.close()


def test_compaction_bound_holds_under_purge_soak(tmp_path):
    """Predictive-cleanup purges emit tombstones; the engine's
    compaction requests keep on-disk bytes <= 2 x live record bytes
    (+ active-segment headroom) — the paper's §3.4 bounded-storage
    claim, previously untested."""
    # tiny host budget: everything spills into the log; a 12 s purge
    # bound: most expired windows purge during the run, so the log keeps
    # accumulating tombstones the compactor must consume to stay bounded
    eng = _make_engine(tmp_path, purge_bound=12.0, host_budget=16 << 10)
    rng = np.random.default_rng(11)
    for batch, now, wm in _batches(rng):
        eng.ingest(batch, now)
        if wm is not None:
            eng.advance_watermark(wm, now)
        eng.poll(now)
    eng.io.drain()
    store = eng.io.store
    assert eng.metrics.purged_windows > 0
    assert store.stats["deletes"] > 0            # purge -> tombstones
    assert store.stats["bytes_compacted"] > 0    # compaction consumed
    store.commit()
    store.compact_if_needed(2.0)                 # settle the tail
    disk = store.on_disk_bytes()
    live = store.live_record_bytes()
    assert disk <= max(2 * live, store.segment_bytes) \
        + store.segment_bytes, (disk, live)
    eng.close()
