"""Differential soak test: the full engine vs a never-spilling oracle.

Drives ``StreamEngine`` through ~50k synthetic events with heavy
lateness, random watermark advances, a mid-stream checkpoint/restore, and
sustained spill pressure (tiny device + host budgets with a spill dir),
then asserts that every window's final result matches a trivially-correct
in-memory oracle — a plain numpy group-by over ALL events ever generated.
Runs the (batched x slot-sharded x block-pool) config matrix; slot
sharding actually shards under ``make verify-multidevice`` (8 simulated
CPU devices) and is a checked no-op on the single-device tier-1
container; ``block_pool`` routes the batched gather through the
persistent device arena (block tables + demand pool-fills) under the
same spill pressure and mid-stream restore.

Railgun-style rationale (PAPERS.md): partitioned streaming state is only
trustworthy while it is continuously validated against an oracle — the
soak is that validation for the tiered-state + batched + sharded stack.
"""
import contextlib

import numpy as np
import pytest
import jax

from repro.configs.base import AionConfig
from repro.core import StreamEngine, TumblingWindows
from repro.core.batch_exec import BatchWorkItem
from repro.core.cleanup import PredictiveCleanup
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.core.windows import WindowId
from repro.distributed.fault import EngineRecovery
from repro.testing import FaultInjector, FaultyBlockStore

#: store ops the chaos axis injects on. Deliberately NOT ``delete``:
#: purges/reconciles run on the engine main thread outside the retry
#: envelope, and the chaos contract is about the data path.
_CHAOS_OPS = ("get", "put", "commit", "readahead")

WINDOW = 10.0
N_EVENTS = 50_000
CHUNK = 1_000
MAX_LATE = 25.0           # heavy lateness: up to 2.5 windows
SEED = 1234


class _NoPurgeCleanup(PredictiveCleanup):
    """Purge-free cleanup for the differential harness.

    The oracle accounts every event forever; purging engine state and
    then receiving more late events for that window would (correctly, per
    the paper's coverage contract) diverge from the oracle, so the soak
    pins a moderate re-execution horizon and disables purging. Purge
    behaviour is covered by the engine unit tests.
    """

    def should_purge(self, window_end: float, watermark: float) -> bool:
        return False


def _cleanup() -> _NoPurgeCleanup:
    # fixed 60s horizon (6 windows > MAX_LATE): min_history keeps the
    # DKW estimator from ever replacing it mid-run
    return _NoPurgeCleanup(initial_bound=60.0, min_history=1 << 62)


def _make_engine(op_name: str, batched: bool, sharded: bool,
                 spill_dir, width: int,
                 pooled: bool = False,
                 store: str = "log",
                 pipelined: bool = False,
                 prefetch: str = "fixed",
                 splitk: int = 0,
                 fault_rate: float = 0.0,
                 fault_seed: int = 0,
                 ladder: bool = True) -> StreamEngine:
    extra = {}
    if fault_rate > 0:
        # chaos axis: zero backoff keeps ~50k-event soaks fast, a low
        # breaker threshold makes the ladder engage under the injected
        # error bursts (store traffic is bursty: destage/spill groups,
        # re-execution fetch fans); ladder=False = the ablation control
        extra = dict(io_retry_backoff=0.0,
                     breaker_error_threshold=2 if ladder else 0)
    aion = AionConfig(block_size=256, batched_execution=batched,
                      slot_sharding=sharded, block_pool=pooled,
                      store_backend=store,
                      store_segment_bytes=128 << 10,
                      pipelined_execution=pipelined,
                      prefetch_backend=prefetch,
                      splitk_chunk_rows=splitk, **extra)
    store_obj = None
    if fault_rate > 0:
        from repro.storage import make_store
        inner = make_store("log", spill_dir, segment_bytes=128 << 10)
        inj = FaultInjector(
            seed=fault_seed,
            rates={op: fault_rate for op in _CHAOS_OPS},
            # failure streaks stay below io_retry_limit, so the retry
            # path deterministically recovers: gave_up == 0 is EXACT
            max_consecutive=2)
        store_obj = FaultyBlockStore(inner, inj)
    kw = {"num_keys": 8} if op_name == "stock" else {}
    # spill pressure: ~1 MB device budget (~256 blocks), ~512 KB host
    # budget -> blocks continuously destage AND spill to storage. The
    # chaos axis squeezes both 8x so the run is *dominated* by store
    # traffic -- every fold crosses the faulty get/put/commit path.
    dev_budget = 1 << 17 if fault_rate > 0 else 1 << 20
    host_budget = 1 << 16 if fault_rate > 0 else 1 << 19
    eng = StreamEngine(
        assigner=TumblingWindows(WINDOW),
        operator=make_operator(op_name, aion.block_size, width, **kw),
        aion=aion, value_width=width,
        cleanup=_cleanup(),
        trigger=DeltaTTrigger(executions=2),
        device_budget_bytes=dev_budget,
        host_budget_bytes=host_budget,
        spill_dir=spill_dir,
        store=store_obj,
    )
    if store_obj is not None:
        eng._fault_injector = store_obj.injector
    return eng


def _final_sweep(eng: StreamEngine, now: float) -> None:
    """Re-execute every window through the engine's own (batched or
    reference) path so final results reflect all folded-in late events —
    including plans lost at the mid-stream restore."""
    eng.flush_deferred(now)   # backpressure deferral must never be loss
    if eng.pipeline is not None:
        assert eng.pipeline.drain(), "fold pipeline failed to drain"
    assert eng.io.drain(), "I/O executor failed to drain"
    items = [BatchWorkItem(wid, eng.windows[wid], True)
             for wid in sorted(eng.windows)]
    if eng.batching_enabled and len(items) > 1:
        eng.batch_exec.execute(items, now)
    else:
        for it in items:
            eng.execute_window(it.wid, now, late=True)


_COUNTERS = ("ingested", "ingested_late", "live_executions",
             "late_executions", "batch_executions",
             "sharded_batch_executions", "pooled_rows", "fallback_rows",
             "demand_pool_fills", "pipeline_rounds", "epoch_demoted_rows",
             "splitk_launches",
             # self-healing ladder observables (ISSUE 9)
             "shed_readahead_drives", "shed_prefetch_rounds",
             "demoted_sync_rounds", "deferred_events",
             "readmitted_events")

_IO_COUNTERS = ("errors", "retries", "gave_up", "readahead_shed",
                "staged_blocks")


class _SoakTotals:
    """Counter totals across both engine incarnations (the restore swaps
    in a fresh engine whose metrics start at zero)."""

    def __init__(self):
        for k in _COUNTERS:
            setattr(self, k, 0)
        for k in _IO_COUNTERS:
            setattr(self, "io_" + k, 0)
        self.injected_faults = 0
        self.ladder_transitions = []

    def absorb(self, eng) -> None:
        for k in _COUNTERS:
            setattr(self, k, getattr(self, k) + getattr(eng.metrics, k))
        for k in _IO_COUNTERS:
            setattr(self, "io_" + k,
                    getattr(self, "io_" + k) + eng.io.stats[k])
        self.ladder_transitions.extend(eng.metrics.ladder_transitions)
        inj = getattr(eng, "_fault_injector", None)
        if inj is not None:
            self.injected_faults += inj.stats["injected"]


def _drive(op_name: str, batched: bool, sharded: bool, spill_dir,
           width: int = 1, pooled: bool = False, store: str = "log",
           pipelined: bool = False, prefetch: str = "fixed",
           splitk: int = 0, fault_rate: float = 0.0,
           fault_seed: int = 0, ladder: bool = True):
    """Run the soak; returns (results, oracle_events, counter_totals)."""
    rng = np.random.default_rng(SEED)
    totals = _SoakTotals()
    eng = _make_engine(op_name, batched, sharded, spill_dir / "a", width,
                       pooled, store, pipelined, prefetch, splitk,
                       fault_rate, fault_seed, ladder)
    all_events = []           # oracle ledger: every event ever generated
    now = 0.0
    wm = 0.0
    emitted = 0
    restored = False
    while emitted < N_EVENTS:
        n = min(CHUNK, N_EVENTS - emitted)
        # heavy lateness: 65% fresh, 25% late up to MAX_LATE, 10% very
        # late (uniform over the full late range)
        u = rng.random(n)
        delay = np.where(
            u < 0.65, rng.uniform(0.0, 2.0, n),
            np.where(u < 0.90, rng.uniform(0.0, MAX_LATE, n),
                     rng.uniform(MAX_LATE * 0.6, MAX_LATE, n)))
        ts = np.maximum(now - delay, 0.0)
        batch = EventBatch(rng.integers(0, 8, n), ts,
                           rng.normal(size=(n, width)).astype(np.float32))
        all_events.append((batch.keys.copy(), batch.timestamps.copy(),
                           batch.values.copy()))
        eng.ingest(batch, now)
        emitted += n
        # random watermark advances: sometimes lag, sometimes jump ahead
        if rng.random() < 0.7:
            wm = max(wm, now - rng.uniform(0.0, 5.0))
            eng.advance_watermark(wm, now)
        eng.poll(now)
        now += rng.uniform(1.0, 4.0)            # random processing pace

        if not restored and emitted >= N_EVENTS // 2:
            # mid-stream crash/restore: serialize, rebuild, resume.
            # Under chaos the checkpoint itself runs fault-free (it is
            # the recovery anchor, not the victim).
            restored = True
            inj = getattr(eng, "_fault_injector", None)
            ctx = inj.paused() if inj is not None else \
                contextlib.nullcontext()
            with ctx:
                snap = eng.checkpoint_state()
                totals.absorb(eng)
                eng.close()
            eng = _make_engine(op_name, batched, sharded,
                               spill_dir / "b", width, pooled, store,
                               pipelined, prefetch, splitk,
                               fault_rate, fault_seed + 1, ladder)
            inj_b = getattr(eng, "_fault_injector", None)
            ctx = inj_b.paused() if inj_b is not None else \
                contextlib.nullcontext()
            with ctx:
                eng.restore_state(snap)

    # close out: expire everything, fire remaining re-execution plans,
    # then a final full sweep through the engine's own execution path
    wm = now + MAX_LATE
    eng.advance_watermark(wm, now)
    for t in np.linspace(now, now + 70.0, 8):
        eng.poll(t)
    _final_sweep(eng, now + 70.0)
    results = dict(eng.results)
    totals.absorb(eng)
    eng.close()
    keys = np.concatenate([k for k, _, _ in all_events])
    tss = np.concatenate([t for _, t, _ in all_events])
    vals = np.concatenate([v for _, _, v in all_events])
    return results, (keys, tss, vals), totals


def _oracle_average(keys, ts, vals):
    """Never-spilling in-memory oracle: exact mean over ALL events of
    each tumbling window."""
    wstart = np.floor(ts / WINDOW) * WINDOW
    out = {}
    for s in np.unique(wstart):
        sel = wstart == s
        out[WindowId(float(s), float(s) + WINDOW)] = \
            float(np.mean(vals[sel, 0], dtype=np.float64))
    return out


def _oracle_stock(keys, ts, vals, num_keys: int = 8):
    wstart = np.floor(ts / WINDOW) * WINDOW
    out = {}
    for s in np.unique(wstart):
        sel = wstart == s
        k = keys[sel] % num_keys
        p = vals[sel, 0].astype(np.float64)
        mn = np.full(num_keys, np.inf)
        mx = np.full(num_keys, -np.inf)
        sm = np.zeros(num_keys)
        ct = np.zeros(num_keys)
        np.minimum.at(mn, k, p)
        np.maximum.at(mx, k, p)
        np.add.at(sm, k, p)
        np.add.at(ct, k, 1.0)
        out[WindowId(float(s), float(s) + WINDOW)] = {
            "mean": sm / np.maximum(ct, 1.0), "min": mn, "max": mx}
    return out


@pytest.mark.parametrize("batched,sharded,pooled,store", [
    # the default persistent tier is the log-structured store
    (True, True, True, "log"), (True, False, True, "log"),  # block table
    (True, True, False, "log"), (True, False, False, "log"),  # stacked
    (False, True, False, "log"), (False, False, False, "log"),
    # legacy npz fallback backend: the same soak over the
    # file-per-block persistent tier (store ablation axis)
    (True, False, True, "npz"), (True, True, False, "npz"),
    # no (batched=False, pooled=True) row: the engine only builds the
    # pool when the batched path can consume block tables, so that
    # config is byte-identical to all-off (pooled per-window folds are
    # covered via single-window batches inside the pooled rows above)
])
def test_soak_differential_average(tmp_path, batched, sharded, pooled,
                                   store):
    results, (keys, ts, vals), totals = _drive(
        "average", batched, sharded, tmp_path, pooled=pooled, store=store)
    want = _oracle_average(keys, ts, vals)
    assert set(results) == set(want)
    for wid in want:
        assert results[wid] == pytest.approx(want[wid], rel=2e-4,
                                             abs=2e-4), wid
    # the soak exercised what it claims to exercise
    assert totals.ingested == N_EVENTS
    assert totals.ingested_late > N_EVENTS // 10       # heavy lateness
    assert totals.late_executions > 0
    if batched:
        assert totals.batch_executions > 0
    else:
        assert totals.batch_executions == 0
    if sharded and batched and len(jax.devices()) > 1:
        assert totals.sharded_batch_executions > 0
    else:
        assert totals.sharded_batch_executions == 0
    if pooled and batched:
        # the block-table path really carried rows under spill pressure
        assert totals.pooled_rows > 0
    else:
        assert totals.pooled_rows == 0


@pytest.mark.parametrize("sharded,pooled", [
    (True, True), (False, True), (True, False), (False, False),
])
def test_soak_differential_stock_spill_pressure(tmp_path, sharded, pooled):
    """Keyed operator under the same soak: per-key min/max/mean survive
    spill pressure + restore, batched, pooled and (where possible)
    sharded."""
    results, (keys, ts, vals), totals = _drive(
        "stock", True, sharded, tmp_path, width=1, pooled=pooled)
    want = _oracle_stock(keys, ts, vals)
    assert set(results) == set(want)
    for wid, w in want.items():
        got = results[wid]
        present = w["min"] < np.inf
        np.testing.assert_allclose(got["mean"][present],
                                   w["mean"][present],
                                   rtol=2e-4, atol=2e-4, err_msg=str(wid))
        np.testing.assert_allclose(got["min"][present], w["min"][present],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got["max"][present], w["max"][present],
                                   rtol=1e-5, atol=1e-5)
    # spill pressure really happened: storage-tier traffic on both runs
    assert totals.ingested == N_EVENTS
    if pooled:
        assert totals.pooled_rows > 0


@pytest.mark.parametrize("pooled", [True, False])
def test_soak_differential_pipelined(tmp_path, pooled):
    """ISSUE 6: the pipelined engine — folds submitted to the async
    round worker while ingestion continues, per-slot epoch validation on
    the pooled path — must stay oracle-exact under the same lateness +
    spill + restore pressure, with zero silently-absorbed I/O failures.
    """
    results, (keys, ts, vals), totals = _drive(
        "average", True, False, tmp_path, pooled=pooled,
        pipelined=True)
    want = _oracle_average(keys, ts, vals)
    assert set(results) == set(want)
    for wid in want:
        assert results[wid] == pytest.approx(want[wid], rel=2e-4,
                                             abs=2e-4), wid
    assert totals.ingested == N_EVENTS
    assert totals.ingested_late > N_EVENTS // 10
    # rounds really flowed through the async worker, and every task the
    # I/O executor ran either succeeded or would have raised (satellite:
    # no swallowed failures)
    assert totals.pipeline_rounds > 0
    assert totals.io_errors == 0
    if pooled:
        assert totals.pooled_rows > 0


@pytest.mark.parametrize("batched,pipelined", [
    (True, False), (True, True), (False, False),
])
def test_soak_differential_learned_prefetch(tmp_path, batched, pipelined):
    """ISSUE 7: the learned prefetch backend (lateness-model-driven
    segment sweeps + coalescing rewrites + WAL-coalesced commits) is a
    pure I/O-scheduling change — results must stay oracle-exact under
    the same lateness + spill + restore pressure."""
    results, (keys, ts, vals), totals = _drive(
        "average", batched, False, tmp_path, pipelined=pipelined,
        prefetch="learned")
    want = _oracle_average(keys, ts, vals)
    assert set(results) == set(want)
    for wid in want:
        assert results[wid] == pytest.approx(want[wid], rel=2e-4,
                                             abs=2e-4), wid
    assert totals.ingested == N_EVENTS
    assert totals.ingested_late > N_EVENTS // 10
    assert totals.io_errors == 0


def _oracle_percentile(keys, ts, vals, qs=(0.5, 0.95, 0.99)):
    wstart = np.floor(ts / WINDOW) * WINDOW
    out = {}
    for s in np.unique(wstart):
        sel = wstart == s
        out[WindowId(float(s), float(s) + WINDOW)] = {
            q: float(np.quantile(vals[sel, 0], q)) for q in qs}
    return out


@pytest.mark.parametrize("sharded,pooled,splitk", [
    # ISSUE 8 axis: split-K chunked folds on/off over the pooled and
    # sharded layouts — results must be invariant to the decomposition
    (False, True, 8), (False, True, 0),
    (True, True, 8), (True, False, 8),
])
def test_soak_differential_splitk(tmp_path, sharded, pooled, splitk):
    """Split-K soak: chunked partial-accumulator folds under the full
    lateness + spill + restore pressure match the oracle exactly, and
    the chunked path really launched when enabled."""
    results, (keys, ts, vals), totals = _drive(
        "stock", True, sharded, tmp_path, width=1, pooled=pooled,
        splitk=splitk)
    want = _oracle_stock(keys, ts, vals)
    assert set(results) == set(want)
    for wid, w in want.items():
        got = results[wid]
        present = w["min"] < np.inf
        np.testing.assert_allclose(got["mean"][present],
                                   w["mean"][present],
                                   rtol=2e-4, atol=2e-4, err_msg=str(wid))
        np.testing.assert_allclose(got["min"][present], w["min"][present],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got["max"][present], w["max"][present],
                                   rtol=1e-5, atol=1e-5)
    assert totals.ingested == N_EVENTS
    if splitk and (pooled or (sharded and len(jax.devices()) > 1)):
        assert totals.splitk_launches > 0
    if not splitk:
        assert totals.splitk_launches == 0


@pytest.mark.parametrize("splitk", [0, 8])
def test_soak_differential_percentile(tmp_path, splitk):
    """ISSUE 8 satellite: percentile's real fold_batch (sorted-merge of
    per-chunk sorted runs) lets the blocking operator ride the batched
    path — the soak matrix no longer needs a fallback axis for it."""
    results, (keys, ts, vals), totals = _drive(
        "percentile", True, False, tmp_path, width=1, pooled=True,
        splitk=splitk)
    want = _oracle_percentile(keys, ts, vals)
    assert set(results) == set(want)
    for wid, w in want.items():
        for q, v in w.items():
            assert results[wid][q] == pytest.approx(v, rel=1e-5,
                                                    abs=1e-5), (wid, q)
    assert totals.ingested == N_EVENTS
    assert totals.batch_executions > 0       # percentile batched for real
    if splitk:
        assert totals.splitk_launches > 0


# --------------------------------------------------------------------------
# chaos axis (ISSUE 9): the full soak under injected store faults
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pipelined", [True, False])
def test_soak_differential_chaos_faults(tmp_path, pipelined):
    """ISSUE 9 tentpole: the soak with >=5% injected store faults on the
    whole data path (get/put/commit/readahead). The retry layer absorbs
    every transient (max_consecutive=2 < io_retry_limit makes recovery
    deterministic), the degradation ladder sheds speculative work first,
    and final results still match the never-failing oracle exactly:
    zero lost windows, zero lost events."""
    results, (keys, ts, vals), totals = _drive(
        "average", True, False, tmp_path, pooled=True,
        pipelined=pipelined, fault_rate=0.25, fault_seed=77)
    want = _oracle_average(keys, ts, vals)
    # oracle parity: identical window set, identical answers
    assert set(results) == set(want)
    for wid in want:
        assert results[wid] == pytest.approx(want[wid], rel=2e-4,
                                             abs=2e-4), wid
    assert totals.ingested == N_EVENTS          # zero lost events
    # the chaos really happened, and the retry layer really absorbed it
    assert totals.injected_faults > 100
    assert totals.io_retries > 0
    assert totals.io_gave_up == 0               # exact, by construction
    assert totals.io_staged_blocks > 0          # demand traffic survived
    # the ladder engaged, and engaged bottom-up: speculative readahead is
    # always the first thing shed, never demand traffic
    assert totals.ladder_transitions, "breaker never engaged"
    assert totals.ladder_transitions[0] == (0, 1)
    for frm, to in totals.ladder_transitions:
        assert abs(to - frm) == 1               # one rung at a time
    assert totals.shed_readahead_drives > 0
    # backpressure deferral (rung 4) may or may not be reached; if it
    # was, every deferred event must have been readmitted
    assert totals.deferred_events == totals.readmitted_events


def test_soak_differential_chaos_restart(tmp_path):
    """ISSUE 9 tentpole: a *permanent* store failure poisons the engine
    mid-run; ``EngineRecovery`` restores from the last manifest
    checkpoint (store reopen = WAL replay), the ledger replays events
    emitted after that checkpoint, and the run finishes with oracle
    parity -- better late than never, even through a restart."""
    from repro.core.buckets import Tier
    from repro.core.pipeline import PipelineError
    from repro.core.staging import StagingError
    from repro.storage import make_store

    store_dir = tmp_path / "chaos"
    inj = FaultInjector(seed=5,
                        rates={op: 0.05 for op in _CHAOS_OPS},
                        max_consecutive=2)

    def factory():
        inner = make_store("log", store_dir, segment_bytes=128 << 10)
        aion = AionConfig(block_size=256, batched_execution=True,
                          block_pool=True, pipelined_execution=True,
                          store_segment_bytes=128 << 10,
                          io_retry_backoff=0.0,
                          breaker_error_threshold=4)
        eng = StreamEngine(
            assigner=TumblingWindows(WINDOW),
            operator=make_operator("average", aion.block_size, 1),
            aion=aion, value_width=1,
            cleanup=_cleanup(),
            trigger=DeltaTTrigger(executions=2),
            # tiny budgets: even this short run spills to storage, so
            # the poisoned `get` is guaranteed to be on the fold path
            device_budget_bytes=1 << 16,
            host_budget_bytes=1 << 15,
            spill_dir=store_dir,
            store=FaultyBlockStore(inner, inj),
        )
        eng._fault_injector = inj
        return eng

    recovery = EngineRecovery(factory, max_restarts=3)
    rng = np.random.default_rng(SEED)
    eng = factory()
    n_events, chunk = 6000, 500
    ledger = []            # (start_index, batch, now): replay source
    all_events = []
    now, wm, emitted, chunks = 0.0, 0.0, 0, 0
    crashed = False

    def emit_chunk():
        nonlocal now, wm, emitted, chunks
        n = min(chunk, n_events - emitted)
        u = rng.random(n)
        delay = np.where(u < 0.65, rng.uniform(0.0, 2.0, n),
                         rng.uniform(0.0, MAX_LATE, n))
        ts = np.maximum(now - delay, 0.0)
        batch = EventBatch(rng.integers(0, 8, n), ts,
                           rng.normal(size=(n, 1)).astype(np.float32))
        all_events.append((batch.keys.copy(), batch.timestamps.copy(),
                           batch.values.copy()))
        ledger.append((emitted, batch, now))
        eng.ingest(batch, now)
        emitted += n
        chunks += 1
        if rng.random() < 0.7:
            wm = max(wm, now - rng.uniform(0.0, 5.0))
            eng.advance_watermark(wm, now)
        eng.poll(now)
        now += rng.uniform(1.0, 4.0)

    while emitted < n_events:
        emit_chunk()
        if chunks % 3 == 0:
            with inj.paused():          # checkpoints run clean
                recovery.checkpoint(eng, token=(emitted, now, wm))
        if not crashed and emitted >= n_events // 2:
            crashed = True
            # push all engine state to the persistent tier (cleanly), so
            # the next fold round MUST read through the store...
            with inj.paused():
                if eng.pipeline is not None:
                    eng.pipeline.drain()
                eng.io.drain()
                for st in eng.windows.values():
                    for blk in list(st.blocks):
                        if blk.tier == Tier.DEVICE:
                            eng.io.destage_block_sync(blk)
                eng.io.spill_blocks_sync(
                    [b for st in eng.windows.values() for b in st.blocks
                     if b.tier == Tier.HOST and b.fill > 0])
            # ...then poison it: every `get` now fails *permanently* --
            # the retry budget must NOT mask it (honest surfacing), the
            # round retry must NOT win, shutdown must raise
            inj.poison(("get",))
            with pytest.raises((PipelineError, StagingError)):
                eng.advance_watermark(now + MAX_LATE, now)
                eng.poll(now)
                eng.close()
            # the engine is dead; tear down its I/O cleanly and restore
            inj.heal()
            eng.pipeline.close()
            eng.io.drain(timeout=30.0)
            eng.io.shutdown()
            with inj.paused():
                eng, (ck_emitted, ck_now, ck_wm) = recovery.restore()
            now, wm = max(now, ck_now), ck_wm
            # better late than never: replay everything the checkpoint
            # does not cover (events land late, the engine folds them)
            for start, batch, b_now in ledger:
                if start >= ck_emitted:
                    eng.ingest(batch, now)
            eng.poll(now)

    assert crashed and recovery.restarts == 1
    wm = now + MAX_LATE
    eng.advance_watermark(wm, now)
    for t in np.linspace(now, now + 70.0, 8):
        eng.poll(t)
    _final_sweep(eng, now + 70.0)
    results = dict(eng.results)
    assert eng.io.stats["gave_up"] == 0
    assert eng.metrics.ingested > 0
    eng.close()

    keys = np.concatenate([k for k, _, _ in all_events])
    tss = np.concatenate([t for _, t, _ in all_events])
    vals = np.concatenate([v for _, _, v in all_events])
    want = _oracle_average(keys, tss, vals)
    assert set(results) == set(want)            # zero lost windows
    for wid in want:
        assert results[wid] == pytest.approx(want[wid], rel=2e-4,
                                             abs=2e-4), wid
