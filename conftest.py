# Ensure the repo root (for `import benchmarks`) is importable regardless
# of whether tests run via `pytest` or `python -m pytest`.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
