"""Registry exporters: Prometheus text exposition, JSON snapshot, and an
optional ``jax.profiler`` trace-annotation hook for fold launches."""
from __future__ import annotations

import contextlib
import json
from typing import Dict

from .registry import Histogram, MetricsRegistry, _HistogramChild

__all__ = ["to_prometheus", "to_json", "profiler_annotation"]


def _fmt_labels(labelnames, labelvalues) -> str:
    if not labelvalues:
        return ""
    body = ",".join(f'{n}="{v}"' for n, v in zip(labelnames, labelvalues))
    return "{" + body + "}"


def to_prometheus(registry: MetricsRegistry) -> str:
    """Prometheus text exposition format (v0.0.4) for the whole registry."""
    lines = []
    for fam in registry.families():
        children = fam.children()
        if not children:
            continue
        name = fam.name
        if fam.kind == "counter" and not name.endswith("_total"):
            name += "_total"
        if fam.help:
            lines.append(f"# HELP {name} {fam.help}")
        lines.append(f"# TYPE {name} {fam.kind}")
        for child in children:
            labels = _fmt_labels(fam.labelnames, child.labels)
            if isinstance(child, _HistogramChild):
                acc = 0
                for bound, n in zip(fam.buckets, child.counts):
                    acc += n
                    lb = _fmt_labels(fam.labelnames + ("le",),
                                     child.labels + (repr(float(bound)),))
                    lines.append(f"{name}_bucket{lb} {acc}")
                lb = _fmt_labels(fam.labelnames + ("le",),
                                 child.labels + ("+Inf",))
                lines.append(f"{name}_bucket{lb} {child.count}")
                lines.append(f"{name}_sum{labels} {child.sum}")
                lines.append(f"{name}_count{labels} {child.count}")
            else:
                lines.append(f"{name}{labels} {child.value}")
    for cname, value in sorted(registry.collect_callbacks().items()):
        lines.append(f"# TYPE {cname} gauge")
        lines.append(f"{cname} {value}")
    return "\n".join(lines) + "\n"


def to_json(registry: MetricsRegistry, indent=None) -> str:
    """JSON rendering of ``registry.snapshot()``."""
    return json.dumps(registry.snapshot(), indent=indent, sort_keys=True,
                      default=str)


@contextlib.contextmanager
def profiler_annotation(name: str, enabled: bool = True):
    """Wrap a region in ``jax.profiler.TraceAnnotation`` when available.

    No-op when disabled or when jax/profiler is unimportable, so callers can
    wrap fold launches unconditionally and gate with a config knob.
    """
    if not enabled:
        yield
        return
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # pragma: no cover - depends on jax build
        yield
        return
    with TraceAnnotation(name):
        yield
