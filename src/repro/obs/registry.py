"""Typed metrics registry: Counter / Gauge / Histogram families with labels.

One :class:`MetricsRegistry` instance is shared by every component of an
engine stack (engine, I/O scheduler, transfer executor, store, block pool,
health ladder, pipeline).  Components register *families* by name; a family
with label names fans out into per-label-value *children* (e.g. one
``aion_io_tasks_total`` child per ``(tenant, class)`` pair).

Two adapters preserve the legacy telemetry surfaces on top of the registry:

* :class:`StatsMap` — a ``MutableMapping`` drop-in for the old ``.stats``
  dicts (``stats["errors"] += 1`` and ``stats["last_error"]`` keep working,
  but numeric entries are registry instruments and ``inc()`` is atomic).
* ``EngineMetrics`` (in ``core/engine.py``) — attribute access routed onto
  registry instruments via ``__getattr__`` / ``__setattr__``.

All instrument mutation is guarded by a per-family lock, so increments from
pipeline workers and I/O executor threads cannot lose updates.
"""
from __future__ import annotations

import bisect
import threading
from typing import (Callable, Dict, Iterator, List, Mapping, MutableMapping,
                    Optional, Sequence, Tuple)

__all__ = [
    "BoundedSeries",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsMap",
]


class BoundedSeries(list):
    """List that sheds its oldest half once it reaches ``maxlen``.

    ``maxlen <= 0`` means unbounded (plain list behaviour).  Moved here from
    ``core/engine.py`` so every telemetry surface can share it; the engine
    re-exports it for backwards compatibility.
    """

    def __init__(self, maxlen: int = 0, iterable: Sequence = ()) -> None:
        super().__init__(iterable)
        self.maxlen = int(maxlen)

    def append(self, item) -> None:  # type: ignore[override]
        super().append(item)
        if self.maxlen > 0 and len(self) >= self.maxlen:
            del self[: len(self) // 2]

    def extend(self, items) -> None:  # type: ignore[override]
        for item in items:
            self.append(item)


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------

class _Child:
    """A single (family, label-values) time series."""

    __slots__ = ("_family", "labels", "_value")

    def __init__(self, family: "_Family", labels: Tuple[str, ...]) -> None:
        self._family = family
        self.labels = labels
        self._value = 0

    @property
    def value(self):
        return self._value

    def inc(self, amount=1) -> None:
        if amount < 0 and self._family.kind == "counter":
            raise ValueError(
                f"{self._family.name}: counters only increase "
                f"(inc({amount!r}))")
        with self._family._lock:
            self._value += amount

    def set(self, value) -> None:
        with self._family._lock:
            self._value = value

    def get(self):
        return self._value


class _Family:
    """A named instrument family; children keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = ()) -> None:
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], _Child] = {}

    def _make_child(self, key: Tuple[str, ...]) -> _Child:
        return _Child(self, key)

    def labels(self, *values, **kw) -> _Child:
        if kw:
            values = tuple(str(kw.get(n, "")) for n in self.labelnames)
        else:
            values = tuple(str(v) for v in values)
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got {values}")
        with self._lock:
            child = self._children.get(values)
            if child is None:
                child = self._make_child(values)
                self._children[values] = child
            return child

    @property
    def default(self) -> _Child:
        """Unlabelled child (only valid when the family has no labels)."""
        return self.labels()

    def children(self) -> List[_Child]:
        with self._lock:
            return list(self._children.values())

    # Convenience pass-throughs for label-less families -------------------
    def inc(self, amount=1) -> None:
        self.labels().inc(amount)

    def set(self, value) -> None:
        self.labels().set(value)

    def get(self):
        return self.labels().get()

    @property
    def value(self):
        return self.labels().value


class Counter(_Family):
    kind = "counter"


class Gauge(_Family):
    kind = "gauge"


DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class _HistogramChild(_Child):
    __slots__ = ("counts", "sum", "count")

    def __init__(self, family: "_Family", labels: Tuple[str, ...]) -> None:
        super().__init__(family, labels)
        self.counts = [0] * (len(family.buckets) + 1)  # type: ignore[attr-defined]
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        fam = self._family
        idx = bisect.bisect_left(fam.buckets, value)  # type: ignore[attr-defined]
        with fam._lock:
            self.counts[idx] += 1
            self.sum += value
            self.count += 1

    def snapshot(self) -> Dict[str, float]:
        with self._family._lock:
            return {"count": self.count, "sum": self.sum}


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))

    def _make_child(self, key: Tuple[str, ...]) -> _HistogramChild:
        return _HistogramChild(self, key)

    def observe(self, value: float) -> None:
        self.labels().observe(value)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of instrument families plus poll callbacks.

    ``register_callback(fn)`` adds a zero-arg callable returning a flat
    ``{metric_name: value}`` dict polled at snapshot time — used for
    occupancy-style gauges (pool free slots, budget bytes) that are cheaper
    to compute on demand than to maintain incrementally.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}
        self._callbacks: List[Tuple[str, Callable[[], Mapping[str, float]]]] = []

    def _instrument(self, cls, name: str, help: str,
                    labelnames: Sequence[str], **kw) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(name, help, tuple(labelnames), **kw)
                self._families[name] = fam
            else:
                if not isinstance(fam, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as {fam.kind}")
                if fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered with labels "
                        f"{tuple(labelnames)} != {fam.labelnames}")
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._instrument(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._instrument(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._instrument(Histogram, name, help, labelnames,
                                buckets=buckets)

    def register_callback(self, fn: Callable[[], Mapping[str, float]],
                          group: str = "gauges") -> None:
        with self._lock:
            self._callbacks.append((group, fn))

    def families(self) -> List[_Family]:
        with self._lock:
            return list(self._families.values())

    def collect_callbacks(self) -> Dict[str, float]:
        with self._lock:
            callbacks = list(self._callbacks)
        out: Dict[str, float] = {}
        for _group, fn in callbacks:
            try:
                out.update(fn())
            except Exception:  # pragma: no cover - snapshot must not raise
                continue
        return out

    def snapshot(self) -> Dict[str, object]:
        """Flat {name{labels}: value} view of every family + callbacks."""
        out: Dict[str, object] = {}
        for fam in self.families():
            for child in fam.children():
                key = fam.name
                if child.labels:
                    key += "{" + ",".join(
                        f'{n}="{v}"'
                        for n, v in zip(fam.labelnames, child.labels)
                    ) + "}"
                if isinstance(child, _HistogramChild):
                    out[key] = child.snapshot()
                else:
                    out[key] = child.value
        out.update(self.collect_callbacks())
        return out


# ---------------------------------------------------------------------------
# Legacy `.stats` dict adapter
# ---------------------------------------------------------------------------

class _TenantCounterView(Mapping):
    """Read view of a labelled counter family, keyed by one label value.

    Backs ``executor.stats["tenant_executed"]`` — reads behave like the old
    ``{tenant: count}`` dict; writes go through ``StatsMap.inc_labeled``.
    """

    def __init__(self, family: Counter, fixed: Dict[str, str],
                 keyed_by: str) -> None:
        self._family = family
        self._fixed = dict(fixed)
        self._keyed_by = keyed_by
        self._key_idx = family.labelnames.index(keyed_by)
        self._fixed_idx = [
            (i, self._fixed[n]) for i, n in enumerate(family.labelnames)
            if n in self._fixed
        ]

    def _matches(self, child: _Child) -> bool:
        return all(child.labels[i] == v for i, v in self._fixed_idx)

    def __getitem__(self, key: str):
        key = str(key)
        for child in self._family.children():
            if self._matches(child) and child.labels[self._key_idx] == key:
                return child.value
        raise KeyError(key)

    def __iter__(self) -> Iterator[str]:
        for child in self._family.children():
            if self._matches(child):
                yield child.labels[self._key_idx]

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def inc(self, key: str, amount=1) -> None:
        labels = dict(self._fixed)
        labels[self._keyed_by] = str(key)
        self._family.labels(**labels).inc(amount)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return repr(dict(self))


class StatsMap(MutableMapping):
    """``.stats`` drop-in whose numeric entries live in a registry.

    Numeric keys read/write registry instruments; non-numeric entries
    (``last_error``) and mapping values (``tenant_executed``) are stored in
    ``_raw``.

    ``stats["k"] += 1`` (read-modify-write) is only atomic when the caller
    holds its own lock; hot multi-threaded paths should use :meth:`inc`.
    Unknown keys assigned a number auto-register a counter — this keeps the
    stores' ``stats.update({...})`` extension pattern working.
    """

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 labels: Optional[Dict[str, str]] = None) -> None:
        self._registry = registry
        self._prefix = prefix
        self._labels = dict(labels or {})
        self._labelnames = tuple(self._labels)
        self._children: Dict[str, _Child] = {}
        self._raw: Dict[str, object] = {}
        self._order: List[str] = []

    # -- wiring ----------------------------------------------------------
    def _metric_name(self, key: str) -> str:
        return f"{self._prefix}_{key}"

    def register(self, key: str, kind: str = "counter", help: str = "") -> None:
        if key in self._children:
            return
        cls = _KINDS[kind]
        fam = self._registry._instrument(
            cls, self._metric_name(key), help, self._labelnames)
        self._children[key] = fam.labels(**self._labels) if self._labels \
            else fam.labels()
        if key not in self._order:
            self._order.append(key)

    def register_many(self, keys: Sequence[str], kind: str = "counter") -> None:
        for key in keys:
            self.register(key, kind)

    def register_raw(self, key: str, value=None) -> None:
        self._raw[key] = value
        if key not in self._order:
            self._order.append(key)

    def register_tenant_view(self, key: str, family: Counter,
                             keyed_by: str = "tenant") -> None:
        self._raw[key] = _TenantCounterView(family, self._labels, keyed_by)
        if key not in self._order:
            self._order.append(key)

    # -- mapping protocol -------------------------------------------------
    def __getitem__(self, key: str):
        child = self._children.get(key)
        if child is not None:
            return child.value
        if key in self._raw:
            return self._raw[key]
        raise KeyError(key)

    def __setitem__(self, key: str, value) -> None:
        child = self._children.get(key)
        if child is None:
            if key in self._raw or not isinstance(value, (int, float)) \
                    or isinstance(value, bool):
                self._raw[key] = value
                if key not in self._order:
                    self._order.append(key)
                return
            self.register(key, "counter")
            child = self._children[key]
        child.set(value)

    def __delitem__(self, key: str) -> None:
        if key in self._raw:
            del self._raw[key]
            self._order.remove(key)
            return
        raise KeyError(f"cannot delete instrument-backed key {key!r}")

    def __contains__(self, key) -> bool:  # type: ignore[override]
        return key in self._children or key in self._raw

    def __iter__(self) -> Iterator[str]:
        return iter(self._order)

    def __len__(self) -> int:
        return len(self._order)

    def keys(self):
        return list(self._order)

    def values(self):
        return [self[k] for k in self._order]

    def items(self):
        return [(k, self[k]) for k in self._order]

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def update(self, other=(), **kw) -> None:  # type: ignore[override]
        if hasattr(other, "items"):
            other = other.items()
        for k, v in other:
            self[k] = v
        for k, v in kw.items():
            self[k] = v

    def setdefault(self, key, default=None):
        if key in self:
            return self[key]
        self[key] = default
        return self[key]

    def copy(self) -> Dict[str, object]:
        out = {}
        for k in self._order:
            v = self[k]
            out[k] = dict(v) if isinstance(v, Mapping) else v
        return out

    def __eq__(self, other) -> bool:  # type: ignore[override]
        if isinstance(other, Mapping) and not isinstance(other, StatsMap):
            return self.copy() == dict(other)
        return self is other

    def __ne__(self, other) -> bool:  # type: ignore[override]
        return not self.__eq__(other)

    __hash__ = None  # type: ignore[assignment]

    def __repr__(self) -> str:
        return f"StatsMap({self.copy()!r})"

    # -- atomic helpers ---------------------------------------------------
    def inc(self, key: str, amount=1) -> None:
        child = self._children.get(key)
        if child is None:
            self.register(key, "counter")
            child = self._children[key]
        child.inc(amount)

    def set(self, key: str, value) -> None:
        self[key] = value

    def inc_labeled(self, key: str, label_value: str, amount=1) -> None:
        view = self._raw[key]
        view.inc(label_value, amount)
