"""Unified observability layer: metrics registry, structured tracing, and
exporters shared by the engine, I/O scheduler, stores, pool, and pipeline."""
from .registry import (BoundedSeries, Counter, Gauge, Histogram,
                       MetricsRegistry, StatsMap)
from .trace import NULL_SPAN, NullSpan, Span, Tracer
from .export import profiler_annotation, to_json, to_prometheus

__all__ = [
    "BoundedSeries", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "StatsMap", "NULL_SPAN", "NullSpan", "Span", "Tracer",
    "profiler_annotation", "to_json", "to_prometheus",
]
