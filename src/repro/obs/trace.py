"""Structured tracing with explicit parent handoff across threads.

Spans carry ``(trace_id, span_id, parent_id)``; a parent span object is
passed *explicitly* to :meth:`Tracer.child` — never via thread-locals — so
a fold round executed on the pipeline worker can parent to the
watermark-advance span created on the caller thread, and an I/O task span
can parent to whichever engine span submitted it.

Sampling happens once, at the root: :meth:`Tracer.root` flips a seeded
coin at ``sample_rate``; children inherit the decision from their parent.
Unsampled (and all, when ``sample_rate <= 0``) spans are the module
singleton :data:`NULL_SPAN`, whose every method is a no-op — the hot-path
cost of disabled tracing is one attribute read and one predictable branch.

Finished spans land in a bounded ring buffer (oldest dropped) and export
as JSON-lines via :meth:`Tracer.export_jsonl`.
"""
from __future__ import annotations

import itertools
import json
import random
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "NullSpan", "NULL_SPAN", "Tracer"]


class NullSpan:
    """No-op span; stands in for every unsampled span."""

    __slots__ = ()
    sampled = False
    trace_id = 0
    span_id = 0

    def event(self, name: str, **attrs) -> None:
        pass

    def set(self, **attrs) -> None:
        pass

    def end(self, **attrs) -> None:
        pass

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def __bool__(self) -> bool:
        return False


NULL_SPAN = NullSpan()


class Span:
    """A sampled span. Mutate only from the thread currently running it;
    hand it to another thread as a *parent* (read-only) freely."""

    __slots__ = ("_tracer", "name", "trace_id", "span_id", "parent_id",
                 "t0", "attrs", "events", "thread", "_ended")
    sampled = True

    def __init__(self, tracer: "Tracer", name: str, trace_id: int,
                 span_id: int, parent_id: Optional[int],
                 attrs: Dict[str, object]) -> None:
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.time()
        self.attrs = attrs
        self.events: List[Dict[str, object]] = []
        self.thread = threading.current_thread().name
        self._ended = False

    def event(self, name: str, **attrs) -> None:
        rec: Dict[str, object] = {"name": name,
                                  "t": round(time.time() - self.t0, 6)}
        if attrs:
            rec.update(attrs)
        self.events.append(rec)

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def end(self, **attrs) -> None:
        if self._ended:
            return
        self._ended = True
        # re-stamp with the finishing thread: task spans are created on
        # the submitter thread but run (and end) on the executor, and the
        # executing thread is the one cross-thread reconstruction needs
        self.thread = threading.current_thread().name
        if attrs:
            self.attrs.update(attrs)
        self._tracer._finish(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()

    def __bool__(self) -> bool:
        return True


class Tracer:
    """Span factory + bounded ring of finished span records.

    ``sample_rate`` in [0, 1] gates *root* spans only; the decision then
    flows down the parent chain. ``seed`` makes sampling reproducible.
    """

    def __init__(self, sample_rate: float = 0.0, capacity: int = 4096,
                 seed: int = 0) -> None:
        self.sample_rate = float(sample_rate)
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=max(1, self.capacity))
        self._rng = random.Random(seed)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self.spans_started = 0
        self.spans_finished = 0
        self.spans_dropped = 0

    @property
    def enabled(self) -> bool:
        return self.sample_rate > 0.0

    # -- span creation ----------------------------------------------------
    def root(self, name: str, **attrs):
        """Start a new trace; samples at ``sample_rate``."""
        if self.sample_rate <= 0.0:
            return NULL_SPAN
        with self._lock:
            if self.sample_rate < 1.0 and self._rng.random() >= self.sample_rate:
                return NULL_SPAN
            trace_id = span_id = next(self._ids)
            self.spans_started += 1
        return Span(self, name, trace_id, span_id, None, dict(attrs))

    def child(self, parent, name: str, **attrs):
        """Continue ``parent``'s trace; NULL when the parent is unsampled."""
        if parent is None or not parent.sampled:
            return NULL_SPAN
        with self._lock:
            span_id = next(self._ids)
            self.spans_started += 1
        return Span(self, name, parent.trace_id, span_id, parent.span_id,
                    dict(attrs))

    # -- ring -------------------------------------------------------------
    def _finish(self, span: Span) -> None:
        rec = {
            "name": span.name,
            "trace": span.trace_id,
            "span": span.span_id,
            "parent": span.parent_id,
            "t0": round(span.t0, 6),
            "dur": round(time.time() - span.t0, 6),
            "thread": span.thread,
            "attrs": span.attrs,
            "events": span.events,
        }
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.spans_dropped += 1
            self._ring.append(rec)
            self.spans_finished += 1

    def records(self) -> List[Dict[str, object]]:
        with self._lock:
            return list(self._ring)

    def export_jsonl(self) -> str:
        return "\n".join(json.dumps(rec, default=str)
                         for rec in self.records())

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "sample_rate": self.sample_rate,
                "spans_started": self.spans_started,
                "spans_finished": self.spans_finished,
                "spans_dropped": self.spans_dropped,
                "ring_len": len(self._ring),
                "ring_capacity": self.capacity,
            }
