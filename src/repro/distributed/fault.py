"""Fault tolerance: heartbeats, straggler backup execution, restart.

* ``HeartbeatMonitor`` — worker liveness with configurable timeout; the
  launcher polls ``dead_workers()`` and triggers checkpoint restart with a
  shrunken mesh (train/elastic.py) when a pod drops.
* ``BackupExecutor`` — straggler mitigation for window re-executions and
  eval tasks: a task slower than ``deadline_factor`` x its EWMA latency
  gets a backup issued; first result wins. Safe because AION window
  (re-)execution is a pure function of bucket contents (idempotent).
* ``RestartManager`` — crash/restore loop glue used by launch/train.py:
  on failure, restore the latest complete checkpoint and resume at the
  recorded step (engine state — watermarks, lateness histogram, bucket
  manifests — restores alongside model state).
* ``EngineRecovery`` — the streaming-path restart glue: hold the latest
  manifest checkpoint of a ``StreamEngine``; when the engine is poisoned
  (a permanent store failure killed a fold round), build a fresh engine
  over the re-opened store — reopen IS the WAL replay — and restore the
  checkpointed bucket state into it. The caller replays its event ledger
  from the checkpoint token.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class HeartbeatMonitor:
    def __init__(self, timeout: float = 30.0):
        self.timeout = timeout
        self._last: Dict[str, float] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str, now: Optional[float] = None) -> None:
        with self._lock:
            self._last[worker] = now if now is not None else time.time()

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()
        with self._lock:
            return [w for w, t in self._last.items()
                    if now - t > self.timeout]

    def alive_workers(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()
        with self._lock:
            return [w for w, t in self._last.items()
                    if now - t <= self.timeout]


@dataclass
class BackupStats:
    launched: int = 0
    backups_issued: int = 0
    backup_wins: int = 0


class BackupExecutor:
    """Run idempotent tasks with deadline-triggered backup copies."""

    def __init__(self, workers: int = 4, deadline_factor: float = 3.0,
                 min_deadline: float = 0.05):
        self._pool = ThreadPoolExecutor(max_workers=workers)
        self.deadline_factor = deadline_factor
        self.min_deadline = min_deadline
        self._ewma: Optional[float] = None
        self.stats = BackupStats()

    def _observe(self, dt: float) -> None:
        self._ewma = dt if self._ewma is None else \
            0.7 * self._ewma + 0.3 * dt

    def run(self, fn: Callable[[], Any]) -> Any:
        """Execute fn; if it exceeds the deadline, race a backup."""
        self.stats.launched += 1
        t0 = time.time()
        primary = self._pool.submit(fn)
        deadline = max((self._ewma or 0.0) * self.deadline_factor,
                       self.min_deadline)
        done, _ = wait([primary], timeout=deadline)
        if done:
            self._observe(time.time() - t0)
            return primary.result()
        # straggler: issue a backup, take whichever finishes first
        self.stats.backups_issued += 1
        backup = self._pool.submit(fn)
        done, _ = wait([primary, backup], return_when=FIRST_COMPLETED)
        winner = done.pop()
        if winner is backup:
            self.stats.backup_wins += 1
        self._observe(time.time() - t0)
        return winner.result()

    def shutdown(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)


class EngineRecovery:
    """Checkpoint/restore loop for one streaming engine.

    ``factory`` builds a FRESH engine over the same (re-opened) store
    directory — the log store's open runs WAL recovery, truncating any
    torn tail, so the records a manifest checkpoint references are
    exactly the acknowledged ones. ``checkpoint`` snapshots the engine's
    bucket manifests plus an opaque caller *token* (typically the count
    of events already emitted to the engine) so the caller knows where
    to resume its ledger replay after ``restore``."""

    def __init__(self, factory: Callable[[], Any], max_restarts: int = 3):
        self.factory = factory
        self.max_restarts = max_restarts
        self.restarts = 0
        self._snap: Optional[Dict[str, Any]] = None
        self._token: Any = None

    @property
    def has_checkpoint(self) -> bool:
        return self._snap is not None

    def checkpoint(self, engine, token: Any = None) -> None:
        """Snapshot ``engine`` (manifest checkpoint: store records are
        referenced, not copied) and remember the resume token."""
        self._snap = engine.checkpoint_state(include_stored_data=False)
        self._token = token

    def restore(self):
        """Build a fresh engine from the factory and load the latest
        checkpoint into it; returns ``(engine, token)``. Raises after
        ``max_restarts`` — a crash loop must surface, not spin."""
        if self._snap is None:
            raise RuntimeError("EngineRecovery: no checkpoint taken yet")
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"EngineRecovery: exceeded max_restarts="
                f"{self.max_restarts}")
        engine = self.factory()
        engine.restore_state(self._snap)
        return engine, self._token


class RestartManager:
    """Run a step loop with crash recovery from the latest checkpoint."""

    def __init__(self, save_every: int = 50, max_restarts: int = 10):
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, *, init_state: Callable[[], Any],
            restore: Callable[[], Optional[Any]],
            step_fn: Callable[[Any, int], Any],
            save: Callable[[Any, int], None],
            num_steps: int) -> Any:
        """Generic loop: restore-or-init, step, periodic save; on exception
        restart from the last checkpoint (up to max_restarts)."""
        while True:
            restored = restore()
            state, start = (restored if restored is not None
                            else (init_state(), 0))
            try:
                for step in range(start, num_steps):
                    state = step_fn(state, step)
                    if (step + 1) % self.save_every == 0 or \
                            step + 1 == num_steps:
                        save(state, step + 1)
                return state
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
