"""Logical-axis sharding.

Every parameter and activation in the model code is annotated with *logical*
axis names; a rule table maps logical axes to physical mesh axes. The rule
table is derived per (arch, mesh) by divisibility checks, so the same model
code serves the 1-device smoke tests, the 256-chip single-pod mesh, and the
512-chip multi-pod mesh.

Parallelism scheme (DESIGN.md §4):
  * ``batch``   -> ('pod', 'data') when divisible, else 'data' — data parallel
  * ``fsdp``    -> 'data' — ZeRO-3 style parameter sharding on the non-TP dim
  * ``heads`` / ``kv_heads`` / ``mlp`` / ``vocab`` / ``experts`` / ``ssd_heads``
                -> 'model' — tensor / expert parallelism (only when divisible)
  * the ``pod`` axis is pure data parallelism: params are replicated across
    pods; gradients all-reduce over ('pod', 'data').

Archs whose head counts don't divide the model axis (hymba 25H, starcoder2
36H) fall back to replicated-attention + TP-MLP; recorded per-arch by
``sharding_profile`` and surfaced in the dry-run report.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig

# Logical axis vocabulary.
BATCH = "batch"          # global batch dim
SEQ = "seq"              # sequence dim (sharded only for context-parallel opt)
EMBED = "embed"          # d_model dim
FSDP = "fsdp"            # parameter dim sharded ZeRO-style over 'data'
HEADS = "heads"          # query heads
KV_HEADS = "kv_heads"    # stored KV heads (possibly repeated for divisibility)
KV_PARAM_HEADS = "kv_param_heads"  # true KV heads on params (no repeat)
KV_SEQ = "kv_seq"        # KV-cache sequence dim (context-parallel decode)
HEAD_DIM = "head_dim"
MLP = "mlp"              # d_ff dim
VOCAB = "vocab"          # vocabulary dim
EXPERTS = "experts"      # MoE expert dim
SSD_HEADS = "ssd_heads"  # mamba2/SSD head dim
SSD_STATE = "ssd_state"
LAYERS = "layers"        # stacked-layer dim (never sharded)
NULL = None


@dataclass(frozen=True)
class ShardingProfile:
    """Which TP dims are actually sharded for a given (arch, mesh)."""
    attn_tp: bool            # heads over 'model'
    mlp_tp: bool             # d_ff over 'model'
    vocab_tp: bool           # padded vocab over 'model'
    expert_tp: bool          # experts over 'model'
    ssd_tp: bool             # SSD heads over 'model'
    kv_repeat: int           # stored-KV replication factor for divisibility
    batch_axes: Tuple[str, ...]
    kv_seq_shard: bool = False  # context-parallel decode cache (seq over model)
    notes: Tuple[str, ...] = ()


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-gated ``shard_map``.

    ``jax.shard_map`` (with its ``check_vma`` kwarg) only exists on newer
    jax; older releases ship it as ``jax.experimental.shard_map.shard_map``
    with the equivalent knob spelled ``check_rep``. Model code calls this
    helper so it runs on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


# Default mesh axis for the streaming engine's slot-sharded batched fold
# (``AionConfig.slot_sharding``): window slots partition across a 1-D mesh
# with NO cross-device reduction — slots are disjoint, so each device owns
# a contiguous slot range outright (psum-free).
SLOT_AXIS = "slots"


def shard_of_window(start: float, end: float, num_shards: int) -> int:
    """Stable window -> shard assignment for the pooled sharded fold.

    The block pool places a window's blocks in per-device slot ranges at
    STAGING time — before any batch composition is known — so placement
    must be a pure function of the window identity, not of the batch.
    Both the staging shard hint and the batch executor's pooled placement
    call this, which is what keeps a window's block-table rows local to
    the shard that owns its arena range. Python's float hash is
    process-stable (PYTHONHASHSEED only perturbs str/bytes).
    """
    if num_shards <= 1:
        return 0
    return int(abs(hash((float(start), float(end))))) % num_shards


def make_slot_mesh(num_devices: int = 0,
                   axis_name: str = SLOT_AXIS) -> Optional[Mesh]:
    """1-D mesh over local devices for slot-sharded window execution.

    ``num_devices == 0`` takes every local device. Returns ``None`` when
    fewer than two devices are available — callers fall back to the
    single-device batched path, which keeps ``slot_sharding=True`` a safe
    no-op on one-device hosts (the tier-1 CPU container).
    """
    devs = jax.devices()
    n = num_devices if num_devices > 0 else len(devs)
    n = min(n, len(devs))
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), (axis_name,))


def _divides(a: int, b: int) -> bool:
    return b > 0 and a > 0 and a % b == 0


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def sharding_profile(cfg: ModelConfig, mesh_cfg: MeshConfig,
                     global_batch: int, seq_len: int = 0,
                     kind: str = "train") -> ShardingProfile:
    axes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    model = axes.get("model", 1)
    data = axes.get("data", 1)
    pod = axes.get("pod", 1)
    notes = []

    # batch: prefer ('pod','data'); drop axes that don't divide.
    batch_axes: Tuple[str, ...]
    if pod > 1 and _divides(global_batch, pod * data):
        batch_axes = ("pod", "data")
    elif _divides(global_batch, data):
        batch_axes = ("data",)
        if pod > 1:
            notes.append("batch not divisible by pod*data; pod idle on batch")
    else:
        batch_axes = ()
        notes.append(f"global_batch={global_batch} not divisible by data={data};"
                     " batch replicated (latency-bound shape)")

    attn_tp = cfg.has_attention and _divides(cfg.num_heads, model)
    if cfg.has_attention and not attn_tp:
        notes.append(f"num_heads={cfg.num_heads} % model={model} != 0: "
                     "attention is DP-only (TP-MLP hybrid fallback)")
    kv_repeat = 1
    if attn_tp:
        if _divides(cfg.num_kv_heads, model):
            kv_repeat = 1
        else:
            # repeat stored KV heads up to the model axis for divisibility
            kv_repeat = model // cfg.num_kv_heads
            if cfg.num_kv_heads * kv_repeat != model:
                # e.g. kv=3, model=16 -> no clean repeat; give up on attn TP
                attn_tp = False
                kv_repeat = 1
                notes.append("kv head repeat not integral; attention DP-only")
            else:
                notes.append(f"stored KV heads repeated x{kv_repeat} "
                             f"({cfg.num_kv_heads}->{model}) for TP divisibility")

    mlp_tp = cfg.d_ff > 0 and _divides(cfg.d_ff, model)
    vocab_tp = _divides(pad_vocab(cfg.vocab_size), model)
    expert_tp = cfg.moe.enabled and _divides(cfg.moe.num_experts, model)
    ssd_tp = False
    if cfg.ssm.enabled:
        d_inner = cfg.ssm.expand * cfg.d_model
        nheads = d_inner // cfg.ssm.head_dim
        ssd_tp = _divides(nheads, model)
        if not ssd_tp:
            notes.append(f"ssd_heads={nheads} % model={model} != 0: SSM DP-only")

    # Context-parallel decode: the decode KV cache is sequence-sharded over
    # the model axis (no head repeat — repeating stored heads inflates the
    # cache 2-16x; seq-sharding divides it by the TP degree instead, with
    # SPMD inserting the cross-shard softmax reductions). Attention *params*
    # keep their head-TP sharding; only stored-KV activations change layout.
    kv_seq_shard = False
    if kind == "decode" and cfg.has_attention:
        kv_repeat = 1
        if cfg.attn_window == 0 and model > 1 and seq_len \
                and seq_len % model == 0:
            kv_seq_shard = True
            notes.append("decode KV cache sequence-sharded over 'model' "
                         "(context-parallel decode, no KV head repeat)")

    return ShardingProfile(
        attn_tp=attn_tp, mlp_tp=mlp_tp, vocab_tp=vocab_tp,
        expert_tp=expert_tp, ssd_tp=ssd_tp, kv_repeat=kv_repeat,
        batch_axes=batch_axes, kv_seq_shard=kv_seq_shard,
        notes=tuple(notes),
    )


def make_rules(cfg: ModelConfig, mesh_cfg: MeshConfig,
               global_batch: int, seq_len: int = 0,
               kind: str = "train") -> Dict[str, Any]:
    """Logical-axis -> physical mesh axis (or None) rule table."""
    prof = sharding_profile(cfg, mesh_cfg, global_batch, seq_len, kind)
    model_size = dict(zip(mesh_cfg.axes, mesh_cfg.shape)).get("model", 1)
    kv_param_tp = prof.attn_tp and cfg.num_kv_heads % max(model_size, 1) == 0
    rules: Dict[str, Any] = {
        BATCH: prof.batch_axes if prof.batch_axes else None,
        SEQ: None,
        EMBED: None,
        FSDP: "data" if "data" in mesh_cfg.axes else None,
        HEADS: "model" if prof.attn_tp else None,
        # stored-KV head activations: head-sharded for train/prefill (via
        # repeat); unsharded for decode (the cache shards on seq instead)
        KV_HEADS: "model" if (prof.attn_tp and kind != "decode") else None,
        KV_PARAM_HEADS: "model" if kv_param_tp else None,
        KV_SEQ: "model" if prof.kv_seq_shard else None,
        HEAD_DIM: None,
        MLP: "model" if prof.mlp_tp else None,
        VOCAB: "model" if prof.vocab_tp else None,
        EXPERTS: "model" if prof.expert_tp else None,
        SSD_HEADS: "model" if prof.ssd_tp else None,
        SSD_STATE: None,
        LAYERS: None,
    }
    return rules


def logical_to_pspec(logical: Tuple[Optional[str], ...],
                     rules: Dict[str, Any]) -> P:
    phys = []
    for ax in logical:
        if ax is None:
            phys.append(None)
        else:
            phys.append(rules.get(ax))
    # trim trailing Nones for tidiness
    while phys and phys[-1] is None:
        phys.pop()
    return P(*phys)


@dataclass
class ShardCtx:
    """Ambient sharding context threaded through model code.

    ``mesh is None`` -> single-device mode: all constraints are no-ops.
    """
    mesh: Optional[Mesh]
    rules: Dict[str, Any] = field(default_factory=dict)
    profile: Optional[ShardingProfile] = None

    def pspec(self, *logical: Optional[str]) -> P:
        return logical_to_pspec(tuple(logical), self.rules)

    def sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*logical))


_LOCAL = threading.local()


def set_ctx(ctx: Optional[ShardCtx]) -> None:
    _LOCAL.ctx = ctx


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_LOCAL, "ctx", None)


@contextlib.contextmanager
def use_ctx(ctx: Optional[ShardCtx]):
    prev = current_ctx()
    set_ctx(ctx)
    try:
        yield ctx
    finally:
        set_ctx(prev)


def constrain(x, *logical: Optional[str]):
    """``with_sharding_constraint`` by logical axes; no-op without a mesh."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = ctx.pspec(*logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def tree_pspecs(spec_tree):
    """Map a tree of logical-axis tuples to PartitionSpecs via the ambient
    context (identity P() tree when no mesh)."""
    ctx = current_ctx()
    rules = ctx.rules if ctx is not None else {}
    return jax.tree.map(
        lambda logical: logical_to_pspec(logical, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
