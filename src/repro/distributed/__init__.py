from repro.distributed.sharding import (
    ShardCtx,
    current_ctx,
    set_ctx,
    use_ctx,
    constrain,
    logical_to_pspec,
    make_rules,
    sharding_profile,
)

__all__ = [
    "ShardCtx", "current_ctx", "set_ctx", "use_ctx", "constrain",
    "logical_to_pspec", "make_rules", "sharding_profile",
]
