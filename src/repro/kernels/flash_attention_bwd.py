"""Pallas TPU kernels: flash attention backward.

Completes the IO-aware attention story (§Perf Cell-A "next lever"): the
backward recomputes score tiles from (q, k, lse) instead of saving the
[Sq, Sk] probability matrix, with fp32 accumulators in VMEM scratch.

Standard two-pass decomposition (FlashAttention-2):
  dq pass : grid (BH, q_blocks, kv_blocks)  — dq[bq] accumulates over kv
  dkv pass: grid (BH, kv_blocks, q_blocks)  — dk/dv[bk] accumulate over q

with  p  = exp(q·kᵀ·scale − lse)
      D  = rowsum(do ⊙ o)
      ds = p ⊙ (do·vᵀ − D)
      dq = scale · ds·k ;  dk = scale · dsᵀ·q ;  dv = pᵀ·do
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(qi, kj, bq, bk, causal, window):
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), bool)
    if causal:
        m &= q_pos >= k_pos
    if window > 0:
        m &= q_pos - k_pos < window
    return m


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, causal, window, block_q, block_k,
               num_kv_blocks):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]                               # [bq]
    delta = delta_ref[0]                           # [bq]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = _mask(qi, kj, block_q, block_k, causal, window)
    p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    acc_scr[...] += jax.lax.dot_general(
        ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(kj == num_kv_blocks - 1)
    def _done():
        dq_ref[0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                block_q, block_k, num_q_blocks):
    kj = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = _mask(qi, kj, block_q, block_k, causal, window)
    p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)      # [bq, bk]
    dv_scr[...] += jax.lax.dot_general(
        p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [bk, d]
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dk_scr[...] += jax.lax.dot_general(
        ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [bk, d]

    @pl.when(qi == num_q_blocks - 1)
    def _done():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, o, do, lse, *, causal=True,
                               window=0, block_q=512, block_k=512,
                               interpret=True
                               ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
    """Flattened-head backward.

    q, o, do: [BH, Sq, D]; k, v: [BH, Sk, D] (heads pre-broadcast for GQA —
    the ops.py wrapper folds groups and sums dk/dv over them);
    lse: [BH, Sq] (fp32, log-sum-exp of scaled scores).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                  # [BH, Sq]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=bq, block_k=bk,
                          num_kv_blocks=nk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, block_q=bq, block_k=bk,
                          num_q_blocks=nq),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=(pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
                   pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0))),
        out_shape=(jax.ShapeDtypeStruct((bh, sk, d), k.dtype),
                   jax.ShapeDtypeStruct((bh, sk, d), v.dtype)),
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
