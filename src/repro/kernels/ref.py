"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` is the numerically-trusted reference the kernels are swept
against in tests (interpret=True on CPU, real Mosaic lowering on TPU).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ref_segment_aggregate(values: jnp.ndarray, segment_ids: jnp.ndarray,
                          num_segments: int, valid: Optional[jnp.ndarray] = None
                          ) -> dict:
    """values [N, W] f32; segment_ids [N] i32 -> per-segment sum/count/min/max.

    Invalid rows (valid==False) contribute nothing.
    """
    n, w = values.shape
    if valid is None:
        valid = jnp.ones((n,), bool)
    sid = jnp.where(valid, segment_ids, num_segments)      # park invalid
    vsum = jax.ops.segment_sum(jnp.where(valid[:, None], values, 0.0),
                               sid, num_segments + 1)[:num_segments]
    cnt = jax.ops.segment_sum(valid.astype(jnp.float32), sid,
                              num_segments + 1)[:num_segments]
    vmin = jax.ops.segment_min(jnp.where(valid[:, None], values, jnp.inf),
                               sid, num_segments + 1)[:num_segments]
    vmax = jax.ops.segment_max(jnp.where(valid[:, None], values, -jnp.inf),
                               sid, num_segments + 1)[:num_segments]
    return {"sum": vsum, "count": cnt, "min": vmin, "max": vmax}


def ref_segment_aggregate_batched(values: jnp.ndarray,
                                  segment_ids: jnp.ndarray,
                                  num_segments: int,
                                  valid: Optional[jnp.ndarray] = None,
                                  slot_ids: Optional[jnp.ndarray] = None,
                                  num_slots: Optional[int] = None) -> dict:
    """values [B, N, W]; segment_ids [B, N]; slot_ids [B] -> per-slot
    sum/count/min/max of shape [num_slots, num_segments, ...].

    Oracle for the batched multi-window kernel: composite (slot, key)
    segment ids reduced in one pass.
    """
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if b == 0 or num_slots == 0:
        # empty batch: the fold identity, with no degenerate [0, ...]
        # reduction (segment_* identities are dtype-max, not inf)
        from repro.kernels.segment_aggregate import empty_batch_identity
        return empty_batch_identity(num_slots, num_segments, w)
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))
    out = ref_segment_aggregate(values.reshape(b * n, w),
                                composite.reshape(b * n),
                                num_slots * num_segments,
                                valid=valid.reshape(b * n))
    return {
        "sum": out["sum"].reshape(num_slots, num_segments, w),
        "count": out["count"].reshape(num_slots, num_segments),
        "min": out["min"].reshape(num_slots, num_segments, w),
        "max": out["max"].reshape(num_slots, num_segments, w),
    }


def ref_segment_aggregate_block_table(values_arena: jnp.ndarray,
                                      segment_ids: jnp.ndarray,
                                      table: jnp.ndarray,
                                      num_segments: int,
                                      valid: Optional[jnp.ndarray] = None,
                                      slot_ids: Optional[jnp.ndarray] = None,
                                      num_slots: Optional[int] = None,
                                      num_cols: Optional[int] = None
                                      ) -> dict:
    """Oracle for the block-table fold over a persistent device pool.

    values_arena [pool_slots, cap, W]; table [R] pool-slot indices;
    segment_ids [R, cap]; slot_ids [R] -> per-slot sum/count/min/max.
    The gather is an explicit take along the pool axis (``num_cols``
    keeps the leading value columns), then the batched oracle — the
    kernels must match this regardless of whether they gather in-kernel
    (scalar-prefetch Mosaic) or via one dense take.
    """
    vals = jnp.take(values_arena, table.astype(jnp.int32), axis=0)
    if num_cols is not None:
        vals = vals[:, :, :num_cols]
    return ref_segment_aggregate_batched(
        vals, segment_ids, num_segments, valid=valid, slot_ids=slot_ids,
        num_slots=num_slots)


def ref_segment_aggregate_block_table_splitk(
        values_arena: jnp.ndarray,
        segment_ids: jnp.ndarray,
        table: jnp.ndarray,
        num_segments: int,
        chunk_rows: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        num_cols: Optional[int] = None) -> dict:
    """Oracle for the split-K block-table fold and its merge semantics.

    Folds ``chunk_rows`` table rows at a time through the plain
    block-table oracle, starting from the fold identity
    (``empty_batch_identity``) and merging each chunk's partial through
    the stat's own reduction: sum/count add, min/max take elementwise
    extrema. Zero rows merges to the identity. The split-K kernels must
    match this regardless of how they chunk, pad, or parallelize."""
    from repro.kernels.segment_aggregate import empty_batch_identity
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    r = table.shape[0]
    w_out = num_cols if num_cols is not None else values_arena.shape[2]
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    acc = empty_batch_identity(num_slots, num_segments, w_out)
    for off in range(0, r, chunk_rows):
        sl = slice(off, min(off + chunk_rows, r))
        part = ref_segment_aggregate_block_table(
            values_arena, segment_ids[sl], table[sl], num_segments,
            valid=None if valid is None else valid[sl],
            slot_ids=slot_ids[sl], num_slots=num_slots, num_cols=num_cols)
        acc = {
            "sum": acc["sum"] + part["sum"],
            "count": acc["count"] + part["count"],
            "min": jnp.minimum(acc["min"], part["min"]),
            "max": jnp.maximum(acc["max"], part["max"]),
        }
    return acc


def ref_flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q [B, Sq, H, D]; k, v [B, Sk, Hkv, D] -> [B, Sq, H, D].
    Plain materialized softmax attention (fp32 math)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def ref_decode_attention_paged(q: jnp.ndarray, kv_pages_k: jnp.ndarray,
                               kv_pages_v: jnp.ndarray,
                               block_table: jnp.ndarray,
                               seq_lens: jnp.ndarray) -> jnp.ndarray:
    """Paged decode attention oracle.

    q            [B, H, D]
    kv_pages_*   [P, page, Hkv, D]   (global page pool)
    block_table  [B, pages_per_seq] i32 (page ids; -1 = unused)
    seq_lens     [B] i32 (valid tokens per sequence)
    -> [B, H, D]
    """
    b, h, d = q.shape
    pages, page_size, hkv, _ = kv_pages_k.shape
    per_seq = block_table.shape[1]
    g = h // hkv

    def one(qi, table, n):
        k = kv_pages_k[jnp.maximum(table, 0)]   # [per_seq, page, Hkv, D]
        v = kv_pages_v[jnp.maximum(table, 0)]
        k = k.reshape(per_seq * page_size, hkv, d).astype(jnp.float32)
        v = v.reshape(per_seq * page_size, hkv, d).astype(jnp.float32)
        pos = jnp.arange(per_seq * page_size)
        valid = (pos < n) & jnp.repeat(table >= 0, page_size)
        qg = qi.reshape(hkv, g, d).astype(jnp.float32)
        s = jnp.einsum("hgd,shd->hgs", qg, k) / math.sqrt(d)
        s = jnp.where(valid[None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hgs,shd->hgd", p, v)
        return o.reshape(h, d)

    return jax.vmap(one)(q, block_table, seq_lens).astype(q.dtype)


def ref_ssd_chunk_scan(xdt: jnp.ndarray, a: jnp.ndarray, B: jnp.ndarray,
                       C: jnp.ndarray, chunk: int,
                       init_state: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential-exact SSD oracle: step the recurrence token by token.

    xdt [b, s, h, p] (x*dt); a [b, s, h] (dt*A); B, C [b, s, n].
    Returns (y [b, s, h, p], final_state [b, h, p, n]).
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    state0 = init_state if init_state is not None else \
        jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, t):
        xt, at, Bt, Ct = t
        decay = jnp.exp(at)[:, :, None, None]              # [b,h,1,1]
        upd = jnp.einsum("bn,bhp->bhpn", Bt.astype(jnp.float32),
                         xt.astype(jnp.float32))
        state = decay * state + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct.astype(jnp.float32), state)
        return state, y

    xs = (xdt.transpose(1, 0, 2, 3), a.transpose(1, 0, 2),
          B.transpose(1, 0, 2), C.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(xdt.dtype), final
