"""Public kernel entry points: jit'd wrappers with backend dispatch.

``backend``:
  'pallas'     real Mosaic lowering (TPU)
  'interpret'  Pallas interpreter (CPU validation — this container)
  'ref'        pure-jnp oracle (numerics baseline)
  'auto'       pallas on TPU, interpret elsewhere
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention_paged_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
from repro.kernels.segment_aggregate import (
    empty_batch_identity as _empty_batch_identity,
    norm_stats as _norm_stats,
    segment_aggregate_batched_dense, segment_aggregate_batched_pallas,
    segment_aggregate_batched_sharded,
    segment_aggregate_batched_splitk_sharded,
    segment_aggregate_block_table_dense,
    segment_aggregate_block_table_pallas,
    segment_aggregate_block_table_sharded,
    segment_aggregate_block_table_splitk_dense,
    segment_aggregate_block_table_splitk_pallas, segment_aggregate_pallas,
)
from repro.kernels.ssd_scan import ssd_scan_pallas


def _resolve(backend: str) -> str:
    if backend != "auto":
        return backend
    platform = jax.devices()[0].platform
    return "pallas" if platform == "tpu" else "interpret"


@functools.partial(jax.jit, static_argnames=("num_segments", "backend",
                                             "block_n", "stats"))
def segment_aggregate(values, segment_ids, num_segments: int, valid=None,
                      backend: str = "auto", block_n: int = 512,
                      stats: tuple = ("sum", "count", "min", "max")):
    """``stats`` selects which aggregates the kernel materializes — the
    selection reaches the Pallas out_shapes, so sum/count-only callers
    skip the min/max VPU broadcast-reduce on the Mosaic path too."""
    stats = _norm_stats(stats)
    be = _resolve(backend)
    if be == "ref":
        out = _ref.ref_segment_aggregate(values, segment_ids, num_segments,
                                         valid)
        return {k: v for k, v in out.items() if k in stats}
    return segment_aggregate_pallas(values, segment_ids, num_segments,
                                    valid=valid, block_n=block_n,
                                    interpret=(be == "interpret"),
                                    stats=stats)


@functools.partial(jax.jit, static_argnames=("num_segments", "num_slots",
                                             "backend", "block_n",
                                             "stats", "mesh", "splitk"))
def segment_aggregate_batched(values, segment_ids, num_segments: int,
                              valid=None, slot_ids=None,
                              num_slots: Optional[int] = None,
                              backend: str = "auto", block_n: int = 512,
                              stats: tuple = ("sum", "count", "min",
                                              "max"),
                              mesh=None, splitk: int = 0):
    """Batched multi-window reduce-by-key: values [B, N, W], ids [B, N],
    slot_ids [B] -> aggregates [num_slots, num_segments, ...] in one pass.

    The engine's batched execution path folds every due window through a
    single launch of this op. ``backend='auto'`` resolves to Mosaic on
    TPU and the dense one-hot jnp formulation elsewhere (identical math;
    XLA:CPU scatters and the Pallas interpreter are both validation-only
    speeds). ``stats`` selects which aggregates to materialize — folds
    that only need sum/count skip the min/max work.

    ``mesh`` (a 1-D device mesh; static, hashable) routes the fold
    through the slot-sharded variant: window slots partition across the
    mesh and each device reduces only its own shard-major rows —
    psum-free, since slots are disjoint. Rows/slots must divide the mesh
    and rows must be packed shard-major (``pack_rows_shard_major``). The
    ``'ref'`` backend ignores the mesh: it is the unsharded oracle the
    sharded path is validated against.

    ``splitk > 0`` with a mesh switches to the **row-balanced** split-K
    variant: rows are dealt across devices with no ownership
    precondition (``pack_rows_shard_major(balance=True)``), each device
    folds a full per-slot partial, and the partials merge after the
    shard_map. Only rows must divide the mesh; slots are unconstrained.
    Callers must check ``WindowOperator.supports_splitk`` — ownership-
    masking folds would drop balanced rows. Without a mesh ``splitk`` is
    a no-op here (single-device chunking lives on the block-table path).
    """
    stats = _norm_stats(stats)
    b = values.shape[0]
    ns = num_slots if num_slots is not None else \
        (b if slot_ids is None else None)
    if ns is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if b == 0 or ns == 0:
        # empty-batch edge: no degenerate [0, ...] kernel launch — return
        # the fold identity (zero sum/count, +/-inf extrema) directly
        empty = _empty_batch_identity(ns, num_segments, values.shape[2])
        return {k: v for k, v in empty.items() if k in stats}
    if backend == "auto":
        be = "pallas" if jax.devices()[0].platform == "tpu" else "dense"
    else:
        be = backend
    if mesh is not None and be != "ref" and mesh.size > 1:
        if splitk > 0:
            return segment_aggregate_batched_splitk_sharded(
                values, segment_ids, num_segments, valid=valid,
                slot_ids=slot_ids, num_slots=ns, mesh=mesh,
                stats=stats, use_pallas=(be in ("pallas", "interpret")),
                block_n=block_n, interpret=(be == "interpret"))
        return segment_aggregate_batched_sharded(
            values, segment_ids, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots, mesh=mesh,
            stats=stats, use_pallas=(be in ("pallas", "interpret")),
            block_n=block_n, interpret=(be == "interpret"))
    if be == "dense":
        return segment_aggregate_batched_dense(
            values, segment_ids, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots, stats=stats)
    if be == "ref":
        out = _ref.ref_segment_aggregate_batched(
            values, segment_ids, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots)
        return {k: v for k, v in out.items() if k in stats}
    return segment_aggregate_batched_pallas(
        values, segment_ids, num_segments, valid=valid,
        slot_ids=slot_ids, num_slots=num_slots, block_n=block_n,
        interpret=(be == "interpret"), stats=stats)


@functools.partial(jax.jit, static_argnames=("num_segments", "num_slots",
                                             "backend", "stats", "mesh",
                                             "num_cols"))
def segment_aggregate_block_table(values_arena, segment_ids, table,
                                  num_segments: int, valid=None,
                                  slot_ids=None,
                                  num_slots: Optional[int] = None,
                                  backend: str = "auto",
                                  stats: tuple = ("sum", "count", "min",
                                                  "max"),
                                  mesh=None,
                                  num_cols: Optional[int] = None):
    """Batched multi-window reduce-by-key over a persistent block pool.

    values_arena [pool_slots, cap, W] (the device arena the staging layer
    fills), table [R] i32 pool-slot indices, segment_ids [R, cap] i32,
    slot_ids [R] window slots -> aggregates [num_slots, num_segments, ...]
    in one pass. This is the zero-copy gather path of the batched engine
    fold: rows are event tiles *referenced* out of the arena rather than
    stacked into a fresh tensor — an in-kernel scalar-prefetch DMA on the
    Mosaic backend, a single take along the pool axis on the dense
    backend. Shapes depend only on the (pow2-padded) table length and the
    fixed arena, so the jit cache stays O(log batch).

    ``mesh`` routes through the sharded variant: arena and table both
    partition across the mesh and each shard gathers only from its own
    arena tile (see ``segment_aggregate_block_table_sharded``). The
    ``'ref'`` backend ignores the mesh — it is the unsharded oracle.
    ``num_cols`` restricts the fold to the leading value columns, sliced
    AFTER the row gather (width-selecting operators pass the full arena
    — never an arena-wide slice copy).
    """
    stats = _norm_stats(stats)
    r = table.shape[0]
    ns = num_slots if num_slots is not None else \
        (r if slot_ids is None else None)
    if ns is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if r == 0 or ns == 0:
        w_out = num_cols if num_cols is not None else values_arena.shape[2]
        empty = _empty_batch_identity(ns, num_segments, w_out)
        return {k: v for k, v in empty.items() if k in stats}
    if backend == "auto":
        be = "pallas" if jax.devices()[0].platform == "tpu" else "dense"
    else:
        be = backend
    if mesh is not None and be != "ref" and mesh.size > 1:
        return segment_aggregate_block_table_sharded(
            values_arena, segment_ids, table, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots, mesh=mesh, stats=stats,
            use_pallas=(be in ("pallas", "interpret")),
            interpret=(be == "interpret"), num_cols=num_cols)
    if be == "dense":
        return segment_aggregate_block_table_dense(
            values_arena, segment_ids, table, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots, stats=stats,
            num_cols=num_cols)
    if be == "ref":
        out = _ref.ref_segment_aggregate_block_table(
            values_arena, segment_ids, table, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots, num_cols=num_cols)
        return {k: v for k, v in out.items() if k in stats}
    return segment_aggregate_block_table_pallas(
        values_arena, segment_ids, table, num_segments, valid=valid,
        slot_ids=slot_ids, num_slots=num_slots,
        interpret=(be == "interpret"), stats=stats, num_cols=num_cols)


@functools.partial(jax.jit, static_argnames=("num_segments", "chunk_rows",
                                             "num_slots", "backend",
                                             "stats", "mesh", "num_cols"))
def segment_aggregate_block_table_splitk(values_arena, segment_ids, table,
                                         num_segments: int, chunk_rows: int,
                                         valid=None, slot_ids=None,
                                         num_slots: Optional[int] = None,
                                         backend: str = "auto",
                                         stats: tuple = ("sum", "count",
                                                         "min", "max"),
                                         mesh=None,
                                         num_cols: Optional[int] = None):
    """Split-K block-table fold: the block-table gather of
    ``segment_aggregate_block_table`` with the pool axis partitioned into
    fixed-shape chunks of ``chunk_rows`` rows, per-chunk partial
    accumulators, and an on-device identity merge (flash-decoding's
    ``mid_o`` second half).

    Launch shapes depend only on ``chunk_rows`` and the chunk count —
    never the raw batch size — so an executor that decomposes variable
    batches into a fixed repertoire of chunk counts folds ANY batch with
    zero recompiles, and one hot window's rows spread across chunk
    programs instead of serializing a single segment stripe. ``mesh``
    routes through the sharded block-table variant with per-shard
    split-K local folds (same ownership layout as the plain sharded op).
    The ``'ref'`` backend is the chunk-looped oracle
    (``ref_segment_aggregate_block_table_splitk``) the other backends
    are validated against.
    """
    stats = _norm_stats(stats)
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    r = table.shape[0]
    ns = num_slots if num_slots is not None else \
        (r if slot_ids is None else None)
    if ns is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if r == 0 or ns == 0:
        w_out = num_cols if num_cols is not None else values_arena.shape[2]
        empty = _empty_batch_identity(ns, num_segments, w_out)
        return {k: v for k, v in empty.items() if k in stats}
    if backend == "auto":
        be = "pallas" if jax.devices()[0].platform == "tpu" else "dense"
    else:
        be = backend
    if mesh is not None and be != "ref" and mesh.size > 1:
        return segment_aggregate_block_table_sharded(
            values_arena, segment_ids, table, num_segments, valid=valid,
            slot_ids=slot_ids, num_slots=num_slots, mesh=mesh, stats=stats,
            use_pallas=(be in ("pallas", "interpret")),
            interpret=(be == "interpret"), num_cols=num_cols,
            chunk_rows=chunk_rows)
    if be == "dense":
        return segment_aggregate_block_table_splitk_dense(
            values_arena, segment_ids, table, num_segments, chunk_rows,
            valid=valid, slot_ids=slot_ids, num_slots=num_slots,
            stats=stats, num_cols=num_cols)
    if be == "ref":
        out = _ref.ref_segment_aggregate_block_table_splitk(
            values_arena, segment_ids, table, num_segments, chunk_rows,
            valid=valid, slot_ids=slot_ids, num_slots=num_slots,
            num_cols=num_cols)
        return {k: v for k, v in out.items() if k in stats}
    return segment_aggregate_block_table_splitk_pallas(
        values_arena, segment_ids, table, num_segments, chunk_rows,
        valid=valid, slot_ids=slot_ids, num_slots=num_slots,
        interpret=(be == "interpret"), stats=stats, num_cols=num_cols)


@functools.partial(jax.jit, static_argnames=("causal", "window", "backend",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    backend: str = "auto", block_q: int = 512,
                    block_k: int = 512):
    be = _resolve(backend)
    if be == "ref":
        return _ref.ref_flash_attention(q, k, v, causal=causal, window=window)
    b, sq, h, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, sk)
    while sk % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=max(bq, 1), block_k=max(bk, 1),
                                  interpret=(be == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def decode_attention_paged(q, k_pages, v_pages, block_table, seq_lens,
                           backend: str = "auto"):
    be = _resolve(backend)
    if be == "ref":
        return _ref.ref_decode_attention_paged(q, k_pages, v_pages,
                                               block_table, seq_lens)
    return decode_attention_paged_pallas(q, k_pages, v_pages, block_table,
                                         seq_lens,
                                         interpret=(be == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "head_block",
                                             "backend"))
def ssd_chunk_scan(xdt, a, B, C, chunk: int = 256, head_block: int = 8,
                   backend: str = "auto"):
    be = _resolve(backend)
    if be == "ref":
        y, _ = _ref.ref_ssd_chunk_scan(xdt, a, B, C, chunk)
        return y
    h = xdt.shape[2]
    hb = min(head_block, h)
    while h % hb:
        hb //= 2
    return ssd_scan_pallas(xdt, a, B, C, chunk, head_block=max(hb, 1),
                           interpret=(be == "interpret"))


# ---------------------------------------------------------------------------
# Differentiable flash attention (custom VJP over the fwd + bwd kernels)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_vjp(q, k, v, causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = True):
    """flash_attention with a flash backward: neither pass materializes the
    [Sq, Sk] probability matrix. q [B,Sq,H,D]; k,v [B,Sk,Hkv,D]."""
    o, _ = _fa_fwd(q, k, v, causal, window, block_q, block_k, interpret)
    return o


def _fa_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    b, sq, h, d = q.shape
    bq = min(block_q, sq)
    bk = min(block_k, k.shape[1])
    o, lse = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    block_q=bq, block_k=bk,
                                    interpret=interpret, return_lse=True)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    # flatten heads; broadcast kv over the GQA group for the bwd kernels
    qf = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv * g, sq, d)
    of = o.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv * g, sq, d)
    dof = do.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv * g, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1) \
        .reshape(b * hkv * g, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1) \
        .reshape(b * hkv * g, sk, d)
    dqf, dkf, dvf = flash_attention_bwd_pallas(
        qf, kf, vf, of, dof, lse, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret)
    dq = dqf.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, h, d)
    # sum group gradients back onto the shared kv heads
    dk = dkf.reshape(b, hkv, g, sk, d).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dvf.reshape(b, hkv, g, sk, d).sum(axis=2).transpose(0, 2, 1, 3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_vjp.defvjp(_fa_fwd, _fa_bwd)
