"""Pallas TPU kernel: paged decode attention over a tiered KV pool.

This is the kernel-level realization of AION's m-bucket: the KV cache of a
long-lived session is block-granular (pages); *resident* pages live in the
HBM pool this kernel reads, cold pages live host-side (serve/kvcache.py
stages them in ahead of a session's predicted decode — proactive caching).
The kernel consumes a **block table** (vLLM-style indirection, adapted to
TPU via scalar prefetch): the table is a scalar-prefetch operand so each
grid step's BlockSpec ``index_map`` dereferences it to pick the physical
page to DMA into VMEM — pages are gathered without any host-side copy.

Grid: (batch, kv_head, pages_per_seq); the page axis is innermost so the
online-softmax state (m, l, acc[G, D]) persists in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
            pages_per_seq: int, g: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                     # [G, D]
    k = k_ref[0][:, 0]                                  # [page, D]
    v = v_ref[0][:, 0]

    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [G, page]

    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)[0]
    valid = (pos < lens_ref[b]) & (table_ref[b, j] >= 0)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pages_per_seq - 1)
    def _finish():
        safe_l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


def decode_attention_paged_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                                  v_pages: jnp.ndarray,
                                  block_table: jnp.ndarray,
                                  seq_lens: jnp.ndarray,
                                  interpret: bool = True) -> jnp.ndarray:
    """q [B, H, D]; k/v_pages [P, page, Hkv, D]; block_table [B, pages_per
    _seq] i32 (page id or -1); seq_lens [B] i32 -> [B, H, D]."""
    b, h, d = q.shape
    p_total, page_size, hkv, _ = k_pages.shape
    pages_per_seq = block_table.shape[1]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)

    qf = q.reshape(b, hkv, g, d)
    table = jnp.maximum(block_table, 0).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, scale=scale, page_size=page_size,
        pages_per_seq=pages_per_seq, g=g)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, hi, j, tbl, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda bi, hi, j, tbl, lens: (tbl[bi, j], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda bi, hi, j, tbl, lens: (tbl[bi, j], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, hi, j, tbl, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(table, seq_lens.astype(jnp.int32),
      qf, k_pages, v_pages)
    return out.reshape(b, h, d)
