"""Pallas TPU kernel: windowed segment aggregation (reduce-by-key).

The streaming engine's hot loop: fold a block batch of events into
per-key aggregates (sum / count / min / max). TPU adaptation: scatter-by-
key is hostile to the VPU, so the kernel converts the segment reduction
into **one-hot matmuls on the MXU** — ``onehot(ids)^T @ values`` — which is
the TPU-native formulation of reduce-by-key (FeatGraph/GE-SpMM style).

Tiling: grid over event tiles of ``block_n`` rows; each step loads a
[block_n, W] value tile + [block_n] ids into VMEM, builds the [block_n, S]
one-hot in registers, and accumulates [S, W] / [S] outputs that stay
resident in VMEM across the whole grid (output BlockSpecs map every step
to the same block).

The **batched** entry point (``segment_aggregate_batched_pallas``) extends
this to many concurrent windows in one device pass: event rows carry a
2-D segment layout ``(window_slot, key)`` which is flattened into the
segment axis (``sid = slot * S + key``) so a single kernel launch reduces
every due window at once — the engine's multi-window execution path.

The **sharded** entry point (``segment_aggregate_batched_sharded``)
partitions that composite segment axis across a 1-D device mesh: device
``d`` owns the contiguous slot range ``[d*slots_per, (d+1)*slots_per)``
and reduces only the block rows placed in its shard. Slots are disjoint,
so shards never touch each other's outputs and the gather needs **no
cross-device reduction** (no psum) — the output is simply each shard's
``[slots_per, S, ...]`` tile concatenated along the slot axis. Rows must
arrive in shard-major order (``pack_rows_shard_major``); a row whose slot
falls outside its shard's range is defensively masked invalid rather than
corrupting a neighbour's slot.

The **block-table** entry points (``segment_aggregate_block_table_*``)
are the zero-copy gather path over the persistent device block pool
(``core.block_pool``): instead of stacked ``[R, cap, W]`` event tensors
they take the whole ``[pool_slots, cap, W]`` values arena plus a ``[R]``
table of pool-slot indices, and gather each row's event tile from the
arena *inside* the launch — a scalar-prefetched ``index_map`` dereference
on the Mosaic path (the flash-decoding ``block_tables`` idiom, one DMA
per row straight out of the arena), a single ``jnp.take`` along the pool
axis on the dense path. The sharded variant partitions BOTH the arena
and the table over the mesh, so each device gathers only from its own
``[pool_slots/D, ...]`` arena tile — the table stays shard-local.

The **split-K** entry points (``segment_aggregate_block_table_splitk_*``)
are the second half of the flash-decoding idiom: the table's row axis is
partitioned into ``k`` fixed-shape chunks of ``chunk_rows`` rows, each
chunk's grid programs fold into their own ``mid_o``-style partial
accumulator (leading chunk axis on every out_shape), and the partials
merge through each stat's own identity (sum/count add, min/max
elementwise extrema — ``merge_partials``). Because every launch shape is
``chunk_rows`` regardless of batch size, varying batches reuse one
compiled kernel instead of recompiling per power-of-two bucket, and a
skewed window whose rows dominate the batch folds across chunks in
parallel instead of serializing one segment stripe.
``segment_aggregate_batched_splitk_sharded`` is the distributed form:
rows balance across the mesh ignoring slot ownership, each device folds
a FULL per-slot partial, and the per-device partials merge after the
``shard_map``.

All Pallas entry points thread ``stats`` through their ``out_shape``s:
sum/count-only folds (average, lrb) never allocate or compute the
min/max VPU broadcast-reduce, matching the dense backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

ALL_STATS = ("sum", "count", "min", "max")


def norm_stats(stats) -> Tuple[str, ...]:
    """Canonicalize a stats selection: fixed order, validated, deduped —
    so jit caches don't fork on permutations of the same request."""
    stats = tuple(stats)
    for s in stats:
        if s not in ALL_STATS:
            raise ValueError(f"unknown stat {s!r} (of {ALL_STATS})")
    out = tuple(s for s in ALL_STATS if s in stats)
    if not out:
        raise ValueError("stats selection is empty")
    return out


def _acc_tile(refs, ids, valid, vals, num_segments: int, n: int) -> None:
    """Accumulate one [n] ids / [n, W] values tile into the stat refs.

    Shared by the flat-grid kernel and the block-table kernel. Only the
    requested stats exist in ``refs``; unrequested aggregates cost
    nothing (the min/max broadcast-reduce temps are never built for
    sum/count-only folds)."""
    seg = jax.lax.broadcasted_iota(jnp.int32, (n, num_segments), 1)
    onehot = (ids[:, None] == seg) & valid[:, None]     # [n, S]
    if "sum" in refs or "count" in refs:
        oh_f = onehot.astype(jnp.float32)
    if "sum" in refs:
        # MXU path: [S, n] @ [n, W]
        refs["sum"][...] += jax.lax.dot_general(
            oh_f, jnp.where(valid[:, None], vals, 0.0),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if "count" in refs:
        refs["count"][...] += jnp.sum(oh_f, axis=0)
    # min/max: masked broadcast-reduce over the tile (VPU path)
    if "min" in refs:
        big = jnp.where(onehot[:, :, None], vals[:, None, :], jnp.inf)
        refs["min"][...] = jnp.minimum(refs["min"][...],
                                       jnp.min(big, axis=0))
    if "max" in refs:
        small = jnp.where(onehot[:, :, None], vals[:, None, :], -jnp.inf)
        refs["max"][...] = jnp.maximum(refs["max"][...],
                                       jnp.max(small, axis=0))


def _init_refs(refs) -> None:
    for name, ref in refs.items():
        if name == "min":
            ref[...] = jnp.full_like(ref, jnp.inf)
        elif name == "max":
            ref[...] = jnp.full_like(ref, -jnp.inf)
        else:
            ref[...] = jnp.zeros_like(ref)


def _kernel(ids_ref, valid_ref, values_ref, *out_refs, num_segments: int,
            block_n: int, stats: Tuple[str, ...]):
    refs = dict(zip(stats, out_refs))
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        _init_refs(refs)

    _acc_tile(refs, ids_ref[...], valid_ref[...] != 0, values_ref[...],
              num_segments, block_n)


def _stat_outputs(stats: Tuple[str, ...], num_segments: int, w: int):
    """(out_shapes, out_specs) for a stats selection; every grid step maps
    to the same (only) block so accumulators stay VMEM-resident (the
    variadic index_maps absorb grid indices and any scalar-prefetch
    operands)."""
    full2 = pl.BlockSpec((num_segments, w), lambda *a: (0, 0))
    full1 = pl.BlockSpec((num_segments,), lambda *a: (0,))
    shapes = []
    specs = []
    for s in stats:
        if s == "count":
            shapes.append(jax.ShapeDtypeStruct((num_segments,), jnp.float32))
            specs.append(full1)
        else:
            shapes.append(jax.ShapeDtypeStruct((num_segments, w),
                                               jnp.float32))
            specs.append(full2)
    return tuple(shapes), tuple(specs)


def segment_aggregate_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                             num_segments: int,
                             valid: Optional[jnp.ndarray] = None,
                             block_n: int = 512,
                             interpret: bool = True,
                             stats: Tuple[str, ...] = ALL_STATS):
    """values [N, W] f32, segment_ids [N] i32 -> dict of [S, W]/[S] aggs.

    N is padded to a multiple of ``block_n``; padding rows are invalid.
    ``stats`` selects which aggregates the kernel materializes (threaded
    through ``out_shape`` — unrequested stats are never computed).
    """
    stats = norm_stats(stats)
    n, w = values.shape
    if valid is None:
        valid = jnp.ones((n,), jnp.int32)
    else:
        valid = valid.astype(jnp.int32)
    block_n = min(block_n, max(n, 8))
    pad = (-n) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    n_pad = n + pad
    grid = (n_pad // block_n,)

    kernel = functools.partial(_kernel, num_segments=num_segments,
                               block_n=block_n, stats=stats)
    out_shapes, out_specs = _stat_outputs(stats, num_segments, w)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(segment_ids.astype(jnp.int32), valid, values.astype(jnp.float32))
    return dict(zip(stats, outs))


def segment_aggregate_batched_pallas(values: jnp.ndarray,
                                     segment_ids: jnp.ndarray,
                                     num_segments: int,
                                     valid: Optional[jnp.ndarray] = None,
                                     slot_ids: Optional[jnp.ndarray] = None,
                                     num_slots: Optional[int] = None,
                                     block_n: int = 512,
                                     interpret: bool = True,
                                     stats: Tuple[str, ...] = ALL_STATS):
    """Multi-window segment aggregation in ONE kernel launch.

    values [B, N, W] f32, segment_ids [B, N] i32 -> per-slot aggregates
    {sum [num_slots, S, W], count [num_slots, S], min, max} — restricted
    to the requested ``stats`` (threaded through the kernel out_shapes,
    so sum/count-only folds skip the min/max VPU work entirely).

    Each of the B rows is a padded event block (``valid`` masks ragged
    fills); ``slot_ids [B]`` maps rows to output window slots, so several
    blocks of the same window may share a slot (default: ``arange(B)``,
    one row per slot). The 2-D segment layout ``(slot, key)`` is flattened
    into the segment axis — ``sid = slot * num_segments + key`` — and fed
    through the same one-hot-matmul grid as the single-window kernel.
    """
    stats = norm_stats(stats)
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))        # [B, N]
    out = segment_aggregate_pallas(
        values.reshape(b * n, w), composite.reshape(b * n),
        num_slots * num_segments, valid=valid.reshape(b * n),
        block_n=block_n, interpret=interpret, stats=stats)
    shaped = {}
    for s in stats:
        if s == "count":
            shaped[s] = out[s].reshape(num_slots, num_segments)
        else:
            shaped[s] = out[s].reshape(num_slots, num_segments, w)
    return shaped


def segment_aggregate_batched_dense(values: jnp.ndarray,
                                    segment_ids: jnp.ndarray,
                                    num_segments: int,
                                    valid: Optional[jnp.ndarray] = None,
                                    slot_ids: Optional[jnp.ndarray] = None,
                                    num_slots: Optional[int] = None,
                                    stats: Tuple[str, ...] = (
                                        "sum", "count", "min", "max")):
    """The kernel's one-hot formulation as plain jnp — the non-TPU hot
    path for the batched engine fold.

    Same contract as ``segment_aggregate_batched_pallas``. XLA:CPU lowers
    ``jax.ops.segment_*`` to serial scatters, which is orders slower than
    the one-hot matmul this uses (identical math to the Mosaic kernel);
    ``stats`` lets callers skip the min/max broadcast-reduce temps when
    only sum/count are needed (the average and LRB folds).
    """
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    s_total = num_slots * num_segments
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32)).reshape(b * n)
    flat_valid = valid.reshape(b * n).astype(bool)
    flat_vals = values.reshape(b * n, w).astype(jnp.float32)
    onehot = (composite[:, None] ==
              jnp.arange(s_total, dtype=jnp.int32)[None, :]) \
        & flat_valid[:, None]                               # [B*N, S]
    oh_f = onehot.astype(jnp.float32)
    out = {}
    if "sum" in stats:
        out["sum"] = jax.lax.dot_general(
            oh_f, jnp.where(flat_valid[:, None], flat_vals, 0.0),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(num_slots, num_segments, w)
    if "count" in stats:
        out["count"] = jnp.sum(oh_f, axis=0).reshape(num_slots,
                                                     num_segments)
    if "min" in stats:
        big = jnp.where(onehot[:, :, None], flat_vals[:, None, :], jnp.inf)
        out["min"] = jnp.min(big, axis=0).reshape(num_slots, num_segments,
                                                  w)
    if "max" in stats:
        small = jnp.where(onehot[:, :, None], flat_vals[:, None, :],
                          -jnp.inf)
        out["max"] = jnp.max(small, axis=0).reshape(num_slots,
                                                    num_segments, w)
    return out


def _bt_kernel(table_ref, ids_ref, valid_ref, arena_ref, *out_refs,
               num_segments: int, cap: int, stats: Tuple[str, ...],
               num_cols: Optional[int]):
    """Block-table kernel body: one grid step per table row. The arena
    BlockSpec's index_map dereferences the scalar-prefetched table, so
    each step DMAs its event tile straight out of the pool arena — the
    row gather happens inside the launch, not as a host/device concat.
    ``num_cols`` selects a value-column prefix AFTER the gather (per-tile
    slice) so width-selecting folds never materialize an arena-wide
    slice copy."""
    refs = dict(zip(stats, out_refs))
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        _init_refs(refs)

    vals = arena_ref[0]
    if num_cols is not None:
        vals = vals[:, :num_cols]
    _acc_tile(refs, ids_ref[0], valid_ref[0] != 0, vals,
              num_segments, cap)


def segment_aggregate_block_table_pallas(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        interpret: bool = True,
        stats: Tuple[str, ...] = ALL_STATS,
        num_cols: Optional[int] = None):
    """Batched fold over a persistent block pool, gathering in-kernel.

    values_arena [pool_slots, cap, W] f32 (the device arena), table [R]
    i32 pool-slot indices, segment_ids [R, cap] i32, slot_ids [R] window
    slots -> per-slot aggregates as ``segment_aggregate_batched_pallas``.
    The table is a scalar-prefetch operand: grid step ``r`` DMAs arena
    row ``table[r]`` into VMEM (flash-decoding's ``block_tables`` idiom),
    so already-resident blocks are folded with zero per-batch copies.
    ``num_cols`` restricts the fold to the leading value columns,
    sliced per-tile inside the kernel (width-selecting operators pass
    the FULL arena — never an arena-wide slice copy).
    """
    stats = norm_stats(stats)
    p, cap, w = values_arena.shape
    w_out = num_cols if num_cols is not None else w
    r = table.shape[0]
    if valid is None:
        valid = jnp.ones((r, cap), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))        # [R, cap]
    s_total = num_slots * num_segments
    kernel = functools.partial(_bt_kernel, num_segments=s_total, cap=cap,
                               stats=stats, num_cols=num_cols)
    out_shapes, out_specs = _stat_outputs(stats, s_total, w_out)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i, tbl: (i, 0)),
            pl.BlockSpec((1, cap), lambda i, tbl: (i, 0)),
            pl.BlockSpec((1, cap, w), lambda i, tbl: (tbl[i], 0, 0)),
        ],
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(table.astype(jnp.int32), composite,
      valid.astype(jnp.int32), values_arena.astype(jnp.float32))
    out = dict(zip(stats, outs))
    shaped = {}
    for s in stats:
        if s == "count":
            shaped[s] = out[s].reshape(num_slots, num_segments)
        else:
            shaped[s] = out[s].reshape(num_slots, num_segments, w_out)
    return shaped


def segment_aggregate_block_table_dense(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        stats: Tuple[str, ...] = ALL_STATS,
        num_cols: Optional[int] = None):
    """Dense-backend block-table fold: ONE ``jnp.take`` along the pool
    axis materializes the batch (a single device gather op, replacing the
    O(rows) per-row concat of the stacked path), then the one-hot fold.
    ``num_cols`` slices the value columns AFTER the gather — O(rows),
    never an arena-wide copy.
    """
    vals = jnp.take(values_arena, table.astype(jnp.int32), axis=0)
    if num_cols is not None:
        vals = vals[:, :, :num_cols]
    return segment_aggregate_batched_dense(
        vals, segment_ids, num_segments, valid=valid, slot_ids=slot_ids,
        num_slots=num_slots, stats=norm_stats(stats))


def merge_partials(partials: dict) -> dict:
    """Merge ``[k, ...]`` per-chunk partial accumulators along the leading
    chunk axis through each stat's identity: sum/count add, min/max take
    elementwise extrema. ``k == 0`` (an empty chunk set) merges to the
    fold identity — a degenerate ``jnp.min`` over an empty axis would
    raise, and the identity is what an empty batch must produce."""
    out = {}
    for s, v in partials.items():
        if v.shape[0] == 0:
            if s == "min":
                out[s] = jnp.full(v.shape[1:], jnp.inf)
            elif s == "max":
                out[s] = jnp.full(v.shape[1:], -jnp.inf)
            else:
                out[s] = jnp.zeros(v.shape[1:], jnp.float32)
        elif s == "min":
            out[s] = jnp.min(v, axis=0)
        elif s == "max":
            out[s] = jnp.max(v, axis=0)
        else:
            out[s] = jnp.sum(v, axis=0)
    return out


def _stat_outputs_chunked(stats: Tuple[str, ...], k: int,
                          num_segments: int, w: int):
    """(out_shapes, out_specs) for the split-K kernel: the out arrays grow
    a leading chunk axis ``[k, S(, W)]`` and chunk ``c``'s programs all map
    to block ``c`` — each chunk's partial accumulator stays VMEM-resident
    across its ``chunk_rows`` inner steps (grid iterates the row axis
    fastest) and is re-initialized when the next chunk begins."""
    full2 = pl.BlockSpec((1, num_segments, w), lambda c, r, *a: (c, 0, 0))
    full1 = pl.BlockSpec((1, num_segments), lambda c, r, *a: (c, 0))
    shapes = []
    specs = []
    for s in stats:
        if s == "count":
            shapes.append(jax.ShapeDtypeStruct((k, num_segments),
                                               jnp.float32))
            specs.append(full1)
        else:
            shapes.append(jax.ShapeDtypeStruct((k, num_segments, w),
                                               jnp.float32))
            specs.append(full2)
    return tuple(shapes), tuple(specs)


def _bt_splitk_kernel(table_ref, ids_ref, valid_ref, arena_ref, *out_refs,
                      num_segments: int, cap: int, stats: Tuple[str, ...],
                      num_cols: Optional[int]):
    """Split-K block-table kernel body: grid ``(k, chunk_rows)``, one step
    per (chunk, row-within-chunk). Accumulators re-init at the first row
    of every chunk (the out BlockSpecs hand each chunk its own [1, S, W]
    block, so ``_acc_tile``'s [S, W] tiles broadcast into it)."""
    refs = dict(zip(stats, out_refs))
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        _init_refs(refs)

    vals = arena_ref[0]
    if num_cols is not None:
        vals = vals[:, :num_cols]
    _acc_tile(refs, ids_ref[0], valid_ref[0] != 0, vals,
              num_segments, cap)


def _splitk_empty(stats, num_slots, num_segments, w_out, merge):
    """Zero-row result for the split-K entry points: the fold identity
    when merging, else a genuinely empty ``k == 0`` partial stack."""
    empty = empty_batch_identity(num_slots, num_segments, w_out)
    merged = {s: empty[s] for s in stats}
    if merge:
        return merged
    return {s: v[None][:0] for s, v in merged.items()}


def segment_aggregate_block_table_splitk_pallas(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int, chunk_rows: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        interpret: bool = True,
        stats: Tuple[str, ...] = ALL_STATS,
        num_cols: Optional[int] = None,
        merge: bool = True):
    """Split-K block-table fold: fixed-shape chunked partial accumulators.

    Same gather contract as ``segment_aggregate_block_table_pallas``, but
    the ``R`` table rows are padded to a multiple of ``chunk_rows`` and
    folded by a ``(k, chunk_rows)`` grid where chunk ``c`` accumulates
    rows ``[c*chunk_rows, (c+1)*chunk_rows)`` into its own partial out
    block (the exemplar's ``mid_o``). Padding rows are fully invalid
    (table entry 0, slot 0, valid 0) so they contribute nothing to any
    chunk's partial — including min/max, whose identities are ±inf, not
    zero. ``merge=False`` returns the raw ``[k, num_slots, S(, W)]``
    partials for caller-side (cross-launch) merging; the default merges
    on device via ``merge_partials``.
    """
    stats = norm_stats(stats)
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    p, cap, w = values_arena.shape
    w_out = num_cols if num_cols is not None else w
    r = table.shape[0]
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if r == 0 or num_slots == 0:
        return _splitk_empty(stats, num_slots, num_segments, w_out, merge)
    if valid is None:
        valid = jnp.ones((r, cap), jnp.int32)
    pad = (-r) % chunk_rows
    if pad:
        table = jnp.pad(table, (0, pad))
        segment_ids = jnp.pad(segment_ids, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        slot_ids = jnp.pad(slot_ids, (0, pad))
    k = (r + pad) // chunk_rows
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))        # [R', cap]
    s_total = num_slots * num_segments
    kernel = functools.partial(_bt_splitk_kernel, num_segments=s_total,
                               cap=cap, stats=stats, num_cols=num_cols)
    out_shapes, out_specs = _stat_outputs_chunked(stats, k, s_total, w_out)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, chunk_rows),
        in_specs=[
            pl.BlockSpec((1, cap),
                         lambda c, i, tbl: (c * chunk_rows + i, 0)),
            pl.BlockSpec((1, cap),
                         lambda c, i, tbl: (c * chunk_rows + i, 0)),
            pl.BlockSpec((1, cap, w),
                         lambda c, i, tbl: (tbl[c * chunk_rows + i], 0, 0)),
        ],
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(table.astype(jnp.int32), composite,
      valid.astype(jnp.int32), values_arena.astype(jnp.float32))
    out = dict(zip(stats, outs))
    partials = {}
    for s in stats:
        if s == "count":
            partials[s] = out[s].reshape(k, num_slots, num_segments)
        else:
            partials[s] = out[s].reshape(k, num_slots, num_segments, w_out)
    return merge_partials(partials) if merge else partials


def segment_aggregate_block_table_splitk_dense(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int, chunk_rows: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        stats: Tuple[str, ...] = ALL_STATS,
        num_cols: Optional[int] = None,
        merge: bool = True):
    """Dense-backend split-K block-table fold: one pool-axis ``take``,
    then a ``vmap`` of the batched one-hot fold over ``k`` fixed-shape
    chunks of ``chunk_rows`` rows, merged (or returned raw with
    ``merge=False``) exactly as the Pallas path."""
    stats = norm_stats(stats)
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    p, cap, w = values_arena.shape
    w_out = num_cols if num_cols is not None else w
    r = table.shape[0]
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if r == 0 or num_slots == 0:
        return _splitk_empty(stats, num_slots, num_segments, w_out, merge)
    if valid is None:
        valid = jnp.ones((r, cap), jnp.int32)
    pad = (-r) % chunk_rows
    if pad:
        table = jnp.pad(table, (0, pad))
        segment_ids = jnp.pad(segment_ids, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        slot_ids = jnp.pad(slot_ids, (0, pad))
    k = (r + pad) // chunk_rows
    vals = jnp.take(values_arena.astype(jnp.float32),
                    table.astype(jnp.int32), axis=0)
    if num_cols is not None:
        vals = vals[:, :, :num_cols]
    partials = jax.vmap(
        lambda v, sid, va, sl: segment_aggregate_batched_dense(
            v, sid, num_segments, valid=va, slot_ids=sl,
            num_slots=num_slots, stats=stats)
    )(vals.reshape(k, chunk_rows, cap, w_out),
      segment_ids.astype(jnp.int32).reshape(k, chunk_rows, cap),
      valid.astype(bool).reshape(k, chunk_rows, cap),
      slot_ids.astype(jnp.int32).reshape(k, chunk_rows))
    return merge_partials(partials) if merge else partials


def segment_aggregate_block_table_sharded(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None, *, mesh,
        stats: Tuple[str, ...] = ALL_STATS,
        use_pallas: bool = False,
        interpret: bool = True,
        num_cols: Optional[int] = None,
        chunk_rows: int = 0):
    """Slot-sharded block-table fold over a 1-D mesh.

    Both the pool arena (slot axis) and the table rows partition across
    the mesh: shard ``d`` receives arena tile ``[pool_slots/D, ...]`` and
    its shard-major rows, and rewrites global pool slots / window slots to
    shard-local indices — the block table stays local to each shard, so
    the gather never crosses devices and the output is a pure slot-axis
    concatenation (psum-free, as in the stacked sharded fold). The
    executor's hash-based window placement plus the pool's per-shard slot
    ranges guarantee well-placed rows; a misplaced row (table entry or
    window slot outside the shard's ranges) is defensively masked invalid.
    ``chunk_rows > 0`` routes each shard's local fold through the split-K
    path (fixed-shape chunks, merged on-device per shard) — the output
    shape and sharding are unchanged.
    """
    stats = norm_stats(stats)
    p, cap, w = values_arena.shape
    r = table.shape[0]
    axis_name = mesh.axis_names[0]
    num_devices = mesh.shape[axis_name]
    if valid is None:
        valid = jnp.ones((r, cap), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if r % num_devices or num_slots % num_devices or p % num_devices:
        raise ValueError(
            f"rows ({r}), slots ({num_slots}) and pool slots ({p}) must "
            f"all divide the slot mesh ({num_devices} devices); pad with "
            "invalid rows (pack_rows_shard_major) and size the pool to "
            "the mesh")
    slots_per = num_slots // num_devices
    pool_per = p // num_devices

    def shard_fn(arena, sid, tbl, val, sl):
        base = jax.lax.axis_index(axis_name)
        local_tbl = tbl.astype(jnp.int32) - base * pool_per
        own_t = (local_tbl >= 0) & (local_tbl < pool_per)
        local_tbl = jnp.where(own_t, local_tbl, 0)
        local_sl = sl.astype(jnp.int32) - base * slots_per
        own_s = (local_sl >= 0) & (local_sl < slots_per)
        local_sl = jnp.where(own_s, local_sl, 0)
        val_own = val.astype(bool) & (own_t & own_s)[:, None]
        if chunk_rows > 0:
            if use_pallas:
                return segment_aggregate_block_table_splitk_pallas(
                    arena, sid, local_tbl, num_segments, chunk_rows,
                    valid=val_own, slot_ids=local_sl, num_slots=slots_per,
                    interpret=interpret, stats=stats, num_cols=num_cols)
            return segment_aggregate_block_table_splitk_dense(
                arena, sid, local_tbl, num_segments, chunk_rows,
                valid=val_own, slot_ids=local_sl, num_slots=slots_per,
                stats=stats, num_cols=num_cols)
        if use_pallas:
            return segment_aggregate_block_table_pallas(
                arena, sid, local_tbl, num_segments, valid=val_own,
                slot_ids=local_sl, num_slots=slots_per,
                interpret=interpret, stats=stats, num_cols=num_cols)
        return segment_aggregate_block_table_dense(
            arena, sid, local_tbl, num_segments, valid=val_own,
            slot_ids=local_sl, num_slots=slots_per, stats=stats,
            num_cols=num_cols)

    in_specs = (P(axis_name, None, None), P(axis_name, None),
                P(axis_name), P(axis_name, None), P(axis_name))
    out_specs = {k: (P(axis_name, None) if k == "count"
                     else P(axis_name, None, None))
                 for k in stats}
    # local import avoids a kernels <-> distributed cycle at module load
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(shard_fn, mesh, in_specs, out_specs)
    return f(values_arena.astype(jnp.float32),
             segment_ids.astype(jnp.int32), table.astype(jnp.int32),
             valid.astype(jnp.int32), slot_ids.astype(jnp.int32))


def empty_batch_identity(num_slots: int, num_segments: int, w: int) -> dict:
    """Fold identity per (slot, segment) for an empty batch: zero
    sums/counts, +/-inf extrema. Shared by the public entry point and the
    ref oracle so the B == 0 contract cannot drift between them."""
    return {
        "sum": jnp.zeros((num_slots, num_segments, w), jnp.float32),
        "count": jnp.zeros((num_slots, num_segments), jnp.float32),
        "min": jnp.full((num_slots, num_segments, w), jnp.inf),
        "max": jnp.full((num_slots, num_segments, w), -jnp.inf),
    }


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1). Shared by the batch executor's
    shape bucketing and the shard-major row packing below."""
    return 1 << max(n - 1, 0).bit_length()


def pack_rows_shard_major(slot_ids, num_devices: int, slots_per: int,
                          balance: bool = False) -> Tuple[list, int]:
    """Host-side row placement for the sharded fold.

    Default (ownership) mode groups row indices by owning shard
    (``slot // slots_per``) and picks the common power-of-two per-shard
    row count every shard pads to, so the
    ``[num_devices * rows_per_shard, ...]`` stack splits evenly under a
    ``shard_map`` over the leading axis. Returns
    ``(per_shard_row_indices, rows_per_shard)``.

    ``balance=True`` ignores slot ownership entirely and deals rows
    round-robin across shards — the split-K layout: a hot window's rows
    spread over every device instead of serializing on their owner, and
    per-shard row counts differ by at most one regardless of skew. Only
    valid for folds that reduce into full per-slot partials
    (``segment_aggregate_batched_splitk_sharded``); the ownership-masked
    kernels would silently drop balanced rows.
    """
    if balance:
        idx = np.arange(len(np.asarray(slot_ids)), dtype=np.int64)
        per = [idx[d::num_devices] for d in range(num_devices)]
    else:
        shard = np.asarray(slot_ids, np.int64) // max(slots_per, 1)
        per = [np.flatnonzero(shard == d) for d in range(num_devices)]
    rows_per_shard = next_pow2(max([len(p) for p in per] + [1]))
    return per, rows_per_shard


def segment_aggregate_batched_sharded(values: jnp.ndarray,
                                      segment_ids: jnp.ndarray,
                                      num_segments: int,
                                      valid: Optional[jnp.ndarray] = None,
                                      slot_ids: Optional[jnp.ndarray] = None,
                                      num_slots: Optional[int] = None,
                                      *, mesh,
                                      stats: Tuple[str, ...] = (
                                          "sum", "count", "min", "max"),
                                      use_pallas: bool = False,
                                      block_n: int = 512,
                                      interpret: bool = True):
    """Slot-sharded multi-window segment aggregation over a 1-D mesh.

    Same contract as ``segment_aggregate_batched_pallas`` with one layout
    precondition: rows are **shard-major** — row ``r`` belongs to the
    device ``r // (B / num_devices)``, and its (global) slot id must fall
    in that device's range ``[d*slots_per, (d+1)*slots_per)`` where
    ``slots_per = num_slots / num_devices`` (``pack_rows_shard_major``
    produces this layout). Each shard reduces its own rows into its own
    slot tile; the 2-D ``(slot, key)`` layout makes the tiles disjoint,
    so the gathered output is a pure concatenation along the slot axis —
    **no psum**. Misplaced rows are masked invalid inside the shard (they
    contribute nothing) instead of aliasing into a resident slot.
    """
    stats = norm_stats(stats)
    b, n, w = values.shape
    axis_name = mesh.axis_names[0]
    num_devices = mesh.shape[axis_name]
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if b % num_devices or num_slots % num_devices:
        raise ValueError(
            f"rows ({b}) and slots ({num_slots}) must both divide the "
            f"slot mesh ({num_devices} devices); pad with invalid rows / "
            "unused slots (pack_rows_shard_major)")
    slots_per = num_slots // num_devices

    def shard_fn(v, sid, val, sl):
        base = jax.lax.axis_index(axis_name) * slots_per
        local = sl.astype(jnp.int32) - base
        own = (local >= 0) & (local < slots_per)
        local = jnp.where(own, local, 0)
        val_own = val.astype(bool) & own[:, None]
        if use_pallas:
            return segment_aggregate_batched_pallas(
                v, sid, num_segments, valid=val_own, slot_ids=local,
                num_slots=slots_per, block_n=block_n, interpret=interpret,
                stats=stats)
        return segment_aggregate_batched_dense(
            v, sid, num_segments, valid=val_own, slot_ids=local,
            num_slots=slots_per, stats=stats)

    in_specs = (P(axis_name, None, None), P(axis_name, None),
                P(axis_name, None), P(axis_name))
    out_specs = {k: (P(axis_name, None) if k == "count"
                     else P(axis_name, None, None))
                 for k in stats}
    # local import avoids a kernels <-> distributed cycle at module load
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(shard_fn, mesh, in_specs, out_specs)
    return f(values.astype(jnp.float32), segment_ids.astype(jnp.int32),
             valid.astype(bool), slot_ids.astype(jnp.int32))


def segment_aggregate_batched_splitk_sharded(
        values: jnp.ndarray,
        segment_ids: jnp.ndarray,
        num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        *, mesh,
        stats: Tuple[str, ...] = ALL_STATS,
        use_pallas: bool = False,
        block_n: int = 512,
        interpret: bool = True):
    """Row-balanced (split-K) sharded fold over a 1-D mesh.

    The distributed half of the split-K idiom: rows are dealt across the
    mesh with NO slot-ownership precondition
    (``pack_rows_shard_major(..., balance=True)``), each device folds its
    rows into a **full** ``[num_slots, S, ...]`` partial accumulator, and
    the ``D`` per-device partials merge through each stat's identity
    after the ``shard_map`` (``merge_partials`` over the stacked leading
    device axis). Compared to the slot-ownership variant this trades a
    ``D``-times-larger accumulator footprint for perfect row balance: a
    Zipf-hot window whose rows dominate the batch folds on every device
    instead of serializing on its owning shard. ``num_slots`` need not
    divide the mesh — only the row count must.

    Only safe for operators whose batch contract reduces through plain
    per-slot accumulators (``WindowOperator.supports_splitk``); kernels
    that mask rows by slot ownership (the bigram scatter) would silently
    drop balanced rows.
    """
    stats = norm_stats(stats)
    b, n, w = values.shape
    axis_name = mesh.axis_names[0]
    num_devices = mesh.shape[axis_name]
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if b % num_devices:
        raise ValueError(
            f"rows ({b}) must divide the slot mesh ({num_devices} "
            "devices); pad with invalid rows "
            "(pack_rows_shard_major(balance=True))")

    def shard_fn(v, sid, val, sl):
        if use_pallas:
            part = segment_aggregate_batched_pallas(
                v, sid, num_segments, valid=val, slot_ids=sl,
                num_slots=num_slots, block_n=block_n,
                interpret=interpret, stats=stats)
        else:
            part = segment_aggregate_batched_dense(
                v, sid, num_segments, valid=val, slot_ids=sl,
                num_slots=num_slots, stats=stats)
        # grow the leading device axis the out_specs stack over
        return {s: o[None] for s, o in part.items()}

    in_specs = (P(axis_name, None, None), P(axis_name, None),
                P(axis_name, None), P(axis_name))
    out_specs = {k: (P(axis_name, None, None) if k == "count"
                     else P(axis_name, None, None, None))
                 for k in stats}
    # local import avoids a kernels <-> distributed cycle at module load
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(shard_fn, mesh, in_specs, out_specs)
    partials = f(values.astype(jnp.float32), segment_ids.astype(jnp.int32),
                 valid.astype(bool), slot_ids.astype(jnp.int32))
    return merge_partials(partials)
