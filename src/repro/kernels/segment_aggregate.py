"""Pallas TPU kernel: windowed segment aggregation (reduce-by-key).

The streaming engine's hot loop: fold a block batch of events into
per-key aggregates (sum / count / min / max). TPU adaptation: scatter-by-
key is hostile to the VPU, so the kernel converts the segment reduction
into **one-hot matmuls on the MXU** — ``onehot(ids)^T @ values`` — which is
the TPU-native formulation of reduce-by-key (FeatGraph/GE-SpMM style).

Tiling: grid over event tiles of ``block_n`` rows; each step loads a
[block_n, W] value tile + [block_n] ids into VMEM, builds the [block_n, S]
one-hot in registers, and accumulates [S, W] / [S] outputs that stay
resident in VMEM across the whole grid (output BlockSpecs map every step
to the same block).

The **batched** entry point (``segment_aggregate_batched_pallas``) extends
this to many concurrent windows in one device pass: event rows carry a
2-D segment layout ``(window_slot, key)`` which is flattened into the
segment axis (``sid = slot * S + key``) so a single kernel launch reduces
every due window at once — the engine's multi-window execution path.

The **sharded** entry point (``segment_aggregate_batched_sharded``)
partitions that composite segment axis across a 1-D device mesh: device
``d`` owns the contiguous slot range ``[d*slots_per, (d+1)*slots_per)``
and reduces only the block rows placed in its shard. Slots are disjoint,
so shards never touch each other's outputs and the gather needs **no
cross-device reduction** (no psum) — the output is simply each shard's
``[slots_per, S, ...]`` tile concatenated along the slot axis. Rows must
arrive in shard-major order (``pack_rows_shard_major``); a row whose slot
falls outside its shard's range is defensively masked invalid rather than
corrupting a neighbour's slot.

The **block-table** entry points (``segment_aggregate_block_table_*``)
are the zero-copy gather path over the persistent device block pool
(``core.block_pool``): instead of stacked ``[R, cap, W]`` event tensors
they take the whole ``[pool_slots, cap, W]`` values arena plus a ``[R]``
table of pool-slot indices, and gather each row's event tile from the
arena *inside* the launch — a scalar-prefetched ``index_map`` dereference
on the Mosaic path (the flash-decoding ``block_tables`` idiom, one DMA
per row straight out of the arena), a single ``jnp.take`` along the pool
axis on the dense path. The sharded variant partitions BOTH the arena
and the table over the mesh, so each device gathers only from its own
``[pool_slots/D, ...]`` arena tile — the table stays shard-local.

All Pallas entry points thread ``stats`` through their ``out_shape``s:
sum/count-only folds (average, lrb) never allocate or compute the
min/max VPU broadcast-reduce, matching the dense backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

ALL_STATS = ("sum", "count", "min", "max")


def norm_stats(stats) -> Tuple[str, ...]:
    """Canonicalize a stats selection: fixed order, validated, deduped —
    so jit caches don't fork on permutations of the same request."""
    stats = tuple(stats)
    for s in stats:
        if s not in ALL_STATS:
            raise ValueError(f"unknown stat {s!r} (of {ALL_STATS})")
    out = tuple(s for s in ALL_STATS if s in stats)
    if not out:
        raise ValueError("stats selection is empty")
    return out


def _acc_tile(refs, ids, valid, vals, num_segments: int, n: int) -> None:
    """Accumulate one [n] ids / [n, W] values tile into the stat refs.

    Shared by the flat-grid kernel and the block-table kernel. Only the
    requested stats exist in ``refs``; unrequested aggregates cost
    nothing (the min/max broadcast-reduce temps are never built for
    sum/count-only folds)."""
    seg = jax.lax.broadcasted_iota(jnp.int32, (n, num_segments), 1)
    onehot = (ids[:, None] == seg) & valid[:, None]     # [n, S]
    if "sum" in refs or "count" in refs:
        oh_f = onehot.astype(jnp.float32)
    if "sum" in refs:
        # MXU path: [S, n] @ [n, W]
        refs["sum"][...] += jax.lax.dot_general(
            oh_f, jnp.where(valid[:, None], vals, 0.0),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if "count" in refs:
        refs["count"][...] += jnp.sum(oh_f, axis=0)
    # min/max: masked broadcast-reduce over the tile (VPU path)
    if "min" in refs:
        big = jnp.where(onehot[:, :, None], vals[:, None, :], jnp.inf)
        refs["min"][...] = jnp.minimum(refs["min"][...],
                                       jnp.min(big, axis=0))
    if "max" in refs:
        small = jnp.where(onehot[:, :, None], vals[:, None, :], -jnp.inf)
        refs["max"][...] = jnp.maximum(refs["max"][...],
                                       jnp.max(small, axis=0))


def _init_refs(refs) -> None:
    for name, ref in refs.items():
        if name == "min":
            ref[...] = jnp.full_like(ref, jnp.inf)
        elif name == "max":
            ref[...] = jnp.full_like(ref, -jnp.inf)
        else:
            ref[...] = jnp.zeros_like(ref)


def _kernel(ids_ref, valid_ref, values_ref, *out_refs, num_segments: int,
            block_n: int, stats: Tuple[str, ...]):
    refs = dict(zip(stats, out_refs))
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        _init_refs(refs)

    _acc_tile(refs, ids_ref[...], valid_ref[...] != 0, values_ref[...],
              num_segments, block_n)


def _stat_outputs(stats: Tuple[str, ...], num_segments: int, w: int):
    """(out_shapes, out_specs) for a stats selection; every grid step maps
    to the same (only) block so accumulators stay VMEM-resident (the
    variadic index_maps absorb grid indices and any scalar-prefetch
    operands)."""
    full2 = pl.BlockSpec((num_segments, w), lambda *a: (0, 0))
    full1 = pl.BlockSpec((num_segments,), lambda *a: (0,))
    shapes = []
    specs = []
    for s in stats:
        if s == "count":
            shapes.append(jax.ShapeDtypeStruct((num_segments,), jnp.float32))
            specs.append(full1)
        else:
            shapes.append(jax.ShapeDtypeStruct((num_segments, w),
                                               jnp.float32))
            specs.append(full2)
    return tuple(shapes), tuple(specs)


def segment_aggregate_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                             num_segments: int,
                             valid: Optional[jnp.ndarray] = None,
                             block_n: int = 512,
                             interpret: bool = True,
                             stats: Tuple[str, ...] = ALL_STATS):
    """values [N, W] f32, segment_ids [N] i32 -> dict of [S, W]/[S] aggs.

    N is padded to a multiple of ``block_n``; padding rows are invalid.
    ``stats`` selects which aggregates the kernel materializes (threaded
    through ``out_shape`` — unrequested stats are never computed).
    """
    stats = norm_stats(stats)
    n, w = values.shape
    if valid is None:
        valid = jnp.ones((n,), jnp.int32)
    else:
        valid = valid.astype(jnp.int32)
    block_n = min(block_n, max(n, 8))
    pad = (-n) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    n_pad = n + pad
    grid = (n_pad // block_n,)

    kernel = functools.partial(_kernel, num_segments=num_segments,
                               block_n=block_n, stats=stats)
    out_shapes, out_specs = _stat_outputs(stats, num_segments, w)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(segment_ids.astype(jnp.int32), valid, values.astype(jnp.float32))
    return dict(zip(stats, outs))


def segment_aggregate_batched_pallas(values: jnp.ndarray,
                                     segment_ids: jnp.ndarray,
                                     num_segments: int,
                                     valid: Optional[jnp.ndarray] = None,
                                     slot_ids: Optional[jnp.ndarray] = None,
                                     num_slots: Optional[int] = None,
                                     block_n: int = 512,
                                     interpret: bool = True,
                                     stats: Tuple[str, ...] = ALL_STATS):
    """Multi-window segment aggregation in ONE kernel launch.

    values [B, N, W] f32, segment_ids [B, N] i32 -> per-slot aggregates
    {sum [num_slots, S, W], count [num_slots, S], min, max} — restricted
    to the requested ``stats`` (threaded through the kernel out_shapes,
    so sum/count-only folds skip the min/max VPU work entirely).

    Each of the B rows is a padded event block (``valid`` masks ragged
    fills); ``slot_ids [B]`` maps rows to output window slots, so several
    blocks of the same window may share a slot (default: ``arange(B)``,
    one row per slot). The 2-D segment layout ``(slot, key)`` is flattened
    into the segment axis — ``sid = slot * num_segments + key`` — and fed
    through the same one-hot-matmul grid as the single-window kernel.
    """
    stats = norm_stats(stats)
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))        # [B, N]
    out = segment_aggregate_pallas(
        values.reshape(b * n, w), composite.reshape(b * n),
        num_slots * num_segments, valid=valid.reshape(b * n),
        block_n=block_n, interpret=interpret, stats=stats)
    shaped = {}
    for s in stats:
        if s == "count":
            shaped[s] = out[s].reshape(num_slots, num_segments)
        else:
            shaped[s] = out[s].reshape(num_slots, num_segments, w)
    return shaped


def segment_aggregate_batched_dense(values: jnp.ndarray,
                                    segment_ids: jnp.ndarray,
                                    num_segments: int,
                                    valid: Optional[jnp.ndarray] = None,
                                    slot_ids: Optional[jnp.ndarray] = None,
                                    num_slots: Optional[int] = None,
                                    stats: Tuple[str, ...] = (
                                        "sum", "count", "min", "max")):
    """The kernel's one-hot formulation as plain jnp — the non-TPU hot
    path for the batched engine fold.

    Same contract as ``segment_aggregate_batched_pallas``. XLA:CPU lowers
    ``jax.ops.segment_*`` to serial scatters, which is orders slower than
    the one-hot matmul this uses (identical math to the Mosaic kernel);
    ``stats`` lets callers skip the min/max broadcast-reduce temps when
    only sum/count are needed (the average and LRB folds).
    """
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    s_total = num_slots * num_segments
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32)).reshape(b * n)
    flat_valid = valid.reshape(b * n).astype(bool)
    flat_vals = values.reshape(b * n, w).astype(jnp.float32)
    onehot = (composite[:, None] ==
              jnp.arange(s_total, dtype=jnp.int32)[None, :]) \
        & flat_valid[:, None]                               # [B*N, S]
    oh_f = onehot.astype(jnp.float32)
    out = {}
    if "sum" in stats:
        out["sum"] = jax.lax.dot_general(
            oh_f, jnp.where(flat_valid[:, None], flat_vals, 0.0),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(num_slots, num_segments, w)
    if "count" in stats:
        out["count"] = jnp.sum(oh_f, axis=0).reshape(num_slots,
                                                     num_segments)
    if "min" in stats:
        big = jnp.where(onehot[:, :, None], flat_vals[:, None, :], jnp.inf)
        out["min"] = jnp.min(big, axis=0).reshape(num_slots, num_segments,
                                                  w)
    if "max" in stats:
        small = jnp.where(onehot[:, :, None], flat_vals[:, None, :],
                          -jnp.inf)
        out["max"] = jnp.max(small, axis=0).reshape(num_slots,
                                                    num_segments, w)
    return out


def _bt_kernel(table_ref, ids_ref, valid_ref, arena_ref, *out_refs,
               num_segments: int, cap: int, stats: Tuple[str, ...],
               num_cols: Optional[int]):
    """Block-table kernel body: one grid step per table row. The arena
    BlockSpec's index_map dereferences the scalar-prefetched table, so
    each step DMAs its event tile straight out of the pool arena — the
    row gather happens inside the launch, not as a host/device concat.
    ``num_cols`` selects a value-column prefix AFTER the gather (per-tile
    slice) so width-selecting folds never materialize an arena-wide
    slice copy."""
    refs = dict(zip(stats, out_refs))
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        _init_refs(refs)

    vals = arena_ref[0]
    if num_cols is not None:
        vals = vals[:, :num_cols]
    _acc_tile(refs, ids_ref[0], valid_ref[0] != 0, vals,
              num_segments, cap)


def segment_aggregate_block_table_pallas(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        interpret: bool = True,
        stats: Tuple[str, ...] = ALL_STATS,
        num_cols: Optional[int] = None):
    """Batched fold over a persistent block pool, gathering in-kernel.

    values_arena [pool_slots, cap, W] f32 (the device arena), table [R]
    i32 pool-slot indices, segment_ids [R, cap] i32, slot_ids [R] window
    slots -> per-slot aggregates as ``segment_aggregate_batched_pallas``.
    The table is a scalar-prefetch operand: grid step ``r`` DMAs arena
    row ``table[r]`` into VMEM (flash-decoding's ``block_tables`` idiom),
    so already-resident blocks are folded with zero per-batch copies.
    ``num_cols`` restricts the fold to the leading value columns,
    sliced per-tile inside the kernel (width-selecting operators pass
    the FULL arena — never an arena-wide slice copy).
    """
    stats = norm_stats(stats)
    p, cap, w = values_arena.shape
    w_out = num_cols if num_cols is not None else w
    r = table.shape[0]
    if valid is None:
        valid = jnp.ones((r, cap), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))        # [R, cap]
    s_total = num_slots * num_segments
    kernel = functools.partial(_bt_kernel, num_segments=s_total, cap=cap,
                               stats=stats, num_cols=num_cols)
    out_shapes, out_specs = _stat_outputs(stats, s_total, w_out)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i, tbl: (i, 0)),
            pl.BlockSpec((1, cap), lambda i, tbl: (i, 0)),
            pl.BlockSpec((1, cap, w), lambda i, tbl: (tbl[i], 0, 0)),
        ],
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(table.astype(jnp.int32), composite,
      valid.astype(jnp.int32), values_arena.astype(jnp.float32))
    out = dict(zip(stats, outs))
    shaped = {}
    for s in stats:
        if s == "count":
            shaped[s] = out[s].reshape(num_slots, num_segments)
        else:
            shaped[s] = out[s].reshape(num_slots, num_segments, w_out)
    return shaped


def segment_aggregate_block_table_dense(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None,
        stats: Tuple[str, ...] = ALL_STATS,
        num_cols: Optional[int] = None):
    """Dense-backend block-table fold: ONE ``jnp.take`` along the pool
    axis materializes the batch (a single device gather op, replacing the
    O(rows) per-row concat of the stacked path), then the one-hot fold.
    ``num_cols`` slices the value columns AFTER the gather — O(rows),
    never an arena-wide copy.
    """
    vals = jnp.take(values_arena, table.astype(jnp.int32), axis=0)
    if num_cols is not None:
        vals = vals[:, :, :num_cols]
    return segment_aggregate_batched_dense(
        vals, segment_ids, num_segments, valid=valid, slot_ids=slot_ids,
        num_slots=num_slots, stats=norm_stats(stats))


def segment_aggregate_block_table_sharded(
        values_arena: jnp.ndarray, segment_ids: jnp.ndarray,
        table: jnp.ndarray, num_segments: int,
        valid: Optional[jnp.ndarray] = None,
        slot_ids: Optional[jnp.ndarray] = None,
        num_slots: Optional[int] = None, *, mesh,
        stats: Tuple[str, ...] = ALL_STATS,
        use_pallas: bool = False,
        interpret: bool = True,
        num_cols: Optional[int] = None):
    """Slot-sharded block-table fold over a 1-D mesh.

    Both the pool arena (slot axis) and the table rows partition across
    the mesh: shard ``d`` receives arena tile ``[pool_slots/D, ...]`` and
    its shard-major rows, and rewrites global pool slots / window slots to
    shard-local indices — the block table stays local to each shard, so
    the gather never crosses devices and the output is a pure slot-axis
    concatenation (psum-free, as in the stacked sharded fold). The
    executor's hash-based window placement plus the pool's per-shard slot
    ranges guarantee well-placed rows; a misplaced row (table entry or
    window slot outside the shard's ranges) is defensively masked invalid.
    """
    stats = norm_stats(stats)
    p, cap, w = values_arena.shape
    r = table.shape[0]
    axis_name = mesh.axis_names[0]
    num_devices = mesh.shape[axis_name]
    if valid is None:
        valid = jnp.ones((r, cap), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(r, dtype=jnp.int32)
        if num_slots is None:
            num_slots = r
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if r % num_devices or num_slots % num_devices or p % num_devices:
        raise ValueError(
            f"rows ({r}), slots ({num_slots}) and pool slots ({p}) must "
            f"all divide the slot mesh ({num_devices} devices); pad with "
            "invalid rows (pack_rows_shard_major) and size the pool to "
            "the mesh")
    slots_per = num_slots // num_devices
    pool_per = p // num_devices

    def shard_fn(arena, sid, tbl, val, sl):
        base = jax.lax.axis_index(axis_name)
        local_tbl = tbl.astype(jnp.int32) - base * pool_per
        own_t = (local_tbl >= 0) & (local_tbl < pool_per)
        local_tbl = jnp.where(own_t, local_tbl, 0)
        local_sl = sl.astype(jnp.int32) - base * slots_per
        own_s = (local_sl >= 0) & (local_sl < slots_per)
        local_sl = jnp.where(own_s, local_sl, 0)
        val_own = val.astype(bool) & (own_t & own_s)[:, None]
        if use_pallas:
            return segment_aggregate_block_table_pallas(
                arena, sid, local_tbl, num_segments, valid=val_own,
                slot_ids=local_sl, num_slots=slots_per,
                interpret=interpret, stats=stats, num_cols=num_cols)
        return segment_aggregate_block_table_dense(
            arena, sid, local_tbl, num_segments, valid=val_own,
            slot_ids=local_sl, num_slots=slots_per, stats=stats,
            num_cols=num_cols)

    in_specs = (P(axis_name, None, None), P(axis_name, None),
                P(axis_name), P(axis_name, None), P(axis_name))
    out_specs = {k: (P(axis_name, None) if k == "count"
                     else P(axis_name, None, None))
                 for k in stats}
    # local import avoids a kernels <-> distributed cycle at module load
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(shard_fn, mesh, in_specs, out_specs)
    return f(values_arena.astype(jnp.float32),
             segment_ids.astype(jnp.int32), table.astype(jnp.int32),
             valid.astype(jnp.int32), slot_ids.astype(jnp.int32))


def empty_batch_identity(num_slots: int, num_segments: int, w: int) -> dict:
    """Fold identity per (slot, segment) for an empty batch: zero
    sums/counts, +/-inf extrema. Shared by the public entry point and the
    ref oracle so the B == 0 contract cannot drift between them."""
    return {
        "sum": jnp.zeros((num_slots, num_segments, w), jnp.float32),
        "count": jnp.zeros((num_slots, num_segments), jnp.float32),
        "min": jnp.full((num_slots, num_segments, w), jnp.inf),
        "max": jnp.full((num_slots, num_segments, w), -jnp.inf),
    }


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1). Shared by the batch executor's
    shape bucketing and the shard-major row packing below."""
    return 1 << max(n - 1, 0).bit_length()


def pack_rows_shard_major(slot_ids, num_devices: int, slots_per: int
                          ) -> Tuple[list, int]:
    """Host-side row placement for the sharded fold.

    Groups row indices by owning shard (``slot // slots_per``) and picks
    the common power-of-two per-shard row count every shard pads to, so
    the ``[num_devices * rows_per_shard, ...]`` stack splits evenly under
    a ``shard_map`` over the leading axis. Returns
    ``(per_shard_row_indices, rows_per_shard)``.
    """
    shard = np.asarray(slot_ids, np.int64) // max(slots_per, 1)
    per = [np.flatnonzero(shard == d) for d in range(num_devices)]
    rows_per_shard = next_pow2(max([len(p) for p in per] + [1]))
    return per, rows_per_shard


def segment_aggregate_batched_sharded(values: jnp.ndarray,
                                      segment_ids: jnp.ndarray,
                                      num_segments: int,
                                      valid: Optional[jnp.ndarray] = None,
                                      slot_ids: Optional[jnp.ndarray] = None,
                                      num_slots: Optional[int] = None,
                                      *, mesh,
                                      stats: Tuple[str, ...] = (
                                          "sum", "count", "min", "max"),
                                      use_pallas: bool = False,
                                      block_n: int = 512,
                                      interpret: bool = True):
    """Slot-sharded multi-window segment aggregation over a 1-D mesh.

    Same contract as ``segment_aggregate_batched_pallas`` with one layout
    precondition: rows are **shard-major** — row ``r`` belongs to the
    device ``r // (B / num_devices)``, and its (global) slot id must fall
    in that device's range ``[d*slots_per, (d+1)*slots_per)`` where
    ``slots_per = num_slots / num_devices`` (``pack_rows_shard_major``
    produces this layout). Each shard reduces its own rows into its own
    slot tile; the 2-D ``(slot, key)`` layout makes the tiles disjoint,
    so the gathered output is a pure concatenation along the slot axis —
    **no psum**. Misplaced rows are masked invalid inside the shard (they
    contribute nothing) instead of aliasing into a resident slot.
    """
    stats = norm_stats(stats)
    b, n, w = values.shape
    axis_name = mesh.axis_names[0]
    num_devices = mesh.shape[axis_name]
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if b % num_devices or num_slots % num_devices:
        raise ValueError(
            f"rows ({b}) and slots ({num_slots}) must both divide the "
            f"slot mesh ({num_devices} devices); pad with invalid rows / "
            "unused slots (pack_rows_shard_major)")
    slots_per = num_slots // num_devices

    def shard_fn(v, sid, val, sl):
        base = jax.lax.axis_index(axis_name) * slots_per
        local = sl.astype(jnp.int32) - base
        own = (local >= 0) & (local < slots_per)
        local = jnp.where(own, local, 0)
        val_own = val.astype(bool) & own[:, None]
        if use_pallas:
            return segment_aggregate_batched_pallas(
                v, sid, num_segments, valid=val_own, slot_ids=local,
                num_slots=slots_per, block_n=block_n, interpret=interpret,
                stats=stats)
        return segment_aggregate_batched_dense(
            v, sid, num_segments, valid=val_own, slot_ids=local,
            num_slots=slots_per, stats=stats)

    in_specs = (P(axis_name, None, None), P(axis_name, None),
                P(axis_name, None), P(axis_name))
    out_specs = {k: (P(axis_name, None) if k == "count"
                     else P(axis_name, None, None))
                 for k in stats}
    # local import avoids a kernels <-> distributed cycle at module load
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(shard_fn, mesh, in_specs, out_specs)
    return f(values.astype(jnp.float32), segment_ids.astype(jnp.int32),
             valid.astype(bool), slot_ids.astype(jnp.int32))
