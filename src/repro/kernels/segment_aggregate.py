"""Pallas TPU kernel: windowed segment aggregation (reduce-by-key).

The streaming engine's hot loop: fold a block batch of events into
per-key aggregates (sum / count / min / max). TPU adaptation: scatter-by-
key is hostile to the VPU, so the kernel converts the segment reduction
into **one-hot matmuls on the MXU** — ``onehot(ids)^T @ values`` — which is
the TPU-native formulation of reduce-by-key (FeatGraph/GE-SpMM style).

Tiling: grid over event tiles of ``block_n`` rows; each step loads a
[block_n, W] value tile + [block_n] ids into VMEM, builds the [block_n, S]
one-hot in registers, and accumulates [S, W] / [S] outputs that stay
resident in VMEM across the whole grid (output BlockSpecs map every step
to the same block).

The **batched** entry point (``segment_aggregate_batched_pallas``) extends
this to many concurrent windows in one device pass: event rows carry a
2-D segment layout ``(window_slot, key)`` which is flattened into the
segment axis (``sid = slot * S + key``) so a single kernel launch reduces
every due window at once — the engine's multi-window execution path.

The **sharded** entry point (``segment_aggregate_batched_sharded``)
partitions that composite segment axis across a 1-D device mesh: device
``d`` owns the contiguous slot range ``[d*slots_per, (d+1)*slots_per)``
and reduces only the block rows placed in its shard. Slots are disjoint,
so shards never touch each other's outputs and the gather needs **no
cross-device reduction** (no psum) — the output is simply each shard's
``[slots_per, S, ...]`` tile concatenated along the slot axis. Rows must
arrive in shard-major order (``pack_rows_shard_major``); a row whose slot
falls outside its shard's range is defensively masked invalid rather than
corrupting a neighbour's slot.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P


def _kernel(ids_ref, valid_ref, values_ref, sum_ref, cnt_ref, min_ref,
            max_ref, *, num_segments: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)

    ids = ids_ref[...]                                  # [block_n]
    valid = valid_ref[...] != 0                         # [block_n]
    vals = values_ref[...]                              # [block_n, W]

    seg = jax.lax.broadcasted_iota(jnp.int32, (block_n, num_segments), 1)
    onehot = (ids[:, None] == seg) & valid[:, None]     # [block_n, S]
    oh_f = onehot.astype(jnp.float32)

    # MXU path: [S, block_n] @ [block_n, W]
    sum_ref[...] += jax.lax.dot_general(
        oh_f, jnp.where(valid[:, None], vals, 0.0),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    cnt_ref[...] += jnp.sum(oh_f, axis=0)

    # min/max: masked broadcast-reduce over the tile (VPU path)
    big = jnp.where(onehot[:, :, None], vals[:, None, :], jnp.inf)
    small = jnp.where(onehot[:, :, None], vals[:, None, :], -jnp.inf)
    min_ref[...] = jnp.minimum(min_ref[...], jnp.min(big, axis=0))
    max_ref[...] = jnp.maximum(max_ref[...], jnp.max(small, axis=0))


def segment_aggregate_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                             num_segments: int,
                             valid: Optional[jnp.ndarray] = None,
                             block_n: int = 512,
                             interpret: bool = True):
    """values [N, W] f32, segment_ids [N] i32 -> dict of [S, W]/[S] aggs.

    N is padded to a multiple of ``block_n``; padding rows are invalid.
    """
    n, w = values.shape
    if valid is None:
        valid = jnp.ones((n,), jnp.int32)
    else:
        valid = valid.astype(jnp.int32)
    block_n = min(block_n, max(n, 8))
    pad = (-n) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    n_pad = n + pad
    grid = (n_pad // block_n,)

    kernel = functools.partial(_kernel, num_segments=num_segments,
                               block_n=block_n)
    out_shapes = (
        jax.ShapeDtypeStruct((num_segments, w), jnp.float32),   # sum
        jax.ShapeDtypeStruct((num_segments,), jnp.float32),     # count
        jax.ShapeDtypeStruct((num_segments, w), jnp.float32),   # min
        jax.ShapeDtypeStruct((num_segments, w), jnp.float32),   # max
    )
    full2 = pl.BlockSpec((num_segments, w), lambda i: (0, 0))
    full1 = pl.BlockSpec((num_segments,), lambda i: (0,))
    s, c, mn, mx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ],
        out_specs=(full2, full1, full2, full2),
        out_shape=out_shapes,
        interpret=interpret,
    )(segment_ids.astype(jnp.int32), valid, values.astype(jnp.float32))
    return {"sum": s, "count": c, "min": mn, "max": mx}


def segment_aggregate_batched_pallas(values: jnp.ndarray,
                                     segment_ids: jnp.ndarray,
                                     num_segments: int,
                                     valid: Optional[jnp.ndarray] = None,
                                     slot_ids: Optional[jnp.ndarray] = None,
                                     num_slots: Optional[int] = None,
                                     block_n: int = 512,
                                     interpret: bool = True):
    """Multi-window segment aggregation in ONE kernel launch.

    values [B, N, W] f32, segment_ids [B, N] i32 -> per-slot aggregates
    {sum [num_slots, S, W], count [num_slots, S], min, max}.

    Each of the B rows is a padded event block (``valid`` masks ragged
    fills); ``slot_ids [B]`` maps rows to output window slots, so several
    blocks of the same window may share a slot (default: ``arange(B)``,
    one row per slot). The 2-D segment layout ``(slot, key)`` is flattened
    into the segment axis — ``sid = slot * num_segments + key`` — and fed
    through the same one-hot-matmul grid as the single-window kernel.
    """
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32))        # [B, N]
    out = segment_aggregate_pallas(
        values.reshape(b * n, w), composite.reshape(b * n),
        num_slots * num_segments, valid=valid.reshape(b * n),
        block_n=block_n, interpret=interpret)
    return {
        "sum": out["sum"].reshape(num_slots, num_segments, w),
        "count": out["count"].reshape(num_slots, num_segments),
        "min": out["min"].reshape(num_slots, num_segments, w),
        "max": out["max"].reshape(num_slots, num_segments, w),
    }


def segment_aggregate_batched_dense(values: jnp.ndarray,
                                    segment_ids: jnp.ndarray,
                                    num_segments: int,
                                    valid: Optional[jnp.ndarray] = None,
                                    slot_ids: Optional[jnp.ndarray] = None,
                                    num_slots: Optional[int] = None,
                                    stats: Tuple[str, ...] = (
                                        "sum", "count", "min", "max")):
    """The kernel's one-hot formulation as plain jnp — the non-TPU hot
    path for the batched engine fold.

    Same contract as ``segment_aggregate_batched_pallas``. XLA:CPU lowers
    ``jax.ops.segment_*`` to serial scatters, which is orders slower than
    the one-hot matmul this uses (identical math to the Mosaic kernel);
    ``stats`` lets callers skip the min/max broadcast-reduce temps when
    only sum/count are needed (the average and LRB folds).
    """
    b, n, w = values.shape
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    s_total = num_slots * num_segments
    composite = (slot_ids.astype(jnp.int32)[:, None] * num_segments
                 + segment_ids.astype(jnp.int32)).reshape(b * n)
    flat_valid = valid.reshape(b * n).astype(bool)
    flat_vals = values.reshape(b * n, w).astype(jnp.float32)
    onehot = (composite[:, None] ==
              jnp.arange(s_total, dtype=jnp.int32)[None, :]) \
        & flat_valid[:, None]                               # [B*N, S]
    oh_f = onehot.astype(jnp.float32)
    out = {}
    if "sum" in stats:
        out["sum"] = jax.lax.dot_general(
            oh_f, jnp.where(flat_valid[:, None], flat_vals, 0.0),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(num_slots, num_segments, w)
    if "count" in stats:
        out["count"] = jnp.sum(oh_f, axis=0).reshape(num_slots,
                                                     num_segments)
    if "min" in stats:
        big = jnp.where(onehot[:, :, None], flat_vals[:, None, :], jnp.inf)
        out["min"] = jnp.min(big, axis=0).reshape(num_slots, num_segments,
                                                  w)
    if "max" in stats:
        small = jnp.where(onehot[:, :, None], flat_vals[:, None, :],
                          -jnp.inf)
        out["max"] = jnp.max(small, axis=0).reshape(num_slots,
                                                    num_segments, w)
    return out


def empty_batch_identity(num_slots: int, num_segments: int, w: int) -> dict:
    """Fold identity per (slot, segment) for an empty batch: zero
    sums/counts, +/-inf extrema. Shared by the public entry point and the
    ref oracle so the B == 0 contract cannot drift between them."""
    return {
        "sum": jnp.zeros((num_slots, num_segments, w), jnp.float32),
        "count": jnp.zeros((num_slots, num_segments), jnp.float32),
        "min": jnp.full((num_slots, num_segments, w), jnp.inf),
        "max": jnp.full((num_slots, num_segments, w), -jnp.inf),
    }


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1). Shared by the batch executor's
    shape bucketing and the shard-major row packing below."""
    return 1 << max(n - 1, 0).bit_length()


def pack_rows_shard_major(slot_ids, num_devices: int, slots_per: int
                          ) -> Tuple[list, int]:
    """Host-side row placement for the sharded fold.

    Groups row indices by owning shard (``slot // slots_per``) and picks
    the common power-of-two per-shard row count every shard pads to, so
    the ``[num_devices * rows_per_shard, ...]`` stack splits evenly under
    a ``shard_map`` over the leading axis. Returns
    ``(per_shard_row_indices, rows_per_shard)``.
    """
    shard = np.asarray(slot_ids, np.int64) // max(slots_per, 1)
    per = [np.flatnonzero(shard == d) for d in range(num_devices)]
    rows_per_shard = next_pow2(max([len(p) for p in per] + [1]))
    return per, rows_per_shard


def segment_aggregate_batched_sharded(values: jnp.ndarray,
                                      segment_ids: jnp.ndarray,
                                      num_segments: int,
                                      valid: Optional[jnp.ndarray] = None,
                                      slot_ids: Optional[jnp.ndarray] = None,
                                      num_slots: Optional[int] = None,
                                      *, mesh,
                                      stats: Tuple[str, ...] = (
                                          "sum", "count", "min", "max"),
                                      use_pallas: bool = False,
                                      block_n: int = 512,
                                      interpret: bool = True):
    """Slot-sharded multi-window segment aggregation over a 1-D mesh.

    Same contract as ``segment_aggregate_batched_pallas`` with one layout
    precondition: rows are **shard-major** — row ``r`` belongs to the
    device ``r // (B / num_devices)``, and its (global) slot id must fall
    in that device's range ``[d*slots_per, (d+1)*slots_per)`` where
    ``slots_per = num_slots / num_devices`` (``pack_rows_shard_major``
    produces this layout). Each shard reduces its own rows into its own
    slot tile; the 2-D ``(slot, key)`` layout makes the tiles disjoint,
    so the gathered output is a pure concatenation along the slot axis —
    **no psum**. Misplaced rows are masked invalid inside the shard (they
    contribute nothing) instead of aliasing into a resident slot.
    """
    b, n, w = values.shape
    axis_name = mesh.axis_names[0]
    num_devices = mesh.shape[axis_name]
    if valid is None:
        valid = jnp.ones((b, n), bool)
    if slot_ids is None:
        slot_ids = jnp.arange(b, dtype=jnp.int32)
        if num_slots is None:
            num_slots = b
    elif num_slots is None:
        raise ValueError("num_slots is required when slot_ids is given")
    if b % num_devices or num_slots % num_devices:
        raise ValueError(
            f"rows ({b}) and slots ({num_slots}) must both divide the "
            f"slot mesh ({num_devices} devices); pad with invalid rows / "
            "unused slots (pack_rows_shard_major)")
    slots_per = num_slots // num_devices

    def shard_fn(v, sid, val, sl):
        base = jax.lax.axis_index(axis_name) * slots_per
        local = sl.astype(jnp.int32) - base
        own = (local >= 0) & (local < slots_per)
        local = jnp.where(own, local, 0)
        val_own = val.astype(bool) & own[:, None]
        if use_pallas:
            out = segment_aggregate_batched_pallas(
                v, sid, num_segments, valid=val_own, slot_ids=local,
                num_slots=slots_per, block_n=block_n, interpret=interpret)
            return {k: o for k, o in out.items() if k in stats}
        return segment_aggregate_batched_dense(
            v, sid, num_segments, valid=val_own, slot_ids=local,
            num_slots=slots_per, stats=stats)

    in_specs = (P(axis_name, None, None), P(axis_name, None),
                P(axis_name, None), P(axis_name))
    out_specs = {k: (P(axis_name, None) if k == "count"
                     else P(axis_name, None, None))
                 for k in stats}
    # local import avoids a kernels <-> distributed cycle at module load
    from repro.distributed.sharding import shard_map_compat
    f = shard_map_compat(shard_fn, mesh, in_specs, out_specs)
    return f(values.astype(jnp.float32), segment_ids.astype(jnp.int32),
             valid.astype(bool), slot_ids.astype(jnp.int32))
