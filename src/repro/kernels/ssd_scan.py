"""Pallas TPU kernel: Mamba-2 SSD chunk scan (forward).

The quadratic intra-chunk term runs on the MXU ([Q, Q] score tiles per
head block); the inter-chunk SSM state [hb, P, N] persists in VMEM scratch
across the (sequential, innermost) chunk grid axis — the recurrence never
round-trips to HBM.

Grid: (batch, head_blocks, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, a_ref, b_in_ref, c_in_ref, y_ref, state_scr, *,
            chunk: int, hb: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0].astype(jnp.float32)        # [Q, hb, P]
    a = a_ref[0].astype(jnp.float32)            # [Q, hb]
    Bc = b_in_ref[0].astype(jnp.float32)        # [Q, N]
    Cc = c_in_ref[0].astype(jnp.float32)        # [Q, N]

    cum = jnp.cumsum(a, axis=0)                 # [Q, hb]
    total = cum[-1]                             # [hb]

    # intra-chunk: scores [Q, Q] on the MXU, decay per head
    scores = jax.lax.dot_general(
        Cc, Bc, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # [Qi, Qj]
    ldecay = cum[:, None, :] - cum[None, :, :]  # [Qi, Qj, hb]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    qj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = qi >= qj
    L = jnp.where(mask[:, :, None], jnp.exp(ldecay), 0.0)
    w_intra = scores[:, :, None] * L            # [Qi, Qj, hb]
    # y_intra[i,h,p] = sum_j w_intra[i,j,h] * xdt[j,h,p]
    y_intra = jnp.einsum("ijh,jhp->ihp", w_intra, xdt,
                         preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    state = state_scr[...]                      # [hb, P, N]
    y_inter = jnp.einsum("in,hpn->ihp", Cc, state,
                         preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, :, None]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    w = jnp.exp(total[None, :] - cum)           # [Q, hb]
    xw = xdt * w[:, :, None]                    # [Q, hb, P]
    chunk_state = jnp.einsum("jhp,jn->hpn", xw, Bc,
                             preferred_element_type=jnp.float32)
    state_scr[...] = jnp.exp(total)[:, None, None] * state + chunk_state


def ssd_scan_pallas(xdt: jnp.ndarray, a: jnp.ndarray, B: jnp.ndarray,
                    C: jnp.ndarray, chunk: int, head_block: int = 8,
                    interpret: bool = True) -> jnp.ndarray:
    """xdt [b, s, h, p] (x*dt); a [b, s, h] (dt*A); B, C [b, s, n].
    Returns y [b, s, h, p] (the final state stays device-side in scratch;
    the ops.py wrapper recomputes it via the ref when needed)."""
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, "seq must be a multiple of the chunk"
    hb = min(head_block, h)
    assert h % hb == 0
    nc = s // q
    grid = (b, h // hb, nc)

    kernel = functools.partial(_kernel, chunk=q, hb=hb)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, hb, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, hb), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, hb, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((hb, p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, a, B, C)
    return y
