from repro.kernels.ops import (
    decode_attention_paged,
    flash_attention,
    flash_attention_vjp,
    segment_aggregate,
    segment_aggregate_batched,
    segment_aggregate_block_table,
    segment_aggregate_block_table_splitk,
    ssd_chunk_scan,
)

__all__ = [
    "decode_attention_paged", "flash_attention", "flash_attention_vjp",
    "segment_aggregate", "segment_aggregate_batched",
    "segment_aggregate_block_table", "segment_aggregate_block_table_splitk",
    "ssd_chunk_scan",
]
