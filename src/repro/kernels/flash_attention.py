"""Pallas TPU kernel: flash attention (forward).

IO-aware attention for the prefill path (FlashAttention, arXiv:2205.14135,
re-tiled for TPU): grid = (batch*kv_heads*groups, q_blocks, kv_blocks) with
the kv dimension innermost so the [block_q, head_dim] accumulator and the
running (m, l) statistics stay in VMEM scratch across kv steps. Causal and
sliding-window masking are applied per tile; fully-masked tiles still run
(Pallas TPU grids are dense) but cost only a masked matmul.

MXU alignment: block_q/block_k default to 512/512 and head_dim is padded
to a multiple of 128 by the wrapper (ops.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, num_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                       # [block_q, d]
    k = k_ref[0]                                       # [block_k, d]
    v = v_ref[0]

    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = True,
                           return_lse: bool = False):
    """q [B, Sq, H, D]; k, v [B, Sk, Hkv, D] -> [B, Sq, H, D].

    H % Hkv == 0 (GQA); Sq % block_q == 0 and Sk % block_k == 0 (the ops.py
    wrapper pads).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0

    # flatten (b, hkv, g) into one grid axis; k/v index ignores g
    qf = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv * g, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)

    num_q_blocks = sq // block_q
    num_kv_blocks = sk // block_k
    grid = (b * hkv * g, num_q_blocks, num_kv_blocks)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=num_kv_blocks)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, kj, g=g: (bh // g, kj, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, kj, g=g: (bh // g, kj, 0)),
        ],
        out_specs=(pl.BlockSpec((1, block_q, d),
                                lambda bh, qi, kj: (bh, qi, 0)),
                   pl.BlockSpec((1, block_q),
                                lambda bh, qi, kj: (bh, qi))),
        out_shape=(jax.ShapeDtypeStruct((b * hkv * g, sq, d), q.dtype),
                   jax.ShapeDtypeStruct((b * hkv * g, sq), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    o4 = out.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, h, d)
    if return_lse:
        return o4, lse                       # lse stays head-flattened
    return o4
