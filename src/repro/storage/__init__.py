"""Persistent p-bucket storage: the BlockStore interface, the
log-structured backend (segmented value log + WAL recovery +
cleanup-driven compaction), and the legacy file-per-block npz fallback.
"""
from repro.storage.blockstore import (
    BlockKey, BlockStore, PermanentStoreError, SimulatedCost,
    TransientStoreError, WindowKey, is_transient_error,
    normalize_window_key, payload_nbytes,
)
from repro.storage.logstore import LogBlockStore
from repro.storage.npzstore import NpzBlockStore


def make_store(backend: str, directory, *, segment_bytes: int = 1 << 20,
               sim_spb: float = 0.0,
               readahead_bytes: int = 16 << 20,
               registry=None) -> BlockStore:
    """Build a store by config name (``AionConfig.store_backend``)."""
    if backend == "log":
        return LogBlockStore(directory, segment_bytes=segment_bytes,
                             sim_spb=sim_spb,
                             readahead_bytes=readahead_bytes,
                             registry=registry)
    if backend == "npz":
        return NpzBlockStore(directory, sim_spb=sim_spb, registry=registry)
    raise ValueError(f"unknown store backend: {backend!r}")


__all__ = [
    "BlockKey", "BlockStore", "LogBlockStore", "NpzBlockStore",
    "PermanentStoreError", "SimulatedCost", "TransientStoreError",
    "WindowKey", "is_transient_error", "make_store",
    "normalize_window_key", "payload_nbytes",
]
