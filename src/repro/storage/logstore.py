"""Log-structured block store: segmented value log + WAL group commit +
cleanup-driven compaction.

The persistent tier of the p-bucket, built the way long-window streaming
stores are (RocksDB under Flink/Aion, Railgun's batched persistent
writes): blocks append to a fixed-size **segmented value log** instead of
one file per block, so spill pressure turns into sequential writes and a
batched fetch turns into one sweep per segment.

On-disk layout (``directory/``)::

    seg-00000000.log     sealed segment: records ... footer(index)
    seg-00000001.log     active segment: records ... (tail may be torn)
    wal.log              group-commit journal for the active segment

**Records** — ``header | payload | crc32``. The header carries the
``(window_start, window_end, block_id)`` key plus ``(fill, capacity,
width)``; the payload is the fill-sliced SoA event data (int32 keys,
float64 timestamps, float32 values — capacity padding is *not* written;
reads re-pad). A tombstone is a record with an empty payload.

**Group commit** — ``put``/``delete`` append to the active segment
through a buffered handle; ``commit()`` flushes + fsyncs the segment,
then appends an acknowledgement ``(segment, committed_offset)`` to the
WAL (flushed + fsynced). A crash after ``commit`` returns loses nothing
acknowledged; anything past the last WAL ack — a torn record from a
crash mid-spill, or fully-written-but-unacknowledged records — is
truncated away on reopen (those blocks still held their host copies; the
spill was never acknowledged).

**Recovery / open** — sealed segments rebuild the in-memory index
``(window_id, block_id) -> (segment, offset)`` from their footers (no
payload reads); the active segment is scanned record-by-record with
checksum validation up to the WAL ack and truncated there. Replay is in
``(segment, offset)`` order: later records supersede earlier ones,
tombstones delete.

**Compaction** — predictive cleanup's purge emits tombstones
(``delete``); ``compact_if_needed`` consumes them, rewriting a victim
segment's live records into the active segment and dropping the file,
until on-disk bytes <= max(ratio x live record bytes, one segment) — the
paper's §3.4 "storage consumption stays bounded" claim, now enforced and
tested. A tombstone is carried forward only while stale value records
for its key survive in other segments (the ``_key_copies`` refcount), so
deleted keys can never resurrect on replay.

**Readahead** — ``readahead(keys)`` batch-reads records (sorted by
segment/offset: sequential sweeps) into a bounded LRU byte-cache that
``get`` consumes; proactive pre-staging drives it ahead of demand, which
is what makes store readahead a measurable, first-class interface
(hit/miss/bytes counters in ``stats``).
"""
from __future__ import annotations

import json
import os
import struct
import threading
import zlib
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.storage.blockstore import (
    BlockKey, BlockStore, WindowKey, normalize_window_key, payload_nbytes,
)

REC_VALUE = 0
REC_TOMB = 1

_REC_MAGIC = 0xA10B10C5
_FOOT_MAGIC = 0xF007A10B
_WAL_MAGIC = 0x3A11A10B

# magic, rtype, block_id, wstart, wend, fill, capacity, width
_REC_HDR = struct.Struct("<IBQddIII")
_CRC = struct.Struct("<I")
# json_len, crc32(json), magic — the fixed footer trailer
_FOOT = struct.Struct("<III")
# magic, segment_id, committed_offset, crc32(first 16 bytes)
_WAL = struct.Struct("<IIQI")


class _Entry:
    """One record's metadata (index entry / footer row)."""
    __slots__ = ("rtype", "key", "fill", "cap", "width", "offset",
                 "rec_len")

    def __init__(self, rtype: int, key: BlockKey, fill: int, cap: int,
                 width: int, offset: int, rec_len: int):
        self.rtype = rtype
        self.key = key
        self.fill = fill
        self.cap = cap
        self.width = width
        self.offset = offset
        self.rec_len = rec_len

    def to_json(self):
        (ws, we), bid = self.key
        return [self.rtype, ws, we, bid, self.fill, self.cap, self.width,
                self.offset, self.rec_len]

    @staticmethod
    def from_json(row) -> "_Entry":
        rtype, ws, we, bid, fill, cap, width, offset, rec_len = row
        return _Entry(int(rtype), ((float(ws), float(we)), int(bid)),
                      int(fill), int(cap), int(width), int(offset),
                      int(rec_len))


class _Seg:
    __slots__ = ("sid", "path", "size", "sealed", "live_bytes",
                 "dead_bytes", "entries")

    def __init__(self, sid: int, path: Path):
        self.sid = sid
        self.path = path
        self.size = 0
        self.sealed = False
        self.live_bytes = 0          # record bytes of live value records
        self.dead_bytes = 0          # superseded/tombstoned + tombstones
        self.entries: List[_Entry] = []


def _encode_record(rtype: int, key: BlockKey, fill: int, cap: int,
                   width: int, payload: bytes) -> bytes:
    (ws, we), bid = key
    hdr = _REC_HDR.pack(_REC_MAGIC, rtype, bid, ws, we, fill, cap, width)
    crc = zlib.crc32(hdr[4:]) & 0xFFFFFFFF
    crc = zlib.crc32(payload, crc) & 0xFFFFFFFF
    return hdr + payload + _CRC.pack(crc)


def _payload_len(fill: int, width: int) -> int:
    return payload_nbytes(fill, width)


class LogBlockStore(BlockStore):
    """Segmented append-only value log with WAL recovery."""

    name = "log"
    durable_writes = True

    def __init__(self, directory: Path, *, segment_bytes: int = 1 << 20,
                 sim_spb: float = 0.0, readahead_bytes: int = 16 << 20,
                 fsync: bool = True, registry=None):
        super().__init__(sim_spb=sim_spb, registry=registry)
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = max(int(segment_bytes), 4096)
        self.readahead_bytes = readahead_bytes
        self._fsync = fsync
        self._lock = threading.RLock()
        self._segs: Dict[int, _Seg] = {}
        # (window_key, block_id) -> live record entry (entry.offset in
        # its segment); THE index the p-bucket keeps in memory
        self._index: Dict[BlockKey, Tuple[int, _Entry]] = {}
        # value-record instances per key across ALL segments (live or
        # dead) — the tombstone-drop rule at compaction
        self._key_copies: Dict[BlockKey, int] = {}
        self._live_payload = 0
        self._cache: "OrderedDict[BlockKey, Tuple[dict, int]]" = \
            OrderedDict()
        self._cache_bytes = 0
        # keys a readahead() was asked to prefetch and has not yet been
        # consumed/abandoned for — hit/miss counters measure READAHEAD
        # effectiveness, not plain demand reads that never had a
        # prefetch opportunity
        self._readahead_wanted: set = set()
        self._active_f = None
        self._wal_f = None
        self._dirty = False
        self.stats.update({
            "recovered_records": 0, "recovery_truncated_bytes": 0,
            "segments_sealed": 0, "wal_commits": 0,
            "segment_sweeps": 0, "sweep_bytes_read": 0,
            "coalesced_windows": 0, "coalesce_bytes": 0,
        })
        self._recover()

    # --------------------------------------------------------------- paths
    def _seg_path(self, sid: int) -> Path:
        return self.directory / f"seg-{sid:08d}.log"

    @property
    def _wal_path(self) -> Path:
        return self.directory / "wal.log"

    def active_segment_path(self) -> Path:
        """Path of the active segment (fault-injection hooks in tests)."""
        with self._lock:
            return self._active.path

    # ------------------------------------------------------------ recovery
    def _read_wal_ack(self) -> Tuple[Optional[int], int]:
        """(segment_id, committed_offset) of the last valid WAL entry."""
        sid, off = None, 0
        p = self._wal_path
        if not p.exists():
            return sid, off
        data = p.read_bytes()
        for i in range(0, len(data) - _WAL.size + 1, _WAL.size):
            try:
                magic, s, o, crc = _WAL.unpack_from(data, i)
            except struct.error:
                break
            if magic != _WAL_MAGIC:
                break
            if (zlib.crc32(data[i:i + 16]) & 0xFFFFFFFF) != crc:
                break
            sid, off = s, o
        return sid, off

    def _scan_segment(self, path: Path, limit: int) -> Tuple[List[_Entry],
                                                             int]:
        """Record-by-record scan with checksum validation, stopping at
        ``limit`` bytes or the first torn/corrupt record. Returns the
        entries of the valid prefix and its length."""
        entries: List[_Entry] = []
        size = path.stat().st_size
        end = min(size, limit)
        with open(path, "rb") as f:
            off = 0
            while off + _REC_HDR.size + _CRC.size <= end:
                f.seek(off)
                hdr = f.read(_REC_HDR.size)
                if len(hdr) < _REC_HDR.size:
                    break
                try:
                    magic, rtype, bid, ws, we, fill, cap, width = \
                        _REC_HDR.unpack(hdr)
                except struct.error:
                    break
                if magic != _REC_MAGIC:
                    break
                plen = _payload_len(fill, width) if rtype == REC_VALUE \
                    else 0
                rec_len = _REC_HDR.size + plen + _CRC.size
                if off + rec_len > end:
                    break                       # torn tail
                payload = f.read(plen)
                (crc,) = _CRC.unpack(f.read(_CRC.size))
                want = zlib.crc32(hdr[4:]) & 0xFFFFFFFF
                want = zlib.crc32(payload, want) & 0xFFFFFFFF
                if crc != want:
                    break                       # corrupt record
                entries.append(_Entry(rtype, ((ws, we), bid), fill, cap,
                                      width, off, rec_len))
                off += rec_len
        return entries, off

    def _parse_footer(self, path: Path) -> Optional[Tuple[List[_Entry],
                                                          int]]:
        """(entries, total_size) when ``path`` carries a valid seal
        footer, else None."""
        size = path.stat().st_size
        if size < _FOOT.size:
            return None
        with open(path, "rb") as f:
            f.seek(size - _FOOT.size)
            jlen, jcrc, magic = _FOOT.unpack(f.read(_FOOT.size))
            if magic != _FOOT_MAGIC or jlen > size - _FOOT.size:
                return None
            f.seek(size - _FOOT.size - jlen)
            raw = f.read(jlen)
        if (zlib.crc32(raw) & 0xFFFFFFFF) != jcrc:
            return None
        try:
            rows = json.loads(raw.decode("utf-8"))
        except ValueError:
            return None
        return [_Entry.from_json(r) for r in rows], size

    def _recover(self) -> None:
        wal_sid, wal_off = self._read_wal_ack()
        sids = sorted(int(p.stem.split("-")[1])
                      for p in self.directory.glob("seg-*.log"))
        replay: List[Tuple[int, _Entry]] = []
        active_sid = None
        for sid in sids:
            path = self._seg_path(sid)
            seg = _Seg(sid, path)
            footer = self._parse_footer(path)
            if footer is not None:
                seg.entries, seg.size = footer
                seg.sealed = True
            else:
                # unsealed: trust only what the WAL acknowledged
                limit = wal_off if sid == wal_sid else 0
                seg.entries, valid = self._scan_segment(path, limit)
                lost = path.stat().st_size - valid
                if lost > 0:
                    with open(path, "r+b") as f:
                        f.truncate(valid)
                    self.stats["recovery_truncated_bytes"] += lost
                seg.size = valid
                if seg.size == 0 and sid != max(sids):
                    # an empty torn segment in the middle: drop it
                    os.unlink(path)
                    continue
                active_sid = sid
            self._segs[sid] = seg
            for e in seg.entries:
                replay.append((sid, e))
        # replay in (segment, offset) order: later supersedes earlier,
        # tombstones delete
        for sid, e in replay:
            self._apply_entry(sid, e)
            self.stats["recovered_records"] += 1
        if active_sid is None:
            active_sid = (max(sids) + 1) if sids else 0
            seg = _Seg(active_sid, self._seg_path(active_sid))
            seg.path.touch()
            self._segs[active_sid] = seg
        self._active_sid = active_sid
        self._active_f = open(self._active.path, "ab")
        self._reset_wal()

    def _apply_entry(self, sid: int, e: _Entry) -> None:
        """Replay one record into the index/accounting state."""
        if e.rtype == REC_VALUE:
            old = self._index.get(e.key)
            if old is not None:
                self._kill(old)
            self._index[e.key] = (sid, e)
            self._segs[sid].live_bytes += e.rec_len
            self._live_payload += _payload_len(e.fill, e.width)
            self._key_copies[e.key] = self._key_copies.get(e.key, 0) + 1
        else:
            old = self._index.pop(e.key, None)
            if old is not None:
                self._kill(old)
            self._segs[sid].dead_bytes += e.rec_len  # tombstones are
            # dead weight themselves, reclaimable under the copies rule

    def _kill(self, loc: Tuple[int, _Entry]) -> None:
        """Move a live record to the dead ledger of its segment."""
        sid, e = loc
        seg = self._segs.get(sid)
        if seg is not None:
            seg.live_bytes -= e.rec_len
            seg.dead_bytes += e.rec_len
        self._live_payload -= _payload_len(e.fill, e.width)

    # ---------------------------------------------------------- active seg
    @property
    def _active(self) -> _Seg:
        return self._segs[self._active_sid]

    def _reset_wal(self) -> None:
        """Start a fresh WAL generation acknowledging the active segment
        at its current size (sealed segments carry their own footers).

        The new WAL is written to a temp file and renamed over the old
        one — truncating in place would open a crash window in which the
        only ack covering the active segment is gone and recovery would
        wrongly truncate acknowledged records to offset 0."""
        if self._wal_f is not None:
            self._wal_f.close()
        tmp = self._wal_path.with_suffix(".tmp")
        self._wal_f = open(tmp, "wb")
        self._append_wal_ack()
        os.replace(tmp, self._wal_path)
        # reopen under the final name so later acks append to the real
        # WAL, not a dangling inode
        self._wal_f.close()
        self._wal_f = open(self._wal_path, "ab")

    def _append_wal_ack(self) -> None:
        head = _WAL.pack(_WAL_MAGIC, self._active_sid,
                         self._active.size, 0)[:16]
        self._wal_f.write(head + _CRC.pack(zlib.crc32(head) & 0xFFFFFFFF))
        self._wal_f.flush()
        if self._fsync:
            os.fsync(self._wal_f.fileno())
        self.stats["wal_commits"] += 1

    def _maybe_roll(self, incoming_len: int) -> None:
        a = self._active
        if a.size > 0 and a.size + incoming_len > self.segment_bytes:
            self._commit_locked()
            self._seal_active()

    def _seal_active(self) -> None:
        """Footer the committed active segment and open a fresh one."""
        a = self._active
        raw = json.dumps([e.to_json() for e in a.entries],
                         separators=(",", ":")).encode("utf-8")
        self._active_f.write(raw + _FOOT.pack(
            len(raw), zlib.crc32(raw) & 0xFFFFFFFF, _FOOT_MAGIC))
        self._active_f.flush()
        if self._fsync:
            os.fsync(self._active_f.fileno())
        self._active_f.close()
        a.size += len(raw) + _FOOT.size
        a.sealed = True
        self.stats["segments_sealed"] += 1
        sid = self._active_sid + 1
        seg = _Seg(sid, self._seg_path(sid))
        seg.path.touch()
        self._segs[sid] = seg
        self._active_sid = sid
        self._active_f = open(seg.path, "ab")
        self._dirty = False
        self._reset_wal()

    def _append_record(self, rtype: int, key: BlockKey, fill: int,
                       cap: int, width: int, payload: bytes) -> Tuple[int,
                                                                      int]:
        rec = _encode_record(rtype, key, fill, cap, width, payload)
        self._maybe_roll(len(rec))
        a = self._active
        offset = a.size
        self._active_f.write(rec)
        e = _Entry(rtype, key, fill, cap, width, offset, len(rec))
        a.entries.append(e)
        a.size += len(rec)
        self._dirty = True
        self.stats["bytes_written"] += len(rec)
        self._apply_entry(a.sid, e)
        return a.sid, offset

    # ------------------------------------------------------------- writes
    def put(self, window_key, block_id, arrays, fill):
        wk = normalize_window_key(window_key)
        key = (wk, int(block_id))
        fill = int(fill)
        cap = int(arrays["keys"].shape[0])
        width = int(arrays["values"].shape[1])
        payload = (
            np.ascontiguousarray(arrays["keys"][:fill],
                                 np.int32).tobytes()
            + np.ascontiguousarray(arrays["timestamps"][:fill],
                                   np.float64).tobytes()
            + np.ascontiguousarray(arrays["values"][:fill],
                                   np.float32).tobytes())
        with self._lock:
            self._cache_drop(key)
            ref = self._append_record(REC_VALUE, key, fill, cap, width,
                                      payload)
            self.stats["puts"] += 1
            self.stats["logical_bytes_written"] += len(payload)
            return ref

    def delete(self, window_key, block_id) -> None:
        key = (normalize_window_key(window_key), int(block_id))
        with self._lock:
            self._cache_drop(key)
            if key not in self._index:
                return
            self._append_record(REC_TOMB, key, 0, 0, 0, b"")
            self.stats["deletes"] += 1

    def commit(self) -> None:
        with self._lock:
            self._commit_locked()

    def _commit_locked(self) -> None:
        if not self._dirty:
            return
        self._active_f.flush()
        if self._fsync:
            os.fsync(self._active_f.fileno())
        self._append_wal_ack()
        self._dirty = False
        self.stats["commits"] += 1

    # -------------------------------------------------------------- reads
    def _cache_drop(self, key: BlockKey) -> None:
        hit = self._cache.pop(key, None)
        if hit is not None:
            self._cache_bytes -= hit[1]

    def _cache_add(self, key: BlockKey, arrays: dict, nbytes: int) -> None:
        self._cache_drop(key)
        self._cache[key] = (arrays, nbytes)
        self._cache_bytes += nbytes
        while self._cache_bytes > self.readahead_bytes and self._cache:
            _, (_, nb) = self._cache.popitem(last=False)
            self._cache_bytes -= nb

    def _decode(self, e: _Entry, payload: bytes) -> dict:
        """Full-capacity SoA arrays from a record payload (re-pad)."""
        n0 = e.fill * 4
        n1 = n0 + e.fill * 8
        keys = np.zeros((e.cap,), np.int32)
        ts = np.zeros((e.cap,), np.float64)
        vals = np.zeros((e.cap, e.width), np.float32)
        if e.fill:
            keys[:e.fill] = np.frombuffer(payload[:n0], np.int32)
            ts[:e.fill] = np.frombuffer(payload[n0:n1], np.float64)
            vals[:e.fill] = np.frombuffer(
                payload[n1:], np.float32).reshape(e.fill, e.width)
        return {"keys": keys, "timestamps": ts, "values": vals}

    @staticmethod
    def _record_payload(rec: bytes) -> Optional[bytes]:
        """CRC-validated payload of one raw record, or None if torn or
        corrupt."""
        payload = rec[_REC_HDR.size:-_CRC.size]
        (crc,) = _CRC.unpack(rec[-_CRC.size:])
        want = zlib.crc32(rec[4:_REC_HDR.size]) & 0xFFFFFFFF
        want = zlib.crc32(payload, want) & 0xFFFFFFFF
        return payload if crc == want else None

    def _read_records(self, locs: List[Tuple[BlockKey, int, _Entry]]
                      ) -> Dict[BlockKey, dict]:
        """Batched record reads, one sequential sweep per segment."""
        out: Dict[BlockKey, dict] = {}
        by_seg: Dict[int, List[Tuple[BlockKey, _Entry]]] = {}
        for key, sid, e in locs:
            by_seg.setdefault(sid, []).append((key, e))
        for sid, items in by_seg.items():
            seg = self._segs.get(sid)
            if seg is None:
                continue
            if sid == self._active_sid:
                self._active_f.flush()     # make buffered tail readable
            with open(seg.path, "rb") as f:
                for key, e in sorted(items, key=lambda it: it[1].offset):
                    f.seek(e.offset)
                    rec = f.read(e.rec_len)
                    if len(rec) < e.rec_len:
                        continue
                    payload = self._record_payload(rec)
                    if payload is None:
                        continue
                    out[key] = self._decode(e, payload)
                    self.stats["bytes_read"] += e.rec_len
        return out

    def get(self, window_key, block_id):
        key = (normalize_window_key(window_key), int(block_id))
        with self._lock:
            hit = self._cache.pop(key, None)
            if hit is not None:
                self._cache_bytes -= hit[1]
                self.stats["gets"] += 1
                if key in self._readahead_wanted:
                    self._readahead_wanted.discard(key)
                    self.stats["readahead_hits"] += 1
                return hit[0]
            loc = self._index.get(key)
            if loc is None:
                return None
            self.stats["gets"] += 1
            if key in self._readahead_wanted:
                # a prefetch was requested but the entry is gone
                # (evicted, or invalidated by a re-put): that is a
                # readahead miss; plain demand reads with no prefetch
                # opportunity do not count
                self._readahead_wanted.discard(key)
                self.stats["readahead_misses"] += 1
            got = self._read_records([(key, loc[0], loc[1])])
            return got.get(key)

    def get_many(self, keys: List[BlockKey]):
        with self._lock:
            self.stats["batched_reads"] += 1
            normed = [(normalize_window_key(wk), int(bid))
                      for wk, bid in keys]
            results: Dict[BlockKey, Optional[dict]] = {}
            misses: List[Tuple[BlockKey, int, _Entry]] = []
            for key in normed:
                hit = self._cache.pop(key, None)
                if hit is not None:
                    self._cache_bytes -= hit[1]
                    if key in self._readahead_wanted:
                        self._readahead_wanted.discard(key)
                        self.stats["readahead_hits"] += 1
                    results[key] = hit[0]
                    continue
                loc = self._index.get(key)
                if loc is None:
                    results[key] = None
                else:
                    if key in self._readahead_wanted:
                        self._readahead_wanted.discard(key)
                        self.stats["readahead_misses"] += 1
                    misses.append((key, loc[0], loc[1]))
            got = self._read_records(misses)
            self.stats["gets"] += len(normed)
            return [results[key] if key in results else got.get(key)
                    for key in normed]

    def readahead(self, keys: Iterable[BlockKey]) -> None:
        with self._lock:
            want: List[Tuple[BlockKey, int, _Entry]] = []
            for wk, bid in keys:
                key = (normalize_window_key(wk), int(bid))
                loc = self._index.get(key)
                if loc is None:
                    continue
                self._readahead_wanted.add(key)
                if key in self._cache:
                    continue
                want.append((key, loc[0], loc[1]))
            if not want:
                return
            got = self._read_records(want)
            for key, _, e in want:
                arrays = got.get(key)
                if arrays is not None:
                    # budget the cache by what actually sits in memory:
                    # the decoded FULL-CAPACITY arrays, not the
                    # fill-sliced on-disk record (a near-empty tail
                    # block decodes to capacity-sized arrays)
                    decoded = payload_nbytes(e.cap, e.width)
                    self._cache_add(key, arrays, decoded)
                    self.stats["readahead_bytes"] += e.rec_len

    # ------------------------------------------- segment-granular prefetch
    def segments_for(self, keys):
        """Physical placement of the live records behind ``keys``:
        ``segment_id -> [(key, offset, record_len)]``, offsets ascending.
        Pure index query (no payload reads) — the learned prefetch
        planner merges this across windows into per-segment sweeps."""
        out: Dict[int, List[Tuple[BlockKey, int, int]]] = {}
        with self._lock:
            for wk, bid in keys:
                key = (normalize_window_key(wk), int(bid))
                loc = self._index.get(key)
                if loc is None:
                    continue
                sid, e = loc
                out.setdefault(sid, []).append((key, e.offset, e.rec_len))
        for items in out.values():
            items.sort(key=lambda it: it[1])
        return out

    def readahead_segments(self, sid, keys):
        """Sweep segment ``sid`` once — one contiguous read spanning
        ``keys``'s records — and cache the decoded blocks. Records whose
        live copy moved to another segment (re-put, compaction) since
        planning are skipped; a very sparse span degrades gracefully to
        the per-record batched path. Returns blocks cached."""
        with self._lock:
            seg = self._segs.get(sid)
            if seg is None:
                return 0
            want: List[Tuple[BlockKey, _Entry]] = []
            for wk, bid in keys:
                key = (normalize_window_key(wk), int(bid))
                loc = self._index.get(key)
                if loc is None or loc[0] != sid:
                    continue
                self._readahead_wanted.add(key)
                if key in self._cache:
                    continue
                want.append((key, loc[1]))
            if not want:
                return 0
            want.sort(key=lambda it: it[1].offset)
            lo = want[0][1].offset
            hi = max(e.offset + e.rec_len for _, e in want)
            rec_bytes = sum(e.rec_len for _, e in want)
            span = hi - lo
            if span > 4 * rec_bytes and span - rec_bytes > (64 << 10):
                # plan went stale (compaction/superseding holes): the
                # sequential read would mostly drag dead bytes — fall
                # back to the per-record sweep
                got = self._read_records([(k, sid, e) for k, e in want])
            else:
                if sid == self._active_sid:
                    self._active_f.flush()
                with open(seg.path, "rb") as f:
                    f.seek(lo)
                    blob = f.read(span)
                self.stats["bytes_read"] += len(blob)
                self.stats["sweep_bytes_read"] += len(blob)
                got = {}
                for key, e in want:
                    rec = blob[e.offset - lo:e.offset - lo + e.rec_len]
                    if len(rec) < e.rec_len:
                        continue
                    payload = self._record_payload(rec)
                    if payload is not None:
                        got[key] = self._decode(e, payload)
            self.stats["segment_sweeps"] += 1
            for key, e in want:
                arrays = got.get(key)
                if arrays is not None:
                    decoded = payload_nbytes(e.cap, e.width)
                    self._cache_add(key, arrays, decoded)
                    self.stats["readahead_bytes"] += e.rec_len
            return len(got)

    def _window_locs(self, wk: WindowKey
                     ) -> List[Tuple[BlockKey, int, _Entry]]:
        return sorted(((key, sid, e)
                       for key, (sid, e) in self._index.items()
                       if key[0] == wk),
                      key=lambda t: (t[1], t[2].offset))

    def window_scatter(self, window_key):
        """(records, segments, span_bytes, record_bytes) for a window's
        live records — span is summed per segment, so a freshly
        coalesced window reports span == record_bytes."""
        wk = normalize_window_key(window_key)
        with self._lock:
            locs = self._window_locs(wk)
            if not locs:
                return (0, 0, 0, 0)
            per_seg: Dict[int, List[_Entry]] = {}
            for _, sid, e in locs:
                per_seg.setdefault(sid, []).append(e)
            span = sum(max(e.offset + e.rec_len for e in es)
                       - min(e.offset for e in es)
                       for es in per_seg.values())
            rec_bytes = sum(e.rec_len for _, _, e in locs)
            return (len(locs), len(per_seg), span, rec_bytes)

    def coalesce_windows(self, window_keys) -> int:
        """Rewrite each window's scattered live records into one
        contiguous run at the log tail, so a predicted re-stage becomes
        a single dense sequential sweep. Windows already dense in one
        segment are skipped (idempotent); the superseded copies become
        dead bytes that cleanup-driven compaction reclaims. Commits
        before returning."""
        rewrote = 0
        with self._lock:
            for window_key in window_keys:
                wk = normalize_window_key(window_key)
                locs = self._window_locs(wk)
                if len(locs) < 2:
                    continue
                _, n_segs, span, rec_bytes = self.window_scatter(wk)
                if rec_bytes >= self.segment_bytes:
                    continue    # bigger than a segment: can't be one run
                # already dense: contiguous per segment, in at most two
                # segments (a tail rewrite may straddle one roll) —
                # rewriting again would churn bytes for no read benefit
                if n_segs <= 2 and span <= 1.5 * rec_bytes:
                    continue
                by_seg: Dict[int, List[Tuple[BlockKey, _Entry]]] = {}
                for key, sid, e in locs:
                    by_seg.setdefault(sid, []).append((key, e))
                for sid in sorted(by_seg):
                    seg = self._segs.get(sid)
                    if seg is None:
                        continue
                    if sid == self._active_sid:
                        self._active_f.flush()
                    with open(seg.path, "rb") as f:
                        for key, e in by_seg[sid]:
                            loc = self._index.get(key)
                            if loc is None or loc[0] != sid \
                                    or loc[1].offset != e.offset:
                                continue       # raced with a re-put
                            f.seek(e.offset)
                            rec = f.read(e.rec_len)
                            if len(rec) < e.rec_len:
                                continue
                            payload = self._record_payload(rec)
                            if payload is None:
                                continue
                            self._cache_drop(key)
                            # raw payload re-append: the new record
                            # supersedes the scattered copy in-index
                            self._append_record(REC_VALUE, key, e.fill,
                                                e.cap, e.width, payload)
                rewrote += 1
                self.stats["coalesced_windows"] += 1
                self.stats["coalesce_bytes"] += rec_bytes
            if rewrote:
                self._commit_locked()
        return rewrote

    # ---------------------------------------------------------- inventory
    def current_fill(self, window_key, block_id):
        key = (normalize_window_key(window_key), int(block_id))
        with self._lock:
            loc = self._index.get(key)
            return None if loc is None else loc[1].fill

    def locate(self, window_key, block_id):
        key = (normalize_window_key(window_key), int(block_id))
        with self._lock:
            loc = self._index.get(key)
            return None if loc is None else (loc[0], loc[1].offset)

    def keys(self) -> List[BlockKey]:
        with self._lock:
            return list(self._index)

    def live_bytes(self) -> int:
        with self._lock:
            return self._live_payload

    def live_record_bytes(self) -> int:
        """Live bytes including record framing (the on-disk comparable)."""
        with self._lock:
            return sum(s.live_bytes for s in self._segs.values())

    def on_disk_bytes(self) -> int:
        with self._lock:
            return sum(s.size for s in self._segs.values())

    # ------------------------------------------------- space reclamation
    def compact_if_needed(self, max_ratio: float = 2.0) -> int:
        """Consume tombstones: rewrite victims' live records into the
        active segment and drop the victim files until on-disk bytes <=
        max(``max_ratio`` x live record bytes, one segment)."""
        reclaimed = 0
        with self._lock:
            self._commit_locked()
            while True:
                live = self.live_record_bytes()
                target = max(max_ratio * live, float(self.segment_bytes))
                if self.on_disk_bytes() <= target:
                    break
                victim = None
                best = 0
                for seg in self._segs.values():
                    if seg.sealed and seg.dead_bytes > best:
                        victim, best = seg, seg.dead_bytes
                if victim is None:
                    a = self._active
                    if a.dead_bytes > 0 and a.size > 0:
                        # dead weight only in the active segment: seal
                        # it (committed above) so it becomes a victim
                        self._commit_locked()
                        self._seal_active()
                        continue
                    break
                reclaimed += self._compact_segment(victim)
            if reclaimed:
                self._commit_locked()
                self.stats["compactions"] += 1
        return reclaimed

    def _compact_segment(self, victim: _Seg) -> int:
        """Rewrite ``victim``'s live records (and still-needed
        tombstones) into the active segment, then drop the file."""
        victim_copies: Dict[BlockKey, int] = {}
        for e in victim.entries:
            if e.rtype == REC_VALUE:
                victim_copies[e.key] = victim_copies.get(e.key, 0) + 1
        moved_bytes = 0
        with open(victim.path, "rb") as f:
            for e in victim.entries:
                if e.rtype == REC_VALUE:
                    loc = self._index.get(e.key)
                    if loc is None or loc[0] != victim.sid \
                            or loc[1].offset != e.offset:
                        continue               # superseded or deleted
                    f.seek(e.offset + _REC_HDR.size)
                    payload = f.read(e.rec_len - _REC_HDR.size
                                     - _CRC.size)
                    # re-append through the normal write path (the new
                    # record supersedes the victim's copy in the index)
                    self._append_record(REC_VALUE, e.key, e.fill, e.cap,
                                        e.width, payload)
                    moved_bytes += e.rec_len
                else:
                    # keep the tombstone while stale value records for
                    # its key survive outside this victim — dropping it
                    # early would resurrect them on replay
                    remaining = self._key_copies.get(e.key, 0) \
                        - victim_copies.get(e.key, 0)
                    if e.key not in self._index and remaining > 0:
                        self._append_record(REC_TOMB, e.key, 0, 0, 0, b"")
        # the victim's value records are gone: drop their copy counts
        for key, n in victim_copies.items():
            left = self._key_copies.get(key, 0) - n
            if left > 0:
                self._key_copies[key] = left
            else:
                self._key_copies.pop(key, None)
        # durability order: new copies are fsynced before the old file
        # disappears
        self._commit_locked()
        size = victim.size
        del self._segs[victim.sid]
        os.unlink(victim.path)
        self.stats["bytes_compacted"] += size
        return size - moved_bytes

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        with self._lock:
            self._commit_locked()
            if self._active_f is not None:
                self._active_f.close()
                self._active_f = None
            if self._wal_f is not None:
                self._wal_f.close()
                self._wal_f = None
