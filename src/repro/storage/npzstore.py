"""Legacy file-per-block ``.npz`` backend, behind the BlockStore
interface.

This is the seed repo's original persistent tier — one uncompressed
``block_<id>.npz`` per spilled block, deleted eagerly on purge — kept as
the fallback implementation and the ablation baseline the log-structured
store is measured against (write batching, batched reads, compaction).
Refs returned by ``put`` are the real file paths so legacy code (and
tests) that look at ``Block.storage_path`` keep working.
"""
from __future__ import annotations

import os
import threading
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.storage.blockstore import (
    BlockKey, BlockStore, FIELDS, WindowKey, normalize_window_key,
    payload_nbytes,
)


class NpzBlockStore(BlockStore):
    """File-per-block store: every record is its own ``.npz``."""

    name = "npz"
    durable_writes = False      # legacy late writes only flip `persisted`

    def __init__(self, directory: Path, sim_spb: float = 0.0,
                 registry=None):
        super().__init__(sim_spb=sim_spb, registry=registry)
        self.directory = Path(directory)
        # engine main thread (purge tombstones) and the I/O executor
        # (spill/stage) both call in
        self._lock = threading.RLock()
        # (window_key, block_id) -> (path, fill, payload_bytes, disk_bytes)
        self._index: Dict[BlockKey, Tuple[Path, int, int, int]] = {}
        if self.directory.exists():
            self._scan_existing()

    def _scan_existing(self) -> None:
        """Adopt pre-existing block files (reopen after restart). The
        window key and fill are not recoverable from the legacy layout;
        records index under the pseudo-window at full capacity — a
        conservative fill that only ever forces a harmless rewrite on
        the next spill (see ``_key_of`` for the lookup fallback)."""
        for p in sorted(self.directory.glob("block_*.npz")):
            try:
                bid = int(p.stem.split("_", 1)[1])
                with np.load(p) as z:
                    fill = int(z["keys"].shape[0])
                    width = int(z["values"].shape[1])
            except Exception:
                continue
            self._index[(normalize_window_key(None), bid)] = (
                p, fill, payload_nbytes(fill, width), p.stat().st_size)

    def _key_of(self, window_key: Optional[WindowKey],
                block_id: int) -> Optional[BlockKey]:
        """Resolve a key, tolerating the pseudo-window of adopted files
        (the npz layout is keyed by block_id alone on disk)."""
        wk = normalize_window_key(window_key)
        if (wk, block_id) in self._index:
            return (wk, block_id)
        alt = (normalize_window_key(None), block_id)
        if alt in self._index:
            return alt
        return None

    # ------------------------------------------------------------- writes
    def put(self, window_key, block_id, arrays, fill):
        with self._lock:
            self.directory.mkdir(parents=True, exist_ok=True)
            path = self.directory / f"block_{block_id}.npz"
            # full-capacity arrays, verbatim — byte-identical to the
            # seed's ``spill_to_storage`` so reload parity is trivial
            np.savez(path, **{k: arrays[k] for k in FIELDS})
            wk = normalize_window_key(window_key)
            disk = path.stat().st_size
            width = int(arrays["values"].shape[1])
            self._index[(wk, block_id)] = (
                path, int(fill), payload_nbytes(int(fill), width), disk)
            self.stats["puts"] += 1
            self.stats["bytes_written"] += disk
            self.stats["logical_bytes_written"] += payload_nbytes(
                int(fill), width)
            return path

    def commit(self) -> None:
        # each savez is already its own file; nothing buffered
        self.stats["commits"] += 1

    def delete(self, window_key, block_id) -> None:
        with self._lock:
            key = self._key_of(window_key, block_id)
            if key is None:
                return
            path, _, _, _ = self._index.pop(key)
            if path.exists():
                os.unlink(path)
            self.stats["deletes"] += 1

    # -------------------------------------------------------------- reads
    def get(self, window_key, block_id):
        with self._lock:
            key = self._key_of(window_key, block_id)
            if key is None:
                return None
            path, _, _, disk = self._index[key]
            if not path.exists():
                return None
            with np.load(path) as z:
                out = {k: z[k] for k in FIELDS}
            self.stats["gets"] += 1
            self.stats["bytes_read"] += disk
            return out

    def get_many(self, keys: List[BlockKey]):
        self.stats["batched_reads"] += 1
        return [self.get(wk, bid) for wk, bid in keys]

    # ---------------------------------------------------------- inventory
    def current_fill(self, window_key, block_id):
        with self._lock:
            key = self._key_of(window_key, block_id)
            if key is None:
                return None
            return self._index[key][1]

    def locate(self, window_key, block_id):
        with self._lock:
            key = self._key_of(window_key, block_id)
            return None if key is None else self._index[key][0]

    def keys(self) -> List[BlockKey]:
        with self._lock:
            return list(self._index)

    def live_bytes(self) -> int:
        with self._lock:
            return sum(pb for _, _, pb, _ in self._index.values())

    def on_disk_bytes(self) -> int:
        with self._lock:
            return sum(d for _, _, _, d in self._index.values())
