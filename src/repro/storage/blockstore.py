"""The persistent tier of the p-bucket, as an interface.

Aion's p-bucket lives in a real persistent store (RocksDB under Flink);
this module defines the contract every backend implements so the engine,
the staging executor, proactive caching and predictive cleanup all talk
to *storage*, never to files:

* ``put`` / ``commit`` — writes are **group-committed**: ``put`` makes a
  record visible to this process, ``commit`` is the durability barrier
  (a crash after ``commit`` returns loses nothing acknowledged; a crash
  before it may lose the uncommitted tail, whose blocks still hold their
  host copies).
* ``get`` / ``get_many`` / ``readahead`` — reads are block-granular;
  ``get_many`` is the batched multi-block path (one sequential sweep per
  segment on the log backend) and ``readahead`` fills a bounded read
  cache ahead of demand so proactive pre-staging turns cold storage
  reads into cache hits — a first-class, measurable interface
  (``stats['readahead_hits']`` / ``'readahead_misses'``).
* ``delete`` — predictive cleanup's purge emits a *tombstone*; space
  comes back through ``compact_if_needed`` (cleanup-driven compaction),
  not through an eager unlink.
* ``charge`` — the deterministic simulated-cost model for benchmarks
  (one persistent-tier channel: threads queue on the sleep) lives behind
  the store, so ablations price every backend identically and
  **zero-byte transfers are never charged**.

``BlockKey`` is ``(window_key, block_id)`` with ``window_key =
(window_start, window_end)`` — the index the paper's p-bucket keeps.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

WindowKey = Tuple[float, float]
BlockKey = Tuple[WindowKey, int]

# SoA field order every backend serializes in
FIELDS = ("keys", "timestamps", "values")
_DTYPES = {"keys": np.int32, "timestamps": np.float64, "values": np.float32}


def normalize_window_key(window_key: Optional[WindowKey]) -> WindowKey:
    """Blocks created outside a window (unit tests, scratch) map to the
    (0, 0) pseudo-window; ``block_id`` keeps the key unique."""
    if window_key is None:
        return (0.0, 0.0)
    return (float(window_key[0]), float(window_key[1]))


def payload_nbytes(fill: int, width: int) -> int:
    """Logical bytes of one record's event payload (the fill-sliced SoA
    arrays: int32 keys + float64 timestamps + float32 values)."""
    return fill * (4 + 8 + 4 * width)


class TransientStoreError(OSError):
    """A store operation failed in a way a retry is expected to fix
    (flaky device, interrupted syscall, overloaded tier). The staging
    layer retries these up to ``AionConfig.io_retry_limit`` with
    exponential backoff before surfacing them."""


class PermanentStoreError(RuntimeError):
    """A store operation failed in a way retries cannot fix (corrupt
    record, failed media, contract violation). Surfaced immediately —
    recovery means restoring from a checkpoint, not retrying."""


def is_transient_error(exc: BaseException) -> bool:
    """Transient vs. permanent classification for the retry budget.

    OS-level I/O errors (``OSError`` and subclasses — the log backend's
    real failure mode), timeouts and connection drops are transient;
    ``PermanentStoreError`` and everything else (``KeyError``,
    ``AssertionError``, ...) are logic/corruption failures that retries
    would only repeat."""
    if isinstance(exc, PermanentStoreError):
        return False
    return isinstance(exc, (OSError, TimeoutError, ConnectionError))


class SimulatedCost:
    """Deterministic persistent-tier cost model (paper Q3 ablations).

    The calling thread really sleeps ``nbytes * seconds_per_byte`` while
    holding the single-channel lock, so scheduling — priorities,
    preemption, pre-staging lead time — decides who stalls, not host
    noise. Zero-byte transfers are free by contract (empty blocks must
    not be billed for I/O that never happens).
    """

    def __init__(self, seconds_per_byte: float = 0.0):
        self.seconds_per_byte = seconds_per_byte
        self._lock = threading.Lock()
        self.total_seconds = 0.0

    def charge(self, nbytes: int) -> float:
        if self.seconds_per_byte <= 0 or nbytes <= 0:
            return 0.0
        dt = nbytes * self.seconds_per_byte
        self.total_seconds += dt
        with self._lock:               # single channel: threads queue
            time.sleep(dt)
        return dt


class BlockStore:
    """Abstract persistent block store. Thread-safe by contract: the
    engine main thread and the I/O executor both call in."""

    name = "abstract"
    #: True when ``put``+``commit`` give real crash durability — the
    #: staging layer persists late-event writes through such stores
    #: (the legacy npz backend only flips the ``persisted`` flag).
    durable_writes = False

    def __init__(self, sim_spb: float = 0.0, registry=None):
        from repro.obs import MetricsRegistry, StatsMap
        self.simcost = SimulatedCost(sim_spb)
        # registry-backed counters behind the legacy dict API; backends
        # extend the set via ``self.stats.update({...})`` (auto-registers)
        if registry is None:
            registry = MetricsRegistry()
        self.registry = registry
        self.stats = StatsMap(registry, f"aion_store_{self.name}")
        self.stats.register_many([
            "puts", "gets", "deletes", "commits",
            "bytes_written", "bytes_read", "bytes_compacted",
            "logical_bytes_written", "batched_reads",
            "readahead_hits", "readahead_misses",
            "readahead_bytes", "compactions",
        ])

    # ------------------------------------------------------------- writes
    def put(self, window_key: Optional[WindowKey], block_id: int,
            arrays: Dict[str, np.ndarray], fill: int):
        """Write one block's SoA arrays (full-capacity; only ``[:fill]``
        is meaningful). Returns an opaque ref. Durable after the next
        ``commit``."""
        raise NotImplementedError

    def commit(self) -> None:
        """Group-commit barrier: every prior ``put``/``delete`` of this
        process is durable when this returns."""
        raise NotImplementedError

    def delete(self, window_key: Optional[WindowKey],
               block_id: int) -> None:
        """Tombstone one block (predictive cleanup's purge). Space is
        reclaimed by compaction, not by this call."""
        raise NotImplementedError

    # -------------------------------------------------------------- reads
    def get(self, window_key: Optional[WindowKey], block_id: int
            ) -> Optional[Dict[str, np.ndarray]]:
        """Full-capacity SoA arrays of one block, or None if absent.
        The caller owns the returned arrays (they may be mutated by
        tail-block appends after a reload)."""
        raise NotImplementedError

    def get_many(self, keys: List[BlockKey]
                 ) -> List[Optional[Dict[str, np.ndarray]]]:
        """Batched multi-block read, results in input order. Backends
        override to turn random block access into sequential sweeps."""
        return [self.get(wk, bid) for wk, bid in keys]

    def readahead(self, keys: Iterable[BlockKey]) -> None:
        """Prefetch hint: bring these blocks toward memory (into the
        read cache) ahead of demand. Best-effort; default no-op."""

    # ------------------------------------------- segment-granular prefetch
    # (learned prefetch planner; only log-structured backends have a
    # physical segment layout — the defaults make everything else report
    # "no segments" so planners fall back to point readahead)
    def segments_for(self, keys: Iterable[BlockKey]
                     ) -> Dict[int, List[Tuple[BlockKey, int, int]]]:
        """Physical placement of live records: ``segment_id -> [(key,
        offset, record_len)]``. Index-only — no payload reads."""
        return {}

    def readahead_segments(self, sid: int,
                           keys: Iterable[BlockKey]) -> int:
        """One sequential sweep over segment ``sid`` caching ``keys``'s
        records. Returns blocks cached (0: backend has no segments)."""
        return 0

    def window_scatter(self, window_key: Optional[WindowKey]
                       ) -> Tuple[int, int, int, int]:
        """Physical scatter of a window's live records: ``(records,
        segments, span_bytes, record_bytes)`` — the coalescing
        planner's rewrite-worthiness signal."""
        return (0, 0, 0, 0)

    def coalesce_windows(self, window_keys: Iterable[WindowKey]) -> int:
        """Rewrite each window's scattered live records into one
        contiguous run at the log tail. Returns windows rewritten."""
        return 0

    # ---------------------------------------------------------- inventory
    def contains(self, window_key: Optional[WindowKey],
                 block_id: int) -> bool:
        return self.current_fill(window_key, block_id) is not None

    def current_fill(self, window_key: Optional[WindowKey],
                     block_id: int) -> Optional[int]:
        """Fill of the stored record for this key, or None if absent —
        lets spill skip rewriting a block whose exact content is already
        persistent, and checkpoint manifests verify store coverage."""
        raise NotImplementedError

    def locate(self, window_key: Optional[WindowKey], block_id: int):
        """Opaque ref for an existing record (restore re-links blocks to
        their pre-crash records), or None."""
        fill = self.current_fill(window_key, block_id)
        return None if fill is None else True

    def keys(self) -> List[BlockKey]:
        raise NotImplementedError

    def live_bytes(self) -> int:
        """Logical payload bytes of live (non-tombstoned) records."""
        raise NotImplementedError

    def on_disk_bytes(self) -> int:
        raise NotImplementedError

    # ------------------------------------------------- space reclamation
    def compact_if_needed(self, max_ratio: float = 2.0) -> int:
        """Reclaim dead space until on-disk bytes <= max(``max_ratio`` x
        live bytes, one segment). Returns bytes compacted away."""
        return 0

    def reconcile(self, live_keys: Iterable[BlockKey]) -> int:
        """Tombstone every record not in ``live_keys`` (orphans left by a
        crash between a checkpoint and the purge tombstones that should
        have followed it). Returns the number of orphans dropped."""
        live = set(live_keys)
        dropped = 0
        for wk, bid in self.keys():
            if (wk, bid) not in live:
                self.delete(wk, bid)
                dropped += 1
        if dropped:
            self.commit()
        return dropped

    # ------------------------------------------------------------- costs
    def charge(self, nbytes: int) -> float:
        """Simulated persistent-tier cost for an ``nbytes`` transfer.
        Empty transfers are free (see ``SimulatedCost``)."""
        return self.simcost.charge(nbytes)

    @property
    def write_amplification(self) -> float:
        """Physical bytes written (incl. compaction rewrites) per logical
        payload byte the engine asked to persist."""
        logical = self.stats["logical_bytes_written"]
        if logical <= 0:
            return 0.0
        return self.stats["bytes_written"] / logical

    # ---------------------------------------------------------- lifecycle
    def flush(self) -> None:
        self.commit()

    def close(self) -> None:
        self.flush()
