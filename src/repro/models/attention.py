"""Attention: blocked (flash-style) for train/prefill, direct for decode.

The blocked path keeps peak memory at one ``[B, block_q, H, block_k]`` score
tile via a two-level ``lax.scan`` with online softmax — this is both the XLA
production path for the dry-run and the numerical oracle the Pallas
``flash_attention`` kernel is tested against.

GQA divisibility: when the TP axis exceeds ``num_kv_heads``, K/V activations
are repeated at compute time (``kv_repeat``) so the stored-head axis shards
evenly; parameters keep the true KV head count.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models.layers import apply_rope, dense_apply, dense_init

NEG_INF = jnp.float32(-1e30)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False):
    d = cfg.d_model
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    q_p, q_s = dense_init(ks[0], d, (h, dh), (shd.FSDP, shd.HEADS, None),
                          dtype, use_bias=cfg.use_bias)
    k_p, k_s = dense_init(ks[1], d, (hkv, dh),
                          (shd.FSDP, shd.KV_PARAM_HEADS, None),
                          dtype, use_bias=cfg.use_bias)
    v_p, v_s = dense_init(ks[2], d, (hkv, dh),
                          (shd.FSDP, shd.KV_PARAM_HEADS, None),
                          dtype, use_bias=cfg.use_bias)
    o_p, o_s = dense_init(ks[3], h * dh, (d,), (shd.HEADS, shd.FSDP), dtype,
                          scale=1.0 / math.sqrt(h * dh), use_bias=cfg.use_bias)
    # o weight reshaped to [h, dh, d] so the head axis shards
    o_p = {"w": o_p["w"].reshape(h, dh, d), **{k: v for k, v in o_p.items() if k == "b"}}
    o_s = {"w": (shd.HEADS, None, shd.FSDP), **{k: (None,) for k in o_p if k == "b"}}
    return ({"q": q_p, "k": k_p, "v": v_p, "o": o_p},
            {"q": q_s, "k": k_s, "v": v_s, "o": o_s})


def _repeat_kv(kv: jnp.ndarray, repeat: int) -> jnp.ndarray:
    """[B, S, Hkv, D] -> [B, S, Hkv*repeat, D] (tile so groups stay contiguous)."""
    if repeat == 1:
        return kv
    b, s, h, d = kv.shape
    return jnp.repeat(kv, repeat, axis=2)


# ---------------------------------------------------------------------------
# Blocked attention with online softmax
# ---------------------------------------------------------------------------

def _pick_block(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: int = 0, q_offset: int = 0,
                      block_q: int = 512, block_k: int = 512,
                      kv_valid_len: Optional[jnp.ndarray] = None,
                      causal_skip: bool = False) -> jnp.ndarray:
    """Online-softmax attention.

    q: [B, Sq, Hq, D]; k, v: [B, Sk, Hs, D] with Hq % Hs == 0.
    ``window > 0``: causal sliding window (token i sees [i-window+1, i]) and
    the kv scan is *structurally* limited to the window span (sub-quadratic).
    ``kv_valid_len``: optional [B] count of valid kv positions (padding mask).
    ``causal_skip``: §Perf optimization — unroll the q-block loop so each q
    block scans only its (statically known) non-masked kv prefix, halving
    executed attention FLOPs for causal full attention.
    Returns [B, Sq, Hq, D] in q.dtype.
    """
    b, sq, hq, dh = q.shape
    _, sk, hs, _ = k.shape
    g = hq // hs
    scale = 1.0 / math.sqrt(dh)
    bq = _pick_block(sq, block_q)
    bk = _pick_block(sk, block_k)
    nq = sq // bq

    qg = q.reshape(b, sq, hs, g, dh)

    def q_block_body(qi, _, n_kv_static: int = 0):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=1)
        q_blk = (q_blk.astype(jnp.float32) * scale).astype(q.dtype)
        q_pos = q_offset + qi * bq + jnp.arange(bq)          # [bq]

        if n_kv_static:
            # causal-skip path: qi is a python int; scan only the blocks
            # this q block can attend to
            starts = jnp.arange(n_kv_static) * bk
        elif window > 0:
            # kv span: [q_start - window + 1, q_start + bq) clamped
            n_off = (window + bq - 1) // bk + 1
            base = qi * bq + bq - 1 - (n_off - 1) * bk

            def kv_starts(o):
                return jnp.clip(base + o * bk, 0, sk - bk)
            offsets = jnp.arange(n_off)
            starts = jax.vmap(kv_starts)(offsets)
        else:
            n_off = sk // bk
            starts = jnp.arange(n_off) * bk

        def kv_step(carry, start):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, start, bk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, start, bk, axis=1)
            k_pos = start + jnp.arange(bk)                   # [bk]
            # scores: [b, hs, g, bq, bk]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32)
            mask = jnp.ones((bq, bk), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window > 0:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            if kv_valid_len is not None:
                s = jnp.where(
                    (k_pos[None, :] < kv_valid_len[:, None])[:, None, None, None, :],
                    s, NEG_INF)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            blk_max = jnp.max(s, axis=-1)                    # [b,hs,g,bq]
            new_m = jnp.maximum(m, blk_max)
            corr = jnp.exp(m - new_m)
            p = jnp.exp(s - new_m[..., None])                # fp32
            new_l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            new_acc = acc * corr[..., None] + pv
            return (new_m, new_l, new_acc), None

        m0 = jnp.full((b, hs, g, bq), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, hs, g, bq), dtype=jnp.float32)
        a0 = jnp.zeros((b, hs, g, bq, dh), dtype=jnp.float32)
        # checkpoint the kv step: backward recomputes the score tile instead
        # of saving [b,hs,g,bq,bk] per step (flash-attention memory shape)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      starts)
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # [b,hs,g,bq,dh]
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, bq, hq, dh)
        return qi + 1, out.astype(q.dtype)

    if causal_skip and causal and window == 0 and q_offset == 0 \
            and bq == bk:
        # unrolled q loop with per-block static kv extents: executed score
        # FLOPs drop from nq*nk to nq*(nq+1)/2 tiles (the causal half)
        outs = []
        ck = jax.checkpoint(q_block_body, static_argnums=(2,))
        for qi in range(nq):
            _, out = ck(qi, None, qi + 1)
            outs.append(out)
        return jnp.concatenate(outs, axis=1)

    # checkpoint per q block: only per-block outputs are saved across the
    # outer scan; the inner kv scan re-runs during that block's backward
    _, blocks = jax.lax.scan(jax.checkpoint(q_block_body), 0, None, length=nq)
    # blocks: [nq, b, bq, hq, dh] -> [b, sq, hq, dh]
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, dh)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     valid_mask: jnp.ndarray) -> jnp.ndarray:
    """Single-step attention against a cache.

    q: [B, 1, Hq, D]; caches: [B, S, Hs, D]; valid_mask: [B, S] bool.
    """
    b, _, hq, dh = q.shape
    _, s, hs, _ = k_cache.shape
    g = hq // hs
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, hs, g, dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid_mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (qkv -> rope -> attend -> o)
# ---------------------------------------------------------------------------

def attn_forward(params, x, cfg: ModelConfig, *, positions,
                 kv_repeat: int = 1, causal: bool = True,
                 window: int = 0, return_kv: bool = False,
                 xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                 kv_valid_len=None, causal_skip: bool = False):
    """Train/prefill attention. x: [B, S, D]. positions: [B, S].

    ``xattn_kv``: precomputed (k, v) for cross-attention (skips self kv).
    Returns (out, (k, v)) — (k, v) are the *stored* (possibly repeated,
    post-RoPE) heads for cache reuse, or None unless requested.
    """
    cd = x.dtype
    q = dense_apply(params["q"], x, cd)                      # [B,S,H,dh]
    q = shd.constrain(q, shd.BATCH, None, shd.HEADS, None)
    if xattn_kv is None:
        k = dense_apply(params["k"], x, cd)
        v = dense_apply(params["v"], x, cd)
        if cfg.rope_theta > 0:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        k = _repeat_kv(k, kv_repeat)
        v = _repeat_kv(v, kv_repeat)
        k = shd.constrain(k, shd.BATCH, None, shd.KV_HEADS, None)
        v = shd.constrain(v, shd.BATCH, None, shd.KV_HEADS, None)
    else:
        k, v = xattn_kv
        if cfg.rope_theta > 0:
            q = apply_rope(q, positions, cfg.rope_theta)
    out = blocked_attention(q, k, v, causal=causal, window=window,
                            kv_valid_len=kv_valid_len,
                            causal_skip=causal_skip)
    out = shd.constrain(out, shd.BATCH, None, shd.HEADS, None)
    y = dense_apply(params["o"], out, cd, contract_dims=2)
    y = shd.constrain(y, shd.BATCH, None, None)
    kv = (k, v) if (return_kv or xattn_kv is not None) else None
    return y, kv


def _quantize_kv(x):
    """[..., dh] -> (int8 values, per-vector scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(x.dtype)


def _shard_map_dus_write(cache, new, slot, mesh, batch_axes):
    """Per-shard dynamic-update-slice on a sequence-sharded cache: each
    model shard writes the token only if the slot lies in its local range —
    no full-cache copy pass (SPerf C3)."""
    from jax.sharding import PartitionSpec as P
    bspec = batch_axes if batch_axes else None

    def write(c_loc, n_loc, s):
        s_loc = c_loc.shape[1]
        idx = jax.lax.axis_index("model")
        local = jnp.asarray(s, jnp.int32) - idx * s_loc
        in_range = (local >= 0) & (local < s_loc)

        def do(c):
            return jax.lax.dynamic_update_slice_in_dim(
                c, n_loc.astype(c.dtype), jnp.clip(local, 0, s_loc - 1),
                axis=1)

        return jax.lax.cond(in_range, do, lambda c: c, c_loc)

    nd_tail = cache.ndim - 2
    cspec = P(bspec, "model", *([None] * nd_tail))
    nspec = P(bspec, None, *([None] * nd_tail))
    return shd.shard_map_compat(write, mesh=mesh,
                                in_specs=(cspec, nspec, P()),
                                out_specs=cspec,
                                check=False)(cache, new, slot)


def attn_decode(params, x, cfg: ModelConfig, *, cache_k, cache_v, cache_pos,
                kv_repeat: int = 1, window: int = 0,
                xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                xattn_len=None, kv_scales=None, dus_write: bool = False):
    """Decode one token. x: [B, 1, D]; caches [B, S_cache, Hs, dh];
    cache_pos: scalar int32 — absolute position of the new token.

    Window archs use a ring buffer of size S_cache == window.
    ``kv_scales``: (k_scale, v_scale) for an int8-quantized cache (§Perf) —
    values are dequantized for the score/readout matmuls and new tokens are
    quantized on write. Returns (out, cache_k, cache_v, scales_or_None).
    """
    cd = x.dtype
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_pos, dtype=jnp.int32)
    q = dense_apply(params["q"], x, cd)
    if cfg.rope_theta > 0:
        q = apply_rope(q, pos, cfg.rope_theta)
    q = shd.constrain(q, shd.BATCH, None, shd.HEADS, None)

    if xattn_kv is not None:
        k_all, v_all = xattn_kv
        s = k_all.shape[1]
        valid = jnp.arange(s)[None, :] < (
            xattn_len[:, None] if xattn_len is not None
            else jnp.full((b, 1), s, jnp.int32))
        out = decode_attention(q, k_all, v_all, valid)
        out = shd.constrain(out, shd.BATCH, None, shd.HEADS, None)
        y = dense_apply(params["o"], out, cd, contract_dims=2)
        return shd.constrain(y, shd.BATCH, None, None), cache_k, cache_v, None

    k_new = dense_apply(params["k"], x, cd)
    v_new = dense_apply(params["v"], x, cd)
    if cfg.rope_theta > 0:
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    k_new = _repeat_kv(k_new, kv_repeat)
    v_new = _repeat_kv(v_new, kv_repeat)
    k_scale_new = v_scale_new = None
    if kv_scales is not None:
        k_new, k_scale_new = _quantize_kv(k_new)
        v_new, v_scale_new = _quantize_kv(v_new)

    s_cache = cache_k.shape[1]
    slot = jnp.where(window > 0, cache_pos % s_cache, cache_pos)
    slot = jnp.asarray(slot, jnp.int32)
    ctx = shd.current_ctx()
    seq_sharded = (ctx is not None and ctx.profile is not None
                   and ctx.profile.kv_seq_shard)
    if seq_sharded and dus_write:
        batch_axes = ctx.profile.batch_axes
        cache_k = _shard_map_dus_write(cache_k, k_new, slot, ctx.mesh,
                                       batch_axes)
        cache_v = _shard_map_dus_write(cache_v, v_new, slot, ctx.mesh,
                                       batch_axes)
    elif seq_sharded:
        # masked write: elementwise select shards cleanly over the sequence
        # axis (a plain dynamic-update-slice on a sharded dim would force
        # SPMD to replicate the cache)
        sel = (jnp.arange(s_cache, dtype=jnp.int32) == slot)[None, :, None, None]
        cache_k = jnp.where(sel, k_new.astype(cache_k.dtype), cache_k)
        cache_v = jnp.where(sel, v_new.astype(cache_v.dtype), cache_v)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    cache_k = shd.constrain(cache_k, shd.BATCH, shd.KV_SEQ, shd.KV_HEADS, None)
    cache_v = shd.constrain(cache_v, shd.BATCH, shd.KV_SEQ, shd.KV_HEADS, None)

    new_scales = None
    if kv_scales is not None:
        k_scale, v_scale = kv_scales
        if seq_sharded:
            sel_s = (jnp.arange(s_cache, dtype=jnp.int32) == slot)[None, :, None]
            k_scale = jnp.where(sel_s, k_scale_new.astype(k_scale.dtype),
                                k_scale)
            v_scale = jnp.where(sel_s, v_scale_new.astype(v_scale.dtype),
                                v_scale)
        else:
            k_scale = jax.lax.dynamic_update_slice_in_dim(
                k_scale, k_scale_new.astype(k_scale.dtype), slot, axis=1)
            v_scale = jax.lax.dynamic_update_slice_in_dim(
                v_scale, v_scale_new.astype(v_scale.dtype), slot, axis=1)
        k_scale = shd.constrain(k_scale, shd.BATCH, shd.KV_SEQ, shd.KV_HEADS)
        v_scale = shd.constrain(v_scale, shd.BATCH, shd.KV_SEQ, shd.KV_HEADS)
        new_scales = (k_scale, v_scale)
        # dequantize for the score/readout matmuls (on TPU this fuses into
        # the attention kernel; the cache traffic stays int8)
        k_att = cache_k.astype(cd) * k_scale[..., None].astype(cd)
        v_att = cache_v.astype(cd) * v_scale[..., None].astype(cd)
    else:
        k_att, v_att = cache_k, cache_v

    n_written = jnp.minimum(cache_pos + 1, s_cache)
    valid = jnp.arange(s_cache)[None, :] < n_written        # [1, S] -> broadcast
    valid = jnp.broadcast_to(valid, (b, s_cache))
    out = decode_attention(q, k_att, v_att, valid)
    out = shd.constrain(out, shd.BATCH, None, shd.HEADS, None)
    y = dense_apply(params["o"], out, cd, contract_dims=2)
    return shd.constrain(y, shd.BATCH, None, None), cache_k, cache_v, new_scales
