"""build_model + input_specs: the public model-construction API.

``input_specs(cfg, shape)`` returns ``(batch_shapes, batch_logical_specs)``
— ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
shardable, no device allocation), as required by the multi-pod dry-run.
Modality frontends (vlm/audio) are STUBS: precomputed patch/frame
embeddings appear directly in the batch.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    FAMILY_AUDIO, FAMILY_ENCDEC, FAMILY_VLM, ModelConfig, ShapeConfig,
)
from repro.distributed import sharding as shd
from repro.models.transformer import Model


def build_model(cfg: ModelConfig, kv_repeat: int = 1,
                remat_group: int = 0, causal_skip: bool = False,
                kv_cache_bits: int = 16,
                kv_dus_write: bool = False) -> Model:
    return Model(cfg=cfg, kv_repeat=kv_repeat, remat_group=remat_group,
                 causal_skip=causal_skip, kv_cache_bits=kv_cache_bits,
                 kv_dus_write=kv_dus_write)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                model: Optional[Model] = None
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """ShapeDtypeStructs + logical axis specs for one (arch, shape) cell.

    train  : full batch with targets
    prefill: prompt batch (no targets)
    decode : single token + zeroed cache of seq_len capacity
    """
    model = model or build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    def add_frontend():
        f = cfg.frontend_tokens
        if cfg.family == FAMILY_VLM:
            batch["patch_embeds"] = _sds((b, f, cfg.d_model), cfg.compute_dtype)
            specs["patch_embeds"] = (shd.BATCH, None, None)
        elif cfg.family in (FAMILY_AUDIO, FAMILY_ENCDEC):
            batch["frame_embeds"] = _sds((b, f, cfg.d_model), cfg.compute_dtype)
            specs["frame_embeds"] = (shd.BATCH, None, None)

    if shape.kind in ("train", "prefill"):
        text_len = s
        if cfg.family == FAMILY_VLM:
            text_len = s - cfg.frontend_tokens
        add_frontend()
        batch["tokens"] = _sds((b, text_len), jnp.int32)
        specs["tokens"] = (shd.BATCH, None)
        if shape.kind == "train":
            batch["targets"] = _sds((b, text_len), jnp.int32)
            specs["targets"] = (shd.BATCH, None)
        return batch, specs

    # decode: one new token against a cache of capacity seq_len
    batch["tokens"] = _sds((b, 1), jnp.int32)
    specs["tokens"] = (shd.BATCH, None)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(b, s))
    batch["cache"] = cache_shapes
    specs["cache"] = model.cache_specs()
    return batch, specs
