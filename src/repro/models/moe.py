"""Mixture-of-experts FFN with expert parallelism.

Routing (softmax top-k + aux losses) runs in plain SPMD land. The expert
FFN runs inside ``shard_map``: experts are sharded over the ``model`` mesh
axis while tokens stay batch-sharded over ``data`` (replicated over
``model``), so each device gathers *locally* the top-capacity tokens for its
local experts, applies the FFN, scatter-adds into a partial output, and the
partials are ``psum``-ed over ``model``. This replaces the classic
all-to-all with one all-reduce of the combined output — no token tensors are
ever all-gathered.

Capacity semantics follow GShard/Switch: per expert, at most
``ceil(T·top_k·cf/E)`` tokens are kept (by routing weight); overflow tokens
contribute nothing (their residual passes through).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models.layers import dense_init


def moe_init(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    router_p, router_s = dense_init(ks[0], d, (e,), (shd.FSDP, None),
                                    jnp.float32)
    scale = 1.0 / math.sqrt(d)
    wg = jax.random.normal(ks[1], (e, d, f), dtype=dtype) * scale
    wu = jax.random.normal(ks[2], (e, d, f), dtype=dtype) * scale
    wd = jax.random.normal(ks[3], (e, f, d), dtype=dtype) / math.sqrt(f)
    params = {"router": router_p, "wg": wg, "wu": wu, "wd": wd}
    specs = {
        "router": router_s,
        "wg": (shd.EXPERTS, shd.FSDP, None),
        "wu": (shd.EXPERTS, shd.FSDP, None),
        "wd": (shd.EXPERTS, None, shd.FSDP),
    }
    return params, specs


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    e, k, cf = cfg.moe.num_experts, cfg.moe.top_k, cfg.moe.capacity_factor
    c = int(math.ceil(tokens * k * cf / e))
    c = ((c + 63) // 64) * 64                      # lane-align
    return min(max(c, 64), tokens)


def _expert_ffn(x_flat, idx, wts, wg, wu, wd, e_offset, capacity, variant):
    """Local expert compute. x_flat [T, D]; idx/wts [T, K];
    wg/wu/wd [E_loc, ...]. Returns partial output [T, D]."""
    e_loc = wg.shape[0]
    t, d = x_flat.shape
    eids = e_offset + jnp.arange(e_loc, dtype=idx.dtype)
    hit = idx[None, :, :] == eids[:, None, None]              # [E_loc, T, K]
    aff = jnp.sum(jnp.where(hit, wts[None], 0.0), axis=-1)    # [E_loc, T]
    gate, token_ids = jax.lax.top_k(aff, capacity)            # [E_loc, C]
    xg = jnp.take(x_flat, token_ids.reshape(-1), axis=0)
    xg = xg.reshape(e_loc, capacity, d)                       # [E_loc, C, D]
    if variant == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg)) \
            * jnp.einsum("ecd,edf->ecf", xg, wu)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xg, wu))
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    y = y * gate[..., None].astype(y.dtype)
    out = jnp.zeros((t, d), dtype=y.dtype)
    out = out.at[token_ids.reshape(-1)].add(y.reshape(-1, d))
    return out


def moe_forward(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar fp32)."""
    cd = x.dtype
    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"]["w"])                # fp32
    probs = jax.nn.softmax(logits, axis=-1)
    wts, idx = jax.lax.top_k(probs, k)                        # [B,S,K]
    wts = wts / jnp.maximum(jnp.sum(wts, axis=-1, keepdims=True), 1e-9)

    # Switch aux losses
    me = jnp.mean(probs, axis=(0, 1))                         # [E]
    ce_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1))                                          # [E]
    aux = cfg.moe.router_aux_weight * e * jnp.sum(me * ce_frac)
    zloss = cfg.moe.router_z_weight * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = aux + zloss

    x_flat = x.reshape(b * s, d)
    idx_flat = idx.reshape(b * s, k)
    wts_flat = wts.reshape(b * s, k).astype(cd)

    ctx = shd.current_ctx()
    expert_tp = (ctx is not None and ctx.mesh is not None
                 and ctx.profile is not None and ctx.profile.expert_tp)
    if not expert_tp:
        capacity = _capacity(b * s, cfg)
        y = _expert_ffn(x_flat, idx_flat, wts_flat,
                        params["wg"].astype(cd), params["wu"].astype(cd),
                        params["wd"].astype(cd), 0, capacity, cfg.mlp_variant)
        return y.reshape(b, s, d), aux

    mesh = ctx.mesh
    batch_axes = ctx.profile.batch_axes
    n_model = mesh.shape["model"]
    e_loc = e // n_model
    # local token count after batch sharding
    n_batch = 1
    for ax in batch_axes:
        n_batch *= mesh.shape[ax]
    t_loc = (b // max(n_batch, 1)) * s
    capacity = _capacity(t_loc, cfg)

    bspec = P(batch_axes if batch_axes else None, None)

    def shard_fn(xf, idxf, wtsf, wg, wu, wd):
        e_off = jax.lax.axis_index("model") * e_loc
        out = _expert_ffn(xf, idxf, wtsf, wg, wu, wd, e_off,
                          capacity, cfg.mlp_variant)
        return jax.lax.psum(out, axis_name="model")

    y = shd.shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(bspec, bspec, bspec,
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=bspec,
        check=False,
    )(x_flat, idx_flat, wts_flat,
      params["wg"].astype(cd), params["wu"].astype(cd),
      params["wd"].astype(cd))
    return y.reshape(b, s, d), aux
