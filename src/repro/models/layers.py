"""Model building blocks: norms, RoPE, MLPs, embeddings.

Conventions
-----------
* Every ``init_*`` returns ``(params, specs)`` — two pytrees with identical
  structure. ``specs`` leaves are tuples of *logical* axis names
  (``repro.distributed.sharding``); ``None`` entries are unsharded dims.
* Params are stored in ``param_dtype`` (fp32), compute casts to
  ``compute_dtype`` (bf16) at use sites.
* Activation tensors are ``[batch, seq, d_model]``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, in_dim: int, out_dims: Tuple[int, ...],
               logical: Tuple[Optional[str], ...], dtype,
               scale: Optional[float] = None, use_bias: bool = False):
    """Dense weight [in_dim, *out_dims] with fan-in normal init."""
    fan_out = 1
    for d in out_dims:
        fan_out *= d
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, *out_dims), dtype=dtype) * scale
    params: Dict[str, Any] = {"w": w}
    specs: Dict[str, Any] = {"w": logical}
    if use_bias:
        params["b"] = jnp.zeros(out_dims, dtype=dtype)
        specs["b"] = logical[1:]
    return params, specs


def dense_apply(params, x, compute_dtype, contract_dims: int = 1):
    """x [..., in] @ w [in, *out] (+ b). ``contract_dims`` leading w dims
    are contracted against trailing x dims."""
    w = params["w"].astype(compute_dtype)
    nd = w.ndim
    x_axes = tuple(range(x.ndim - contract_dims, x.ndim))
    w_axes = tuple(range(contract_dims))
    y = jax.lax.dot_general(
        x, w, dimension_numbers=((x_axes, w_axes), ((), ())),
        preferred_element_type=compute_dtype)
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype=dtype)}, {"scale": (None,)}


def rmsnorm_apply(params, x, eps: float, compute_dtype):
    # normalize in fp32 for stability, return compute dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(compute_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    freqs = rope_frequencies(x.shape[-1], theta)              # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or 2-matrix GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        gate_p, gate_s = dense_init(ks[0], d, (f,), (shd.FSDP, shd.MLP), dtype,
                                    use_bias=cfg.use_bias)
        up_p, up_s = dense_init(ks[1], d, (f,), (shd.FSDP, shd.MLP), dtype,
                                use_bias=cfg.use_bias)
        down_p, down_s = dense_init(ks[2], f, (d,), (shd.MLP, shd.FSDP), dtype,
                                    use_bias=cfg.use_bias)
        return ({"gate": gate_p, "up": up_p, "down": down_p},
                {"gate": gate_s, "up": up_s, "down": down_s})
    up_p, up_s = dense_init(ks[0], d, (f,), (shd.FSDP, shd.MLP), dtype,
                            use_bias=cfg.use_bias)
    down_p, down_s = dense_init(ks[1], f, (d,), (shd.MLP, shd.FSDP), dtype,
                                use_bias=cfg.use_bias)
    return {"up": up_p, "down": down_p}, {"up": up_s, "down": down_s}


def mlp_apply(params, x, cfg: ModelConfig, compute_dtype):
    if cfg.mlp_variant == "swiglu":
        g = dense_apply(params["gate"], x, compute_dtype)
        u = dense_apply(params["up"], x, compute_dtype)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(dense_apply(params["up"], x, compute_dtype))
    h = shd.constrain(h, shd.BATCH, None, shd.MLP)
    return dense_apply(params["down"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding (padded, vocab-sharded)
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig, dtype):
    vp = shd.pad_vocab(cfg.vocab_size)
    table = jax.random.normal(key, (vp, cfg.d_model), dtype=dtype)
    params = {"table": table}
    specs = {"table": (shd.VOCAB, shd.FSDP)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        unembed = jax.random.normal(k2, (cfg.d_model, vp), dtype=dtype)
        unembed = unembed / math.sqrt(cfg.d_model)
        params["unembed"] = unembed
        specs["unembed"] = (shd.FSDP, shd.VOCAB)
    return params, specs


def embed_apply(params, tokens, compute_dtype):
    """tokens [B, S] int32 -> [B, S, D]."""
    table = params["table"].astype(compute_dtype)
    return jnp.take(table, tokens, axis=0)


def unembed_apply(params, x, cfg: ModelConfig):
    """x [B, S, D] -> fp32 logits [B, S, V_padded] with pad positions
    masked to a large negative value (so CE over padded vocab is exact)."""
    if cfg.tie_embeddings:
        w = params["table"].astype(x.dtype).T / math.sqrt(cfg.d_model)
    else:
        w = params["unembed"].astype(x.dtype)
    logits = jax.lax.dot_general(
        x, w, dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    logits = shd.constrain(logits, shd.BATCH, None, shd.VOCAB)
    vp = logits.shape[-1]
    pad = vp - cfg.vocab_size
    if pad:
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    return logits
