"""Model assembly: scan-over-layers transformer for all assigned families.

The per-layer parameter trees are stacked along a leading ``layers`` axis and
driven by ``lax.scan`` — one layer is traced once, keeping the HLO compact
for the 512-device dry-run compiles and enabling per-layer remat.

Families:
  dense / vlm       : attn + MLP          (vlm prepends stub patch embeddings)
  moe               : attn + MoE FFN
  ssm               : mamba2 SSD block only
  hybrid            : parallel 0.5*(attn + SSD) then MLP  (hymba)
  encdec / audio    : bidirectional encoder + causal decoder w/ cross-attn
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    FAMILY_AUDIO, FAMILY_DENSE, FAMILY_ENCDEC, FAMILY_HYBRID, FAMILY_MOE,
    FAMILY_SSM, FAMILY_VLM, ModelConfig,
)
from repro.distributed import sharding as shd
from repro.models import attention as attn_mod
from repro.models import layers as lyr
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _stack_layer_init(init_one, key, num_layers: int):
    keys = jax.random.split(key, num_layers)
    params = jax.vmap(init_one)(keys)
    return params


def _stack_specs(specs):
    """Prepend the (unsharded) layers axis to every spec leaf."""
    return jax.tree.map(lambda s: (shd.LAYERS, *s), specs,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Per-family layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, dtype, cross: bool = False):
    """One decoder layer's (params, specs) for cfg.family."""
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    fam = cfg.family

    params["ln1"], specs["ln1"] = lyr.rmsnorm_init(cfg.d_model, dtype)
    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM, FAMILY_HYBRID,
               FAMILY_ENCDEC, FAMILY_AUDIO):
        params["attn"], specs["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    if fam in (FAMILY_SSM, FAMILY_HYBRID):
        params["ssd"], specs["ssd"] = ssm_mod.ssd_init(ks[1], cfg, dtype)
    if cross:
        params["ln_x"], specs["ln_x"] = lyr.rmsnorm_init(cfg.d_model, dtype)
        params["xattn"], specs["xattn"] = attn_mod.attn_init(ks[2], cfg, dtype)
    if fam == FAMILY_MOE:
        params["ln2"], specs["ln2"] = lyr.rmsnorm_init(cfg.d_model, dtype)
        params["moe"], specs["moe"] = moe_mod.moe_init(ks[3], cfg, dtype)
    elif cfg.d_ff > 0:
        params["ln2"], specs["ln2"] = lyr.rmsnorm_init(cfg.d_model, dtype)
        params["mlp"], specs["mlp"] = lyr.mlp_init(ks[4], cfg, dtype)
    return params, specs


def _layer_forward(lp, x, cfg: ModelConfig, *, positions, kv_repeat, causal,
                   window, cross_kv=None, xattn_len=None, kv_valid_len=None,
                   collect_kv=False, collect_state=False,
                   causal_skip=False):
    """Full-sequence layer. Returns (x, aux, collected)."""
    cd = x.dtype
    fam = cfg.family
    aux = jnp.float32(0.0)
    collected: Dict[str, Any] = {}

    h = lyr.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps, cd)
    delta = jnp.zeros_like(x)
    if "attn" in lp:
        a_out, kv = attn_mod.attn_forward(
            lp["attn"], h, cfg, positions=positions, kv_repeat=kv_repeat,
            causal=causal, window=window, return_kv=collect_kv,
            kv_valid_len=kv_valid_len, causal_skip=causal_skip)
        if collect_kv and kv is not None:
            collected["k"], collected["v"] = kv
        delta = delta + a_out
    if "ssd" in lp:
        s_out, state = ssm_mod.ssd_forward(
            lp["ssd"], h, cfg, return_state=collect_state)
        if collect_state and state is not None:
            collected["ssm"] = state["ssm"]
            collected["conv_x"] = state["conv"]["x"]
            collected["conv_b"] = state["conv"]["B"]
            collected["conv_c"] = state["conv"]["C"]
        delta = delta + s_out
    if "attn" in lp and "ssd" in lp:
        delta = delta * 0.5                     # hymba: mean of parallel heads
    x = x + delta

    if cross_kv is not None:
        hx = lyr.rmsnorm_apply(lp["ln_x"], x, cfg.norm_eps, cd)
        x_out, _ = attn_mod.attn_forward(
            lp["xattn"], hx, cfg, positions=positions, causal=False,
            xattn_kv=cross_kv, kv_valid_len=xattn_len)
        x = x + x_out

    if "moe" in lp:
        h2 = lyr.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps, cd)
        m_out, m_aux = moe_mod.moe_forward(lp["moe"], h2, cfg)
        x = x + m_out
        aux = aux + m_aux
    elif "mlp" in lp:
        h2 = lyr.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps, cd)
        x = x + lyr.mlp_apply(lp["mlp"], h2, cfg, cd)
    x = shd.constrain(x, shd.BATCH, None, None)
    return x, aux, collected


def _layer_decode(lp, x, cfg: ModelConfig, *, cache_layer, cache_pos,
                  kv_repeat, window, xattn_len=None, dus_write=False):
    """Single-token layer step. Returns (x, new_cache_layer)."""
    cd = x.dtype
    new_cache: Dict[str, Any] = {}
    h = lyr.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps, cd)
    delta = jnp.zeros_like(x)
    if "attn" in lp:
        scales = None
        if "k_scale" in cache_layer:
            scales = (cache_layer["k_scale"], cache_layer["v_scale"])
        a_out, ck, cv, new_scales = attn_mod.attn_decode(
            lp["attn"], h, cfg, cache_k=cache_layer["k"],
            cache_v=cache_layer["v"], cache_pos=cache_pos,
            kv_repeat=kv_repeat, window=window, kv_scales=scales,
            dus_write=dus_write)
        new_cache["k"], new_cache["v"] = ck, cv
        if new_scales is not None:
            new_cache["k_scale"], new_cache["v_scale"] = new_scales
        delta = delta + a_out
    if "ssd" in lp:
        state = {"ssm": cache_layer["ssm"],
                 "conv": {"x": cache_layer["conv_x"],
                          "B": cache_layer["conv_b"],
                          "C": cache_layer["conv_c"]}}
        s_out, new_state = ssm_mod.ssd_decode(lp["ssd"], h, cfg, state=state)
        new_cache["ssm"] = new_state["ssm"]
        new_cache["conv_x"] = new_state["conv"]["x"]
        new_cache["conv_b"] = new_state["conv"]["B"]
        new_cache["conv_c"] = new_state["conv"]["C"]
        delta = delta + s_out
    if "attn" in lp and "ssd" in lp:
        delta = delta * 0.5
    x = x + delta

    if "xattn" in lp:
        hx = lyr.rmsnorm_apply(lp["ln_x"], x, cfg.norm_eps, cd)
        x_out, _, _, _ = attn_mod.attn_decode(
            lp["xattn"], hx, cfg, cache_k=None, cache_v=None,
            cache_pos=cache_pos,
            xattn_kv=(cache_layer["cross_k"], cache_layer["cross_v"]),
            xattn_len=xattn_len)
        new_cache["cross_k"] = cache_layer["cross_k"]
        new_cache["cross_v"] = cache_layer["cross_v"]
        x = x + x_out

    if "moe" in lp:
        h2 = lyr.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps, cd)
        m_out, _ = moe_mod.moe_forward(lp["moe"], h2, cfg)
        x = x + m_out
    elif "mlp" in lp:
        h2 = lyr.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps, cd)
        x = x + lyr.mlp_apply(lp["mlp"], h2, cfg, cd)
    return x, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass
class Model:
    """Pure-function bundle for one architecture.

    ``kv_repeat`` is a build-time constant (from the sharding profile) since
    it determines cache shapes. ``remat_group > 1`` enables two-level
    (sqrt-L) remat: the layer scan is regrouped as
    ``[n_groups, group, ...]`` with a checkpoint at the group level, cutting
    saved residual carries from L to (L/group + group) at the cost of one
    extra in-group forward during backward.
    """
    cfg: ModelConfig
    kv_repeat: int = 1
    remat_group: int = 0
    causal_skip: bool = False    # §Perf: skip fully-masked causal kv tiles
    kv_cache_bits: int = 16      # §Perf: 8 -> int8 KV cache + bf16 scales
    kv_dus_write: bool = False   # §Perf: per-shard DUS cache write

    # -------------------------------------------------- init
    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        k_embed, k_layers, k_enc, k_final = jax.random.split(key, 4)
        params: Dict[str, Any] = {}
        params["embed"], _ = lyr.embed_init(k_embed, cfg, dtype)

        cross = cfg.family in (FAMILY_ENCDEC, FAMILY_AUDIO)
        init_one = lambda k: _layer_init(k, cfg, dtype, cross=cross)[0]
        params["layers"] = _stack_layer_init(init_one, k_layers, cfg.num_layers)

        if cfg.encoder_layers:
            enc_cfg = cfg
            init_enc = lambda k: _layer_init(k, enc_cfg, dtype, cross=False)[0]
            params["encoder"] = _stack_layer_init(init_enc, k_enc,
                                                  cfg.encoder_layers)
            params["enc_norm"], _ = lyr.rmsnorm_init(cfg.d_model, dtype)
        params["final_norm"], _ = lyr.rmsnorm_init(cfg.d_model, dtype)
        return params

    def specs(self):
        """Logical-axis spec tree matching init()'s structure (static —
        derived via eval_shape so nothing is allocated)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        cross = cfg.family in (FAMILY_ENCDEC, FAMILY_AUDIO)
        specs: Dict[str, Any] = {}
        _, e_specs = _eval_specs(lambda k: lyr.embed_init(k, cfg, dtype))
        specs["embed"] = e_specs
        _, l_specs = _eval_specs(lambda k: _layer_init(k, cfg, dtype, cross=cross))
        specs["layers"] = _stack_specs(l_specs)
        if cfg.encoder_layers:
            _, enc_specs = _eval_specs(
                lambda k: _layer_init(k, cfg, dtype, cross=False))
            specs["encoder"] = _stack_specs(enc_specs)
            specs["enc_norm"] = {"scale": (None,)}
        specs["final_norm"] = {"scale": (None,)}
        return specs

    # -------------------------------------------------- embedding helpers
    def _embed_inputs(self, params, batch):
        """Returns (embeds [B,S,D], positions [B,S])."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        tok_emb = lyr.embed_apply(params["embed"], batch["tokens"], cd)
        if cfg.family == FAMILY_VLM and "patch_embeds" in batch:
            emb = jnp.concatenate(
                [batch["patch_embeds"].astype(cd), tok_emb], axis=1)
        else:
            emb = tok_emb
        b, s = emb.shape[0], emb.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return emb, positions

    def _encode(self, params, batch):
        """Encoder stack over stub frame embeddings (audio/encdec)."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x = batch["frame_embeds"].astype(cd)
        b, f = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

        def enc_layer(carry, lp):
            h, _ = carry
            h2, aux, _ = _layer_forward(
                lp, h, cfg, positions=positions, kv_repeat=self.kv_repeat,
                causal=False, window=0)
            return (h2, aux), None

        fn = _remat_wrap(enc_layer, cfg.remat)
        (x, _), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)), params["encoder"])
        return lyr.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps, cd)

    def _cross_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V from encoder output (stacked [L,...])."""
        cfg = self.cfg
        cd = enc_out.dtype
        b, f = enc_out.shape[0], enc_out.shape[1]
        positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

        def one_layer(lp):
            k = lyr.dense_apply(lp["xattn"]["k"], enc_out, cd)
            v = lyr.dense_apply(lp["xattn"]["v"], enc_out, cd)
            if cfg.rope_theta > 0:
                k = attn_mod.apply_rope(k, positions, cfg.rope_theta)
            k = attn_mod._repeat_kv(k, self.kv_repeat)
            v = attn_mod._repeat_kv(v, self.kv_repeat)
            return k, v

        return jax.lax.map(one_layer, params["layers"])

    # -------------------------------------------------- train forward
    def train_logits(self, params, batch):
        """Teacher-forced forward. Returns (logits fp32 [B,S,Vp], aux)."""
        cfg = self.cfg
        emb, positions = self._embed_inputs(params, batch)
        cross_kv = None
        xattn_len = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch)
            cross_kv_all = self._cross_kv(params, enc_out)  # ([L,..], [L,..])
        window = cfg.attn_window

        def layer(carry, lp_and_kv):
            x, aux = carry
            if cfg.encoder_layers:
                lp, ckv = lp_and_kv
            else:
                lp, ckv = lp_and_kv, None
            x, a, _ = _layer_forward(
                lp, x, cfg, positions=positions, kv_repeat=self.kv_repeat,
                causal=True, window=window, cross_kv=ckv,
                xattn_len=xattn_len, causal_skip=self.causal_skip)
            return (x, aux + a), None

        fn = _remat_wrap(layer, cfg.remat)
        xs = (params["layers"], cross_kv_all) if cfg.encoder_layers \
            else params["layers"]
        g = self.remat_group
        if g > 1 and cfg.num_layers % g == 0:
            n_groups = cfg.num_layers // g

            def regroup(a):
                return a.reshape(n_groups, g, *a.shape[1:])

            xs_g = jax.tree.map(regroup, xs)

            def group_body(carry, gxs):
                carry, _ = jax.lax.scan(fn, carry, gxs)
                return carry, None

            (x, aux), _ = jax.lax.scan(jax.checkpoint(group_body),
                                       (emb, jnp.float32(0.0)), xs_g)
        else:
            (x, aux), _ = jax.lax.scan(fn, (emb, jnp.float32(0.0)), xs)
        cd = jnp.dtype(cfg.compute_dtype)
        x = lyr.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cd)
        logits = lyr.unembed_apply(params["embed"], x, cfg)
        return logits, aux

    def loss(self, params, batch):
        """Mean CE over targets >= 0 (+ MoE aux). Returns (loss, metrics)."""
        logits, aux = self.train_logits(params, batch)
        targets = batch["targets"]
        if logits.shape[1] != targets.shape[1]:
            # vlm: logits cover patch positions too; score text tail only
            logits = logits[:, logits.shape[1] - targets.shape[1]:]
        mask = (targets >= 0).astype(jnp.float32)
        tgt = jnp.maximum(targets, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * mask
        ntok = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(ce) / ntok + aux
        return loss, {"ce": jnp.sum(ce) / ntok, "aux": aux, "ntok": ntok}

    # -------------------------------------------------- serving
    def cache_len_for(self, seq_len: int) -> int:
        if self.cfg.attn_window:
            return min(seq_len, self.cfg.attn_window)
        return seq_len

    def init_cache(self, batch_size: int, cache_len: int):
        """Zeroed decode cache (also used via eval_shape by the dry-run)."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        L, b = cfg.num_layers, batch_size
        layers: Dict[str, Any] = {}
        if cfg.has_attention:
            hs = cfg.num_kv_heads * self.kv_repeat
            dh = cfg.resolved_head_dim
            s_c = self.cache_len_for(cache_len)
            kv_dtype = jnp.int8 if self.kv_cache_bits == 8 else cd
            layers["k"] = jnp.zeros((L, b, s_c, hs, dh), dtype=kv_dtype)
            layers["v"] = jnp.zeros((L, b, s_c, hs, dh), dtype=kv_dtype)
            if self.kv_cache_bits == 8:
                layers["k_scale"] = jnp.zeros((L, b, s_c, hs), dtype=cd)
                layers["v_scale"] = jnp.zeros((L, b, s_c, hs), dtype=cd)
        if cfg.ssm.enabled:
            d_inner, nh, p, n = ssm_mod.ssm_dims(cfg)
            cw = cfg.ssm.conv_width
            layers["ssm"] = jnp.zeros((L, b, nh, p, n), dtype=jnp.float32)
            layers["conv_x"] = jnp.zeros((L, b, cw - 1, nh, p), dtype=cd)
            layers["conv_b"] = jnp.zeros((L, b, cw - 1, cfg.ssm.state_size), dtype=cd)
            layers["conv_c"] = jnp.zeros((L, b, cw - 1, cfg.ssm.state_size), dtype=cd)
        if cfg.encoder_layers:
            hs = cfg.num_kv_heads * self.kv_repeat
            dh = cfg.resolved_head_dim
            f = cfg.frontend_tokens
            layers["cross_k"] = jnp.zeros((L, b, f, hs, dh), dtype=cd)
            layers["cross_v"] = jnp.zeros((L, b, f, hs, dh), dtype=cd)
        return {"pos": jnp.zeros((), jnp.int32), "layers": layers}

    def cache_specs(self):
        """Logical shardings for the decode cache."""
        cfg = self.cfg
        layers: Dict[str, Any] = {}
        if cfg.has_attention:
            layers["k"] = (shd.LAYERS, shd.BATCH, shd.KV_SEQ, shd.KV_HEADS, None)
            layers["v"] = (shd.LAYERS, shd.BATCH, shd.KV_SEQ, shd.KV_HEADS, None)
            if self.kv_cache_bits == 8:
                layers["k_scale"] = (shd.LAYERS, shd.BATCH, shd.KV_SEQ,
                                     shd.KV_HEADS)
                layers["v_scale"] = (shd.LAYERS, shd.BATCH, shd.KV_SEQ,
                                     shd.KV_HEADS)
        if cfg.ssm.enabled:
            layers["ssm"] = (shd.LAYERS, shd.BATCH, shd.SSD_HEADS, None, None)
            layers["conv_x"] = (shd.LAYERS, shd.BATCH, None, shd.SSD_HEADS, None)
            layers["conv_b"] = (shd.LAYERS, shd.BATCH, None, None)
            layers["conv_c"] = (shd.LAYERS, shd.BATCH, None, None)
        if cfg.encoder_layers:
            layers["cross_k"] = (shd.LAYERS, shd.BATCH, None, shd.KV_HEADS, None)
            layers["cross_v"] = (shd.LAYERS, shd.BATCH, None, shd.KV_HEADS, None)
        return {"pos": (), "layers": layers}

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Process a prompt, return (last-token logits, filled cache).

        ``max_len``: cache capacity to allocate (>= prompt length) so
        subsequent ``decode_step`` calls have room; defaults to prompt
        length + 1.
        """
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        emb, positions = self._embed_inputs(params, batch)
        b, s = emb.shape[0], emb.shape[1]
        window = cfg.attn_window
        collect_kv = cfg.has_attention
        collect_state = cfg.ssm.enabled

        cross_kv_all = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch)
            cross_kv_all = self._cross_kv(params, enc_out)

        def layer(x, lp_and_kv):
            if cfg.encoder_layers:
                lp, ckv = lp_and_kv
            else:
                lp, ckv = lp_and_kv, None
            x, _, coll = _layer_forward(
                lp, x, cfg, positions=positions, kv_repeat=self.kv_repeat,
                causal=True, window=window, cross_kv=ckv,
                collect_kv=collect_kv, collect_state=collect_state)
            return x, coll

        xs = (params["layers"], cross_kv_all) if cfg.encoder_layers \
            else params["layers"]
        x, collected = jax.lax.scan(layer, emb, xs)
        x = lyr.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cd)
        logits = lyr.unembed_apply(params["embed"], x[:, -1:], cfg)

        cap = max_len if max_len is not None else s + 1
        if cap < s and not cfg.attn_window:
            raise ValueError(f"prefill cache capacity {cap} < prompt "
                             f"embedding length {s}")
        cache = self.init_cache(b, cap)
        layers = dict(cache["layers"])
        if collect_kv:
            k_full, v_full = collected["k"], collected["v"]   # [L,B,S,hs,dh]
            s_c = layers["k"].shape[2]
            if self.kv_cache_bits == 8:
                k_full, k_sc = attn_mod._quantize_kv(k_full)
                v_full, v_sc = attn_mod._quantize_kv(v_full)
                if s_c < s:
                    layers["k_scale"] = k_sc[:, :, s - s_c:].astype(cd)
                    layers["v_scale"] = v_sc[:, :, s - s_c:].astype(cd)
                else:
                    layers["k_scale"] = layers["k_scale"].at[:, :, :s].set(
                        k_sc.astype(cd))
                    layers["v_scale"] = layers["v_scale"].at[:, :, :s].set(
                        v_sc.astype(cd))
            kv_dt = layers["k"].dtype
            if s_c < s:
                # sliding window: keep the ring-aligned tail (s % window == 0)
                layers["k"] = k_full[:, :, s - s_c:].astype(kv_dt)
                layers["v"] = v_full[:, :, s - s_c:].astype(kv_dt)
            else:
                layers["k"] = layers["k"].at[:, :, :s].set(k_full.astype(kv_dt))
                layers["v"] = layers["v"].at[:, :, :s].set(v_full.astype(kv_dt))
        if collect_state:
            layers["ssm"] = collected["ssm"]
            layers["conv_x"] = collected["conv_x"].astype(cd)
            layers["conv_b"] = collected["conv_b"].astype(cd)
            layers["conv_c"] = collected["conv_c"].astype(cd)
        if cfg.encoder_layers and cross_kv_all is not None:
            layers["cross_k"] = cross_kv_all[0].astype(cd)
            layers["cross_v"] = cross_kv_all[1].astype(cd)
        return logits, {"pos": jnp.asarray(s, jnp.int32), "layers": layers}

    def prefill_streaming(self, params, batch, chunk: int = 4096):
        """SSM-family chunked prefill: process an arbitrarily long prompt in
        fixed-size chunks carrying the SSM/conv state between them — peak
        activation memory is O(chunk), which is what makes the ``long_500k``
        shape *ingestable*, not just decodable. Returns (last-token logits,
        decode-ready cache)."""
        cfg = self.cfg
        assert cfg.family == FAMILY_SSM, "streaming prefill is SSM-only"
        cd = jnp.dtype(cfg.compute_dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        assert s % chunk == 0 or s < chunk, \
            "prompt length must be a multiple of the chunk"
        chunk = min(chunk, s)
        cache = self.init_cache(b, 1)
        layers = cache["layers"]
        logits = None
        for c0 in range(0, s, chunk):
            tok_c = tokens[:, c0:c0 + chunk]
            x = lyr.embed_apply(params["embed"], tok_c, cd)

            def layer(x, lp_and_cl):
                lp, cl = lp_and_cl
                h = lyr.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps, cd)
                out, st = ssm_mod.ssd_forward(
                    lp["ssd"], h, cfg,
                    init_state=cl["ssm"],
                    conv_state={"x": cl["conv_x"], "B": cl["conv_b"],
                                "C": cl["conv_c"]},
                    return_state=True)
                x = x + out
                new_cl = {"ssm": st["ssm"],
                          "conv_x": st["conv"]["x"].astype(cd),
                          "conv_b": st["conv"]["B"].astype(cd),
                          "conv_c": st["conv"]["C"].astype(cd)}
                return x, new_cl

            x, layers = jax.lax.scan(layer, x, (params["layers"], layers))
            x = lyr.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cd)
            logits = lyr.unembed_apply(params["embed"], x[:, -1:], cfg)
        return logits, {"pos": jnp.asarray(s, jnp.int32), "layers": layers}

    def decode_step(self, params, tokens, cache):
        """tokens [B, 1] -> (logits [B,1,Vp], new cache)."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x = lyr.embed_apply(params["embed"], tokens, cd)
        pos = cache["pos"]
        window = cfg.attn_window

        def layer(x, lp_and_cache):
            lp, cl = lp_and_cache
            x, new_cl = _layer_decode(
                lp, x, cfg, cache_layer=cl, cache_pos=pos,
                kv_repeat=self.kv_repeat, window=window,
                dus_write=self.kv_dus_write)
            return x, new_cl

        x, new_layers = jax.lax.scan(layer, x, (params["layers"],
                                                cache["layers"]))
        x = lyr.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cd)
        logits = lyr.unembed_apply(params["embed"], x, cfg)
        return logits, {"pos": pos + 1, "layers": new_layers}


def _eval_specs(init_fn):
    """Run an init that returns (params, specs) under eval_shape and return
    (param ShapeDtypeStructs, concrete specs). Specs are static tuples, so we
    call the fn once abstractly and once for specs via closure capture."""
    captured = {}

    def wrapper(k):
        p, s = init_fn(k)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(wrapper, jax.random.PRNGKey(0))
    return shapes, captured["specs"]
