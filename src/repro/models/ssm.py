"""Mamba-2 / SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD: the sequence is split into chunks of ``Q`` tokens; a single
``lax.scan`` carries the inter-chunk SSM state while each step computes the
intra-chunk (quadratic, attention-like) term — O(S·Q) compute, O(1) state.

Recurrence (per head h, state dim n, head dim p):
    h_t = exp(dt_t·A) h_{t-1} + B_t (dt_t x_t)
    y_t = C_t · h_t + D x_t
with A negative scalar per head, B/C shared across heads (n_groups=1 — the
multi-value-attention analog in the SSD paper).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models.layers import dense_apply, dense_init, rmsnorm_apply, rmsnorm_init


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, nheads, head_dim, state)."""
    d_inner = cfg.ssm.expand * cfg.d_model
    nheads = d_inner // cfg.ssm.head_dim
    return d_inner, nheads, cfg.ssm.head_dim, cfg.ssm.state_size


def ssd_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner, nh, p, n = ssm_dims(cfg)
    cw = cfg.ssm.conv_width
    ks = jax.random.split(key, 8)
    z_p, z_s = dense_init(ks[0], d, (nh, p), (shd.FSDP, shd.SSD_HEADS, None), dtype)
    x_p, x_s = dense_init(ks[1], d, (nh, p), (shd.FSDP, shd.SSD_HEADS, None), dtype)
    b_p, b_s = dense_init(ks[2], d, (n,), (shd.FSDP, None), dtype)
    c_p, c_s = dense_init(ks[3], d, (n,), (shd.FSDP, None), dtype)
    dt_p, dt_s = dense_init(ks[4], d, (nh,), (shd.FSDP, shd.SSD_HEADS), dtype)
    o_p, o_s = dense_init(ks[5], nh * p, (d,), (shd.SSD_HEADS, shd.FSDP), dtype,
                          scale=1.0 / math.sqrt(d_inner))
    o_p = {"w": o_p["w"].reshape(nh, p, d)}
    o_s = {"w": (shd.SSD_HEADS, None, shd.FSDP)}
    # A_log: A = -exp(A_log) in [-16, -1]
    a_log = jnp.log(jax.random.uniform(ks[6], (nh,), dtype=jnp.float32,
                                       minval=1.0, maxval=16.0))
    # dt bias: softplus^{-1}(u), u ~ logU[1e-3, 1e-1]
    u = jnp.exp(jax.random.uniform(ks[7], (nh,), dtype=jnp.float32,
                                   minval=math.log(1e-3), maxval=math.log(1e-1)))
    dt_bias = u + jnp.log(-jnp.expm1(-u))
    # depthwise causal convs on x / B / C streams
    conv_x = jnp.zeros((cw, nh, p), dtype=dtype).at[cw - 1].set(1.0)
    conv_b = jnp.zeros((cw, n), dtype=dtype).at[cw - 1].set(1.0)
    conv_c = jnp.zeros((cw, n), dtype=dtype).at[cw - 1].set(1.0)
    norm_p, norm_s = rmsnorm_init(nh * p, dtype)
    params = {
        "z": z_p, "x": x_p, "B": b_p, "C": c_p, "dt": dt_p, "o": o_p,
        "A_log": a_log.astype(dtype), "D": jnp.ones((nh,), dtype=dtype),
        "dt_bias": dt_bias.astype(dtype),
        "conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c,
        "norm": norm_p,
    }
    specs = {
        "z": z_s, "x": x_s, "B": b_s, "C": c_s, "dt": dt_s, "o": o_s,
        "A_log": (shd.SSD_HEADS,), "D": (shd.SSD_HEADS,),
        "dt_bias": (shd.SSD_HEADS,),
        "conv_x": (None, shd.SSD_HEADS, None), "conv_b": (None, None),
        "conv_c": (None, None),
        "norm": norm_s,
    }
    return params, specs


def _causal_depthwise_conv(u: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """u: [B, S, ...chan], w: [cw, ...chan] -> same shape as u (causal)."""
    cw = w.shape[0]
    pad = [(0, 0), (cw - 1, 0)] + [(0, 0)] * (u.ndim - 2)
    up = jnp.pad(u, pad)
    out = jnp.zeros_like(u)
    s = u.shape[1]
    for i in range(cw):
        out = out + w[i] * jax.lax.dynamic_slice_in_dim(up, i, s, axis=1)
    return out


def ssd_scan(xdt: jnp.ndarray, a: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
             chunk: int, init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    xdt: [b, s, h, p] (x pre-multiplied by dt); a: [b, s, h] (dt*A, negative);
    B, C: [b, s, n]. Returns (y [b, s, h, p], final_state [b, h, p, n]).
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    xdt_c = xdt.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    a_c = a.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    B_c = B.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    C_c = C.reshape(b, nc, q, n).transpose(1, 0, 2, 3)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), dtype=jnp.float32)

    mask = jnp.tril(jnp.ones((q, q), dtype=bool))

    def step(state, inp):
        xc, ac, Bc, Cc = inp                      # [b,q,h,p], [b,q,h], [b,q,n]
        cum = jnp.cumsum(ac, axis=1)              # [b,q,h]
        total = cum[:, -1]                        # [b,h]
        # intra-chunk (attention-like) term
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc,
                            preferred_element_type=jnp.float32)  # [b,q,q]
        ldecay = cum[:, :, None, :] - cum[:, None, :, :]          # [b,qi,qj,h]
        ldecay = jnp.where(mask[None, :, :, None], ldecay, -jnp.inf)
        L = jnp.exp(ldecay)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, L,
                             xc.astype(jnp.float32))
        # inter-chunk term from carried state
        y_inter = jnp.einsum("bin,bhpn->bihp", Cc, state) \
            * jnp.exp(cum)[..., None]
        # state update
        w = jnp.exp(total[:, None, :] - cum)       # [b,q,h]
        chunk_state = jnp.einsum("bjn,bjh,bjhp->bhpn", Bc, w,
                                 xc.astype(jnp.float32))
        new_state = jnp.exp(total)[:, :, None, None] * state + chunk_state
        return new_state, (y_intra + y_inter)

    # checkpoint per chunk: backward recomputes the [b,q,q,h] decay tile
    # instead of saving it for every chunk
    final_state, ys = jax.lax.scan(jax.checkpoint(step), init_state,
                                   (xdt_c, a_c, B_c, C_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y.astype(xdt.dtype), final_state


def ssd_forward(params, x, cfg: ModelConfig, *,
                init_state=None, conv_state=None, return_state: bool = False):
    """Full mamba2 block over a sequence. x: [B, S, D].

    Returns (y, state_dict or None) where state_dict carries the SSM state
    and conv tail for streaming/decode continuation.
    """
    cd = x.dtype
    d_inner, nh, p, n = ssm_dims(cfg)
    cw = cfg.ssm.conv_width
    b, s, _ = x.shape

    z = dense_apply(params["z"], x, cd)                       # [B,S,H,P]
    xs = dense_apply(params["x"], x, cd)
    Bp = dense_apply(params["B"], x, cd)                      # [B,S,N]
    Cp = dense_apply(params["C"], x, cd)
    dt = dense_apply(params["dt"], x, jnp.float32)            # [B,S,H]

    if conv_state is not None:
        # prepend cached tail so the causal conv continues the stream
        xs = jnp.concatenate([conv_state["x"].astype(cd), xs], axis=1)
        Bp = jnp.concatenate([conv_state["B"].astype(cd), Bp], axis=1)
        Cp = jnp.concatenate([conv_state["C"].astype(cd), Cp], axis=1)
    xs_c = jax.nn.silu(_causal_depthwise_conv(xs, params["conv_x"].astype(cd)))
    Bp_c = jax.nn.silu(_causal_depthwise_conv(Bp, params["conv_b"].astype(cd)))
    Cp_c = jax.nn.silu(_causal_depthwise_conv(Cp, params["conv_c"].astype(cd)))
    if conv_state is not None:
        xs_c, Bp_c, Cp_c = (t[:, -s:] for t in (xs_c, Bp_c, Cp_c))
    new_conv = None
    if return_state:
        tail = cw - 1
        src_x = xs if conv_state is None else xs
        new_conv = {"x": src_x[:, -tail:], "B": Bp[:, -tail:], "C": Cp[:, -tail:]}

    xs_c = shd.constrain(xs_c, shd.BATCH, None, shd.SSD_HEADS, None)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))         # [H]
    a = dt * A                                                # [B,S,H]
    xdt = xs_c * dt.astype(cd)[..., None]

    y, state = ssd_scan(xdt, a, Bp_c, Cp_c, cfg.ssm.chunk_size,
                        init_state=init_state)
    y = y + params["D"].astype(cd)[None, None, :, None] * xs_c
    y = y * jax.nn.silu(z)
    y = shd.constrain(y, shd.BATCH, None, shd.SSD_HEADS, None)
    y = rmsnorm_apply(params["norm"], y.reshape(b, s, nh * p),
                      cfg.norm_eps, cd).reshape(b, s, nh, p)
    out = dense_apply(params["o"], y, cd, contract_dims=2)
    out = shd.constrain(out, shd.BATCH, None, None)
    if return_state:
        return out, {"ssm": state, "conv": new_conv}
    return out, None


def ssd_decode(params, x, cfg: ModelConfig, *, state):
    """Single-token step. x: [B, 1, D]; state: {'ssm': [B,H,P,N],
    'conv': {'x': [B,cw-1,H,P], 'B': [B,cw-1,N], 'C': [B,cw-1,N]}}.
    Returns (y [B,1,D], new_state)."""
    cd = x.dtype
    d_inner, nh, p, n = ssm_dims(cfg)
    cw = cfg.ssm.conv_width

    z = dense_apply(params["z"], x, cd)[:, 0]                 # [B,H,P]
    xs = dense_apply(params["x"], x, cd)                      # [B,1,H,P]
    Bp = dense_apply(params["B"], x, cd)
    Cp = dense_apply(params["C"], x, cd)
    dt = dense_apply(params["dt"], x, jnp.float32)[:, 0]      # [B,H]

    conv = state["conv"]
    x_win = jnp.concatenate([conv["x"].astype(cd), xs], axis=1)   # [B,cw,H,P]
    B_win = jnp.concatenate([conv["B"].astype(cd), Bp], axis=1)
    C_win = jnp.concatenate([conv["C"].astype(cd), Cp], axis=1)
    xc = jax.nn.silu(jnp.einsum("bwhp,whp->bhp", x_win, params["conv_x"].astype(cd)))
    Bc = jax.nn.silu(jnp.einsum("bwn,wn->bn", B_win, params["conv_b"].astype(cd)))
    Cc = jax.nn.silu(jnp.einsum("bwn,wn->bn", C_win, params["conv_c"].astype(cd)))
    new_conv = {"x": x_win[:, 1:], "B": B_win[:, 1:], "C": C_win[:, 1:]}

    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                   # [B,H]
    h = state["ssm"]                                          # [B,H,P,N] fp32
    upd = jnp.einsum("bn,bhp,bh->bhpn", Bc.astype(jnp.float32),
                     xc.astype(jnp.float32), dt)
    h_new = decay[:, :, None, None] * h + upd
    y = jnp.einsum("bn,bhpn->bhp", Cc.astype(jnp.float32), h_new)
    y = y.astype(cd) + params["D"].astype(cd)[None, :, None] * xc
    y = y * jax.nn.silu(z)
    b = x.shape[0]
    y = rmsnorm_apply(params["norm"], y.reshape(b, nh * p), cfg.norm_eps, cd)
    y = y.reshape(b, 1, nh, p)
    out = dense_apply(params["o"], y, cd, contract_dims=2)
    return out, {"ssm": h_new, "conv": new_conv}
