from repro.models.model import (
    build_model,
    Model,
    input_specs,
)

__all__ = ["build_model", "Model", "input_specs"]
