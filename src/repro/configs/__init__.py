"""Architecture/config registry.

``get_config(name)`` returns the full assigned config; ``reduced(cfg)``
derives a same-family smoke-test config (small widths/layers/experts) that
runs one step on CPU; ``applicable_shapes(cfg)`` encodes the cell matrix
(long_500k only for sub-quadratic archs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import (
    ALL_SHAPES,
    AionConfig,
    LONG_500K,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    MULTI_POD_MESH,
    ShapeConfig,
    SHAPES_BY_NAME,
    SINGLE_POD_MESH,
    SSMConfig,
    FAMILY_AUDIO,
    FAMILY_DENSE,
    FAMILY_ENCDEC,
    FAMILY_HYBRID,
    FAMILY_MOE,
    FAMILY_SSM,
    FAMILY_VLM,
)

from repro.configs.mamba2_780m import CONFIG as MAMBA2_780M
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.mistral_large_123b import CONFIG as MISTRAL_LARGE_123B
from repro.configs.command_r_35b import CONFIG as COMMAND_R_35B
from repro.configs.starcoder2_7b import CONFIG as STARCODER2_7B
from repro.configs.internvl2_76b import CONFIG as INTERNVL2_76B
from repro.configs.phi35_moe_42b import CONFIG as PHI35_MOE_42B
from repro.configs.qwen3_moe_30b import CONFIG as QWEN3_MOE_30B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from repro.configs.hymba_1_5b import CONFIG as HYMBA_1_5B

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        MAMBA2_780M,
        GRANITE_34B,
        MISTRAL_LARGE_123B,
        COMMAND_R_35B,
        STARCODER2_7B,
        INTERNVL2_76B,
        PHI35_MOE_42B,
        QWEN3_MOE_30B,
        SEAMLESS_M4T_MEDIUM,
        HYMBA_1_5B,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving the family shape
    (GQA ratio, MoE routing, SSM state, enc-dec split, frontends)."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2),
        d_model=256,
        vocab_size=512,
        d_ff=0 if cfg.d_ff == 0 else 512,
        head_dim=64 if cfg.resolved_head_dim else 0,
        rope_theta=cfg.rope_theta,
        remat="none",
        tie_embeddings=cfg.tie_embeddings,
        family=cfg.family,
        source=cfg.source,
    )
    if cfg.has_attention:
        # keep the GQA group ratio when possible
        ratio = max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1)
        kw["num_heads"] = 4
        kw["num_kv_heads"] = max(4 // min(ratio, 4), 1)
    else:
        kw["num_heads"] = 0
        kw["num_kv_heads"] = 0
    if cfg.moe.enabled:
        kw["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
        )
    if cfg.ssm.enabled:
        kw["ssm"] = SSMConfig(
            state_size=min(cfg.ssm.state_size, 16),
            head_dim=32,
            expand=2,
            chunk_size=32,
        )
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.frontend_tokens:
        kw["frontend_tokens"] = 16
    if cfg.attn_window:
        kw["attn_window"] = 32
    return ModelConfig(**kw)


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assignment's cell matrix. long_500k needs sub-quadratic decode;
    skipped for pure full-attention archs (noted in DESIGN.md §5)."""
    shapes = []
    for s in ALL_SHAPES:
        if s.name == LONG_500K.name and not cfg.is_subquadratic:
            continue
        shapes.append(s)
    return shapes


def all_cells() -> List[Tuple[ModelConfig, ShapeConfig]]:
    cells = []
    for name in sorted(ARCHS):
        cfg = ARCHS[name]
        for s in applicable_shapes(cfg):
            cells.append((cfg, s))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, reason) for every assigned-but-skipped cell."""
    out = []
    for name in sorted(ARCHS):
        cfg = ARCHS[name]
        for s in ALL_SHAPES:
            if s.name == LONG_500K.name and not cfg.is_subquadratic:
                out.append((name, s.name,
                            "pure full-attention arch: 500k decode is not "
                            "sub-quadratic (DESIGN.md §5)"))
    return out


__all__ = [
    "ARCHS", "get_config", "reduced", "applicable_shapes", "all_cells",
    "skipped_cells", "AionConfig", "MeshConfig", "ModelConfig", "MoEConfig",
    "ShapeConfig", "SSMConfig", "ALL_SHAPES", "SHAPES_BY_NAME",
    "SINGLE_POD_MESH", "MULTI_POD_MESH",
    "FAMILY_AUDIO", "FAMILY_DENSE", "FAMILY_ENCDEC", "FAMILY_HYBRID",
    "FAMILY_MOE", "FAMILY_SSM", "FAMILY_VLM",
]
