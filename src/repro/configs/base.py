"""Configuration schema for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; every
input-shape cell as a :class:`ShapeConfig`; meshes as :class:`MeshConfig`.
Configs are plain frozen dataclasses so they hash, compare, and serialize
trivially (JSON manifests for checkpoints / dry-run records).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


# ---------------------------------------------------------------------------
# Model family tags (drive which blocks the assembly uses)
# ---------------------------------------------------------------------------
FAMILY_DENSE = "dense"      # decoder-only dense transformer (GQA)
FAMILY_MOE = "moe"          # decoder-only with MoE FFN
FAMILY_SSM = "ssm"          # attention-free state-space (mamba2 / SSD)
FAMILY_HYBRID = "hybrid"    # parallel attention + SSM heads (hymba)
FAMILY_ENCDEC = "encdec"    # encoder-decoder (seamless)
FAMILY_VLM = "vlm"          # vision frontend (stub) + dense decoder backbone
FAMILY_AUDIO = "audio"      # audio frontend (stub) + enc-dec backbone

ALL_FAMILIES = (
    FAMILY_DENSE, FAMILY_MOE, FAMILY_SSM, FAMILY_HYBRID,
    FAMILY_ENCDEC, FAMILY_VLM, FAMILY_AUDIO,
)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN parameters."""
    num_experts: int = 0
    top_k: int = 0
    # capacity factor for dense-dispatch (tokens routed per expert =
    # capacity_factor * tokens * top_k / num_experts, rounded up to 128)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD parameters (state-space duality, arXiv:2405.21060)."""
    state_size: int = 0          # N: SSM state dimension (per group)
    head_dim: int = 64           # P: SSD head dim
    expand: int = 2              # d_inner = expand * d_model
    chunk_size: int = 256        # SSD chunk length (Q in the paper)
    conv_width: int = 4          # short causal conv width
    n_groups: int = 1            # B/C groups shared across heads (MVA analog)

    @property
    def enabled(self) -> bool:
        return self.state_size > 0


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. Dims follow the assignment table verbatim."""
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int            # GQA kv heads (0 for attention-free)
    d_ff: int                    # FFN hidden (per-expert hidden for MoE)
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # enc-dec: encoder layer count (decoder uses num_layers)
    encoder_layers: int = 0
    # frontends (vlm/audio): number of stub embedding positions prepended
    frontend_tokens: int = 0
    # hymba: sliding-window size for the attention heads (sub-quadratic)
    attn_window: int = 0         # 0 -> full causal attention
    mlp_variant: str = "swiglu"  # 'swiglu' (3 mats) | 'gelu' (2 mats)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    use_bias: bool = False
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # remat policy for scan-over-layers: 'none' | 'full' | 'dots'
    remat: str = "full"
    source: str = ""             # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if a 500k-token decode step is feasible (SSM state or
        sliding-window attention keeps per-step state o(seq))."""
        if self.family == FAMILY_SSM:
            return True
        if self.family == FAMILY_HYBRID and self.attn_window > 0:
            return True
        return False

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        total = V * d                                    # embedding
        if not self.tie_embeddings:
            total += V * d                               # unembedding
        per_layer = 0
        if self.has_attention:
            q = d * (self.num_heads * hd)
            kv = 2 * d * (self.num_kv_heads * hd)
            o = (self.num_heads * hd) * d
            per_layer += q + kv + o
        if self.ssm.enabled:
            d_inner = self.ssm.expand * self.d_model
            nheads = max(d_inner // self.ssm.head_dim, 1)
            g = self.ssm.n_groups
            # in_proj: z, x, B, C (per group), dt (per head)
            per_layer += d * (2 * d_inner + 2 * self.ssm.state_size * g + nheads)
            per_layer += d_inner * d                     # out_proj
            per_layer += self.ssm.conv_width * (d_inner + 2 * self.ssm.state_size * g)
            per_layer += 2 * nheads                      # A_log, D
        n_mlp_mats = 3 if self.mlp_variant == "swiglu" else 2
        if self.moe.enabled:
            per_layer += d * self.moe.num_experts        # router
            per_layer += self.moe.num_experts * n_mlp_mats * d * self.d_ff
        elif self.d_ff > 0:
            per_layer += n_mlp_mats * d * self.d_ff      # SwiGLU: gate, up, down
        per_layer += 2 * d                               # 2 RMSNorm scales
        total += L * per_layer
        if self.encoder_layers:
            # encoder: self-attn + FFN, decoder adds cross-attn
            enc_layer = 0
            if self.has_attention:
                q = d * (self.num_heads * hd)
                kv = 2 * d * (self.num_kv_heads * hd)
                o = (self.num_heads * hd) * d
                enc_layer += q + kv + o
            enc_layer += 3 * d * self.d_ff + 2 * d
            total += self.encoder_layers * enc_layer
            # decoder cross-attention (added per decoder layer)
            if self.has_attention:
                total += L * (d * (self.num_heads * hd)
                              + 2 * d * (self.num_kv_heads * hd)
                              + (self.num_heads * hd) * d + d)
        total += d                                       # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts FFNs)."""
        if not self.moe.enabled:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n_mlp_mats = 3 if self.mlp_variant == "swiglu" else 2
        inactive_ffn = (self.moe.num_experts - self.moe.top_k) * n_mlp_mats * d * self.d_ff
        return self.param_count() - L * inactive_ffn


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell. ``kind`` selects which step gets lowered."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh. ``shape`` and ``axes`` zip together."""
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class AionConfig:
    """Engine-level knobs for the paper's technique (§3)."""
    # block granularity of buckets (events per block; KV tokens per block)
    block_size: int = 512
    # m-bucket capacity in blocks per window / per session
    m_bucket_blocks: int = 64
    # standard-policy bootstrap fraction kept resident after destage
    rho_min: float = 0.05
    # predictive cleanup: cover this fraction of late events ...
    cleanup_coverage: float = 0.99
    # ... at this confidence (one-sided DKW band on the empirical CDF)
    cleanup_confidence: float = 0.95
    # staleness trigger
    max_staleness: float = 0.05
    trigger_max_iters: int = 512
    trigger_tol: float = 1e-4
    # global policy memory-pressure thresholds (fractions of HBM budget)
    pressure_moderate: float = 0.75
    pressure_severe: float = 0.90
    # watermark period (processing-time seconds) for periodic watermarks
    watermark_period: float = 1.0
    # batched multi-window execution (core/batch_exec.py): fold every due
    # window of one priority class in a single device pass when the
    # operator implements the batch contract; the per-window path remains
    # the reference and the fallback
    batched_execution: bool = True
    # slot-sharded multi-device batched fold: partition window slots of a
    # batch across a 1-D mesh of local devices (shard_map over the
    # composite (window_slot, key) segment axis, psum-free — slots are
    # disjoint). The executor round-robins due windows onto device-local
    # slot ranges and pads each shard to a common power-of-two row count.
    # Safe no-op on single-device hosts (falls back to the unsharded
    # batched path); requires batched_execution and a batch-contract
    # operator to take effect.
    slot_sharding: bool = False
    # how many local devices the slot mesh spans; 0 = every local device
    # (clamped to the number actually present)
    slot_shard_devices: int = 0
    # mesh axis name for the slot shard (only needs changing if an outer
    # mesh already uses 'slots')
    slot_shard_axis: str = "slots"
    # device-side row stacking for the batched gather: m-bucket rows that
    # are already device-resident are stacked with a device concat
    # (jnp.stack) instead of being pulled back to the host — the sharded
    # path never round-trips hot blocks through host memory. Cold
    # p-blocks still arrive via IOScheduler.fetch_block_host (accounted,
    # simulated-cost-charged). False restores the PR-1 host-side
    # np.stack + single contiguous device_put. Only reached when
    # ``block_pool`` is off (or as the pool's per-row fallback).
    device_stacking: bool = True
    # persistent device block pool (core/block_pool.py): staging writes
    # blocks INTO a preallocated [pool_slots, block_capacity(, W)] device
    # arena (a dynamic-update-slice at a pool slot) instead of a per-block
    # device_put, and the batched fold consumes a BLOCK TABLE of pool-slot
    # indices — the row gather becomes one take along the pool axis
    # (dense) / an in-kernel scalar-prefetch gather (Mosaic), with zero
    # per-batch copies for already-resident blocks. Safe fallback: pool
    # exhaustion degrades a block to the legacy device_put/stack path.
    block_pool: bool = True
    # arena capacity in blocks; rounded up to a multiple of the slot-shard
    # count, and clamped so the arena never exceeds the device budget
    pool_slots: int = 256
    # split-K chunked fold over the block table (flash-decoding part 2):
    # > 0 partitions a round's pooled rows into fixed-shape chunks of
    # this many rows, folds each chunk into its own partial accumulator,
    # and merges partials through the operator's merge identity. Launch
    # shapes then depend only on the chunk repertoire ({1,2,4,8} chunks
    # per launch), never the raw batch size — zero recompiles as batches
    # vary, and a Zipf-hot window's rows fold across chunk programs
    # instead of serializing one segment stripe. Under slot sharding the
    # executor instead deals rows round-robin across the mesh (balanced
    # split-K) when the operator supports it. 0 disables (one stripe per
    # window, pow2-bucketed shapes); auto-disabled for rounds smaller
    # than one chunk per device.
    splitk_chunk_rows: int = 0
    # overlap demand pool-fills of cold p-blocks with the fold of the
    # already-resident shard: the executor issues PRIO_DEMAND_STAGE fills,
    # folds the resident block table while the I/O thread stages, then
    # folds the newly-filled slots and merges the accumulators. False
    # restores the PR-3 behaviour (cold p-blocks read host-side).
    pool_overlap_prefetch: bool = True
    # persistent tier of the p-bucket (repro.storage): 'log' is the
    # log-structured store — segmented append-only value log, per-record
    # checksums, WAL group commit (a crash loses nothing acknowledged),
    # index rebuilt from segment footers on open, batched/readahead
    # reads, and cleanup-driven compaction that consumes purge
    # tombstones. 'npz' is the legacy file-per-block fallback (eager
    # unlink on purge, no batching) kept for ablations.
    store_backend: str = "log"
    # value-log segment size; sealed segments carry an index footer and
    # become compaction victims
    store_segment_bytes: int = 1 << 20
    # compaction bound: background compaction keeps on-disk bytes <=
    # max(ratio x live record bytes, one segment) — the paper's §3.4
    # "storage consumption stays bounded" claim, enforced
    store_compact_ratio: float = 2.0
    # store read-cache budget for batched readahead sweeps
    store_readahead_bytes: int = 16 << 20
    # pipelined asynchronous execution (core/pipeline.py): watermark
    # advances and due re-executions SUBMIT fold rounds to a dedicated
    # worker instead of folding inline, so ingestion/staging overlap the
    # previous round's fold and emission is futures-based
    # (StreamEngine.result_futures resolve when the round's device work
    # completes). Requires batched_execution + a batch-contract operator;
    # otherwise the synchronous loop is kept.
    pipelined_execution: bool = False
    # pipelined staging lookahead: submitting a round while another is
    # in flight immediately queues PRIO_STAGE pool fills for the new
    # round's cold blocks, so its I/O runs while the current round folds
    # (staging stays continuously in flight instead of fenced per round)
    pipeline_prefetch: bool = True
    # per-pool-slot epoch/sequence scheme (carried from PR 4's open
    # items): under the pipelined executor, arena pins shrink to the
    # snapshot->dispatch window and rows are validated by (slot, epoch)
    # instead of holding the pin across the whole round — ingest-time
    # fills that land mid-round donate in place (O(block)) rather than
    # taking the functional copy path. Rows whose slot epoch moved
    # between classification and dispatch demote to the stacked fallback.
    pool_slot_epochs: bool = True
    # bound on the engine's per-poll metrics series (batch occupancy,
    # device/host byte samples): each series keeps at most this many
    # recent entries (oldest half is shed when the cap is hit, so appends
    # stay amortized O(1)). 0 disables the bound (the pre-PR-6 leak).
    metrics_series_max: int = 4096
    # ---- learned prefetch subsystem (repro/prefetch, ROADMAP item 3) --
    # 'fixed' keeps the paper's fixed-margin proactive caching (whole
    # windows, one EWMA Δt lead) — the differential-testing baseline;
    # 'learned' swaps in the lateness-model-driven, segment-granular
    # readahead planner (per-key-class empirical-CDF re-execution
    # probabilities, per-segment sequential sweeps priced against a
    # bandwidth/slack cost model, coalescing rewrites of scattered hot
    # windows)
    prefetch_backend: str = "fixed"
    # readahead planning horizon in event-time seconds (how far past the
    # staging margin the planner looks for prefetch-worthy windows);
    # 0 = auto (4x the pre-stage margin)
    prefetch_horizon: float = 0.0
    # prior store bandwidth for the sweep cost model until measured
    # sweeps take over (EWMA)
    prefetch_bandwidth_bytes_per_s: float = 64e6
    # per-drive cap on issued sweep bytes; 0 = the store read-cache
    # budget (issuing more than the cache holds evicts our own work)
    prefetch_budget_bytes: int = 0
    # windows whose predicted re-execution probability falls below this
    # are not swept (their keys went quiet; re-evaluated every drive)
    prefetch_min_probability: float = 0.05
    # number of key classes the lateness model fits separate CDFs for
    prefetch_key_classes: int = 8
    # coalescing rewrites: scattered windows predicted to re-execute
    # (probability >= the threshold) are rewritten into one contiguous
    # run, once, so the re-stage becomes a single dense sweep
    prefetch_coalesce: bool = True
    prefetch_coalesce_probability: float = 0.25
    # WAL commit coalescing: spill batches and late-write tasks share
    # one group commit (fsync) via a deferred flush task instead of
    # each paying their own
    wal_coalesce_commits: bool = True
    # ---- self-healing I/O path (ISSUE 9) -----------------------------
    # transient store failures (OSError/timeouts — see
    # storage.is_transient_error) retry up to this many times with
    # exponential backoff + jitter before surfacing; permanent failures
    # surface immediately. 0 disables retries (PR-6 behaviour).
    io_retry_limit: int = 4
    # base backoff delay in seconds; attempt k sleeps
    # io_retry_backoff * 2^k * jitter, jitter uniform in [0.5, 1.5)
    io_retry_backoff: float = 0.01
    # circuit breaker on store health: when one engine poll tick sees at
    # least this many new I/O errors + retries, the degradation ladder
    # escalates one rung (shed readahead -> shed pipelined prefetch ->
    # demote pipelined rounds to sync -> ingest backpressure); after
    # breaker_cooldown_ticks consecutive clean ticks it steps back down.
    # 0 disables the ladder entirely.
    breaker_error_threshold: int = 8
    breaker_cooldown_ticks: int = 2
    # ladder rung 4: ingest() defers incoming batches to a bounded queue
    # (reporting the deferred count) instead of admitting them while the
    # breaker is fully open; deferred batches re-admit on later polls
    # and are always flushed by checkpoint/close — no event is dropped
    ingest_backpressure: bool = True
    # failed pipelined fold rounds retry once through
    # distributed.fault.BackupExecutor (folds are pure functions of
    # bucket contents, so the retry is idempotent) before the failure
    # poisons the pipeline
    fold_round_retry: bool = True
    # ---- observability layer (ISSUE 10) ------------------------------
    # fraction of root spans (ingest / watermark_advance / poll) that
    # are traced; children (fold rounds, I/O tasks) inherit the parent's
    # decision. 0.0 keeps tracing entirely off the hot path (every span
    # is the shared no-op NULL_SPAN); 1.0 traces everything and must
    # stay under 5% fold-throughput overhead (see `make bench-obs`)
    trace_sample_rate: float = 0.0
    # finished spans are kept in a bounded ring buffer of this many
    # records; oldest are dropped (counted in tracer stats)
    trace_ring_max: int = 4096
    # default format for engine.observability(export=...): "json" or
    # "prometheus"
    metrics_export: str = "json"
    # wrap fold launches in jax.profiler.TraceAnnotation so device
    # traces line up with engine spans (no-op if the profiler is
    # unavailable)
    profiler_annotations: bool = False
    # cap on StoreHealth.transitions / EngineMetrics.ladder_transitions
    # (BoundedSeries; sheds oldest half at the cap)
    health_transitions_max: int = 4096


def to_json(cfg: Any) -> str:
    return json.dumps(dataclasses.asdict(cfg), indent=2, default=str)
