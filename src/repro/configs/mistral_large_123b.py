"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family=FAMILY_DENSE,
    num_layers=88,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=32_768,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
