"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per-expert) vocab=151936,
MoE 128e top-8. head_dim=128 per the model card (q/k project above d_model).
"""
from repro.configs.base import ModelConfig, MoEConfig, FAMILY_MOE

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family=FAMILY_MOE,
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=8),
    source="hf:Qwen/Qwen3-30B-A3B",
)
