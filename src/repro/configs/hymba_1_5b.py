"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention heads use a sliding window (Hymba uses SWA in all but 3 layers;
we use SWA uniformly), making long_500k decode sub-quadratic.
"""
from repro.configs.base import ModelConfig, SSMConfig, FAMILY_HYBRID

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=FAMILY_HYBRID,
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    ssm=SSMConfig(state_size=16, head_dim=64, expand=2, chunk_size=256),
    attn_window=1024,
    source="arXiv:2411.13676",
)
