"""command-r-35b — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="command-r-35b",
    family=FAMILY_DENSE,
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_528,
    vocab_size=256_000,
    tie_embeddings=True,         # command-r ties input/output embeddings
    source="hf:CohereForAI/c4ai-command-r-v01",
)
