"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596].

12L d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096 vocab=256206. The audio
frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings consumed by the encoder.
"""
from repro.configs.base import ModelConfig, FAMILY_AUDIO

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family=FAMILY_AUDIO,
    num_layers=12,               # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    frontend_tokens=1024,        # precomputed audio frame embeddings (stub)
    source="arXiv:2308.11596",
)
