"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324].

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="granite-34b",
    family=FAMILY_DENSE,
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    mlp_variant="gelu",          # GPTBigCode-style 2-matrix MLP
    source="arXiv:2405.04324",
)
