"""starcoder2-7b — GQA, RoPE [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family=FAMILY_DENSE,
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18_432,
    vocab_size=49_152,
    mlp_variant="gelu",          # starcoder2 uses a 2-matrix GELU MLP
    use_bias=True,               # starcoder2 keeps biases
    source="arXiv:2402.19173",
)
