"""internvl2-76b — InternViT + InternLM2 backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The InternViT
frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (``frontend_tokens`` positions of d_model).
"""
from repro.configs.base import ModelConfig, FAMILY_VLM

CONFIG = ModelConfig(
    name="internvl2-76b",
    family=FAMILY_VLM,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    frontend_tokens=256,         # one image tile = 256 patch embeddings
    source="arXiv:2404.16821",
)
