"""The paper's own evaluation workloads (Table 1) as engine configs.

Four scenarios: two micro-benchmarks (*average*, *bigrams*) and two
applications (*stock market*, *LRB*). Parameters follow Table 1 verbatim;
payload bytes become the event value width so memory pressure is comparable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class WorkloadConfig:
    name: str
    max_ingestion_rate: int      # events/s (Table 1)
    window_duration: float       # seconds (Table 1)
    payload_bytes: int           # Table 1
    # which windowed operator the engine runs
    operator: str                # 'average' | 'bigrams' | 'stock' | 'lrb'
    # value width in float32 lanes derived from payload size
    value_width: int = 0
    blocking: bool = False       # §3.3: blocking ops need full window resident
    num_keys: int = 64           # key cardinality (stocks / road segments)

    def resolved_value_width(self) -> int:
        if self.value_width:
            return self.value_width
        return max(self.payload_bytes // 4, 1)


AVERAGE = WorkloadConfig(
    name="average", max_ingestion_rate=10_000, window_duration=20.0,
    payload_bytes=2304, operator="average", num_keys=1,
)
BIGRAMS = WorkloadConfig(
    name="bigrams", max_ingestion_rate=5_000, window_duration=30.0,
    payload_bytes=3584, operator="bigrams", num_keys=1,
)
STOCK_MARKET = WorkloadConfig(
    name="stock_market", max_ingestion_rate=10_000, window_duration=30.0,
    payload_bytes=1664, operator="stock", num_keys=128,
)
LRB = WorkloadConfig(
    name="lrb", max_ingestion_rate=10_000, window_duration=60.0,
    payload_bytes=1536, operator="lrb", num_keys=256,
)

WORKLOADS = {w.name: w for w in (AVERAGE, BIGRAMS, STOCK_MARKET, LRB)}


def get_workload(name: str) -> WorkloadConfig:
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; known: {sorted(WORKLOADS)}")
    return WORKLOADS[name]


# --------------------------------------------------------------- tenancy
@dataclass(frozen=True)
class TenantProfile:
    """Declarative description of one tenant stream for the multiplexed
    engine (``core.pipeline.MultiTenantEngine.from_profiles``).

    The ten profiles below map the repo's ten model-shaped serving
    configs (``configs/<model>.py``) onto the paper's workloads: each
    profile is "the event-time telemetry stream of one served model".
    ``weight`` is the tenant's I/O fairness weight — the transfer
    executor serves ``weight`` consecutive tasks per tenant within a
    priority class before its round-robin cursor advances — and the
    budget fractions slice the shared device/host totals. Bigger models
    get larger weights and budget slices (costlier per-event serving,
    more telemetry volume); the fractions sum to ~1.0 so the shared
    budget is fully partitioned.
    """
    name: str
    workload: WorkloadConfig
    weight: int = 1
    device_budget_frac: float = 0.10
    host_budget_frac: float = 0.10


TENANT_PROFILES: Tuple[TenantProfile, ...] = (
    TenantProfile("mamba2_780m", AVERAGE, weight=1,
                  device_budget_frac=0.04, host_budget_frac=0.04),
    TenantProfile("hymba_1_5b", AVERAGE, weight=1,
                  device_budget_frac=0.05, host_budget_frac=0.05),
    TenantProfile("starcoder2_7b", BIGRAMS, weight=1,
                  device_budget_frac=0.07, host_budget_frac=0.07),
    TenantProfile("seamless_m4t_medium", BIGRAMS, weight=1,
                  device_budget_frac=0.06, host_budget_frac=0.06),
    TenantProfile("qwen3_moe_30b", STOCK_MARKET, weight=2,
                  device_budget_frac=0.09, host_budget_frac=0.09),
    TenantProfile("granite_34b", LRB, weight=2,
                  device_budget_frac=0.10, host_budget_frac=0.10),
    TenantProfile("command_r_35b", STOCK_MARKET, weight=2,
                  device_budget_frac=0.10, host_budget_frac=0.10),
    TenantProfile("phi35_moe_42b", LRB, weight=3,
                  device_budget_frac=0.12, host_budget_frac=0.12),
    TenantProfile("internvl2_76b", LRB, weight=3,
                  device_budget_frac=0.17, host_budget_frac=0.17),
    TenantProfile("mistral_large_123b", STOCK_MARKET, weight=4,
                  device_budget_frac=0.20, host_budget_frac=0.20),
)


def get_tenant_profile(name: str) -> TenantProfile:
    for p in TENANT_PROFILES:
        if p.name == name:
            return p
    raise KeyError(f"unknown tenant profile {name!r}; known: "
                   f"{[p.name for p in TENANT_PROFILES]}")
