"""The paper's own evaluation workloads (Table 1) as engine configs.

Four scenarios: two micro-benchmarks (*average*, *bigrams*) and two
applications (*stock market*, *LRB*). Parameters follow Table 1 verbatim;
payload bytes become the event value width so memory pressure is comparable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class WorkloadConfig:
    name: str
    max_ingestion_rate: int      # events/s (Table 1)
    window_duration: float       # seconds (Table 1)
    payload_bytes: int           # Table 1
    # which windowed operator the engine runs
    operator: str                # 'average' | 'bigrams' | 'stock' | 'lrb'
    # value width in float32 lanes derived from payload size
    value_width: int = 0
    blocking: bool = False       # §3.3: blocking ops need full window resident
    num_keys: int = 64           # key cardinality (stocks / road segments)

    def resolved_value_width(self) -> int:
        if self.value_width:
            return self.value_width
        return max(self.payload_bytes // 4, 1)


AVERAGE = WorkloadConfig(
    name="average", max_ingestion_rate=10_000, window_duration=20.0,
    payload_bytes=2304, operator="average", num_keys=1,
)
BIGRAMS = WorkloadConfig(
    name="bigrams", max_ingestion_rate=5_000, window_duration=30.0,
    payload_bytes=3584, operator="bigrams", num_keys=1,
)
STOCK_MARKET = WorkloadConfig(
    name="stock_market", max_ingestion_rate=10_000, window_duration=30.0,
    payload_bytes=1664, operator="stock", num_keys=128,
)
LRB = WorkloadConfig(
    name="lrb", max_ingestion_rate=10_000, window_duration=60.0,
    payload_bytes=1536, operator="lrb", num_keys=256,
)

WORKLOADS = {w.name: w for w in (AVERAGE, BIGRAMS, STOCK_MARKET, LRB)}


def get_workload(name: str) -> WorkloadConfig:
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; known: {sorted(WORKLOADS)}")
    return WORKLOADS[name]
