"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMConfig, FAMILY_SSM

CONFIG = ModelConfig(
    name="mamba2-780m",
    family=FAMILY_SSM,
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                      # attn-free mamba2 block has no separate FFN
    vocab_size=50_280,
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
