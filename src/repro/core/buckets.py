"""Two-tier bucket state (paper §3.1): m-bucket in device memory (HBM
analogue), p-bucket in host memory with spill to a persistent block
store.

TPU adaptation: Flink's per-record ListState becomes *block-granular*
state — events append into fixed-capacity SoA blocks; a window's state is
an ordered list of blocks, each resident in exactly one tier:

    DEVICE  (m-bucket)  — jax arrays, counted against an HBM budget
    HOST    (p-bucket)  — pinned numpy arrays
    STORAGE (p-bucket)  — a ``repro.storage`` BlockStore record
                          (log-structured value log, or the legacy
                          file-per-block .npz fallback)

Blocks move between tiers only through ``core.staging`` (the single
prioritized I/O executor), never synchronously inside operator execution —
that asynchrony is what lets proactive caching mask transfer latency.
"""
from __future__ import annotations

import enum
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.events import EventBatch


class Tier(enum.Enum):
    DEVICE = "device"
    HOST = "host"
    STORAGE = "storage"


class _BlockIdGen:
    """Monotonic block-id source. ``bump_to`` lets a checkpoint restore
    re-use the checkpointed ids (the store keys records by them) without
    colliding with ids handed to blocks created afterwards."""

    def __init__(self):
        self._n = 0
        self._lock = threading.Lock()

    def __next__(self) -> int:
        with self._lock:
            self._n += 1
            return self._n

    def bump_to(self, n: int) -> None:
        with self._lock:
            self._n = max(self._n, int(n))


_BLOCK_IDS = _BlockIdGen()


@dataclass
class Block:
    """Fixed-capacity SoA block. Exactly one of (host_data, device_data,
    storage_path) is the authoritative copy, per ``tier``.

    ``lock``/``dropped`` serialize the ownership handoff between the
    engine's predictive cleanup (main thread) and the staging executor
    (I/O thread): a stage that commits after the block was dropped must
    release its own budget reservation, and a drop that races a
    committed stage must report the device bytes so the engine releases
    them — otherwise reservations leak.

    With the persistent block pool (``AionConfig.block_pool``), a
    device-resident block holds a ``pool_slot`` into the arena instead of
    per-block ``device_data`` buffers; ``pool`` is the back-reference
    through which destage/drop surrender the slot (exactly once — the
    surrender happens under ``lock`` via ``pool.release_slot``).
    """
    capacity: int
    width: int
    block_id: int = field(default_factory=lambda: next(_BLOCK_IDS))
    fill: int = 0
    tier: Tier = Tier.HOST
    persisted: bool = False      # has touched the persistent tier (p-bucket)
    dropped: bool = False        # predictive cleanup freed this block
    host_data: Optional[Dict[str, np.ndarray]] = None
    device_data: Optional[Dict[str, object]] = None
    # legacy direct-file path (the npz backend mirrors its ref here so
    # file-per-block code and tests keep working)
    storage_path: Optional[Path] = None
    # persistent store holding this block's record, and the opaque ref
    # its ``put`` returned; the store indexes by (window_key, block_id)
    store: Optional[object] = field(default=None, repr=False, compare=False)
    storage_ref: Optional[object] = None
    window_key: Optional[Tuple[float, float]] = None
    pool_slot: Optional[int] = None    # arena slot while device-resident
    pool: Optional[object] = field(default=None, repr=False, compare=False)
    # host copy counted against IOScheduler's host tier (idempotent
    # accounting: staging keeps host copies, so destage/stage round-trips
    # must not re-count the same bytes)
    host_accounted: bool = False
    # membership flag for IOScheduler._host_lru: set when this block is
    # appended as a spill candidate, cleared when the spill loop pops it
    # — the failure unwind re-queues a block exactly once even when two
    # coalesced flushes over overlapping batches both fail
    in_spill_lru: bool = False
    lock: threading.Lock = field(default_factory=threading.Lock,
                                 repr=False, compare=False)

    @staticmethod
    def new(capacity: int, width: int) -> "Block":
        b = Block(capacity=capacity, width=width)
        b.host_data = {
            "keys": np.zeros((capacity,), np.int32),
            "timestamps": np.zeros((capacity,), np.float64),
            "values": np.zeros((capacity, width), np.float32),
        }
        return b

    @property
    def nbytes(self) -> int:
        per_event = 4 + 8 + 4 * self.width
        return self.capacity * per_event

    @property
    def full(self) -> bool:
        return self.fill >= self.capacity

    def append(self, batch: EventBatch, start: int) -> int:
        """Copy events from batch[start:] into free space; returns #taken.
        Only valid on HOST tier (ingest path writes host-side)."""
        assert self.tier == Tier.HOST and self.host_data is not None
        take = min(self.capacity - self.fill, len(batch) - start)
        if take <= 0:
            return 0
        sl = slice(self.fill, self.fill + take)
        self.host_data["keys"][sl] = batch.keys[start:start + take]
        self.host_data["timestamps"][sl] = batch.timestamps[start:start + take]
        self.host_data["values"][sl] = batch.values[start:start + take]
        self.fill += take
        return take

    def as_event_batch(self) -> EventBatch:
        """Host view of valid events (host or storage tier)."""
        if self.tier == Tier.STORAGE:
            self._load_from_storage()
        assert self.host_data is not None
        return EventBatch(self.host_data["keys"][:self.fill],
                          self.host_data["timestamps"][:self.fill],
                          self.host_data["values"][:self.fill])

    @property
    def in_storage(self) -> bool:
        """True when a persistent copy exists (store record or legacy
        direct file)."""
        return (self.store is not None and self.storage_ref is not None) \
            or self.storage_path is not None

    def _load_from_storage(self) -> None:
        if self.store is not None and self.storage_ref is not None:
            data = self.store.get(self.window_key, self.block_id)
            assert data is not None, \
                f"store record missing for block {self.block_id}"
            self.host_data = data
        else:
            assert self.storage_path is not None
            with np.load(self.storage_path) as z:
                self.host_data = {
                    k: z[k] for k in ("keys", "timestamps", "values")}
        self.tier = Tier.HOST

    def put_to_store(self, store) -> None:
        """Write this block's current content into ``store`` (skipping
        the write when the store already holds this exact fill — block
        content is append-only, so fill identifies it). Durable after the
        store's next group commit; the caller clears the host copy only
        after that commit. Caller holds ``lock``."""
        assert self.host_data is not None
        if not (self.store is store
                and store.current_fill(self.window_key,
                                       self.block_id) == self.fill):
            ref = store.put(self.window_key, self.block_id,
                            self.host_data, self.fill)
            self.store = store
            self.storage_ref = ref
            self.storage_path = ref if isinstance(ref, Path) else None

    def drop(self) -> int:
        """Free all copies (predictive cleanup). Returns the device bytes
        that were committed to the budget at drop time — the caller owns
        releasing them (an in-flight stage that commits later sees
        ``dropped`` and releases its own reservation instead)."""
        with self.lock:
            self.dropped = True
            # pooled blocks never held a per-block reservation (the
            # arena's bytes are charged once, at pool construction), so
            # only a legacy device_put block reports bytes to release
            device_bytes = self.nbytes if (
                self.tier == Tier.DEVICE and self.pool_slot is None) else 0
            self.host_data = None
            self.device_data = None
            if self.pool is not None:
                # surrender the arena slot exactly once (an in-flight
                # stage that commits after this sees ``dropped`` and
                # frees the slot it allocated instead)
                self.pool.release_slot(self)
            if self.store is not None and self.storage_ref is not None:
                # predictive cleanup's purge emits a TOMBSTONE; space
                # comes back through cleanup-driven compaction (the npz
                # backend's delete unlinks eagerly, preserving the
                # legacy behaviour)
                self.store.delete(self.window_key, self.block_id)
            elif self.storage_path is not None \
                    and self.storage_path.exists():
                os.unlink(self.storage_path)
            self.storage_ref = None
            self.storage_path = None
            return device_bytes


class MemoryBudget:
    """Byte accounting for the device (m-bucket) tier."""

    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self.used_bytes = 0
        self._lock = threading.Lock()
        self.peak_bytes = 0

    def try_reserve(self, n: int) -> bool:
        with self._lock:
            if self.used_bytes + n > self.capacity_bytes:
                return False
            self.used_bytes += n
            self.peak_bytes = max(self.peak_bytes, self.used_bytes)
            return True

    def release(self, n: int) -> None:
        with self._lock:
            self.used_bytes = max(self.used_bytes - n, 0)

    @property
    def utilization(self) -> float:
        return self.used_bytes / max(self.capacity_bytes, 1)


class TenantBudget(MemoryBudget):
    """A tenant's slice of a shared device budget.

    Reservations must clear BOTH limits: the tenant's own cap (fairness
    — one tenant cannot crowd the others out of the device) and the
    shared parent budget (physics — the device only has so many bytes).
    ``used_bytes``/``utilization`` report the tenant's own usage, which
    is what per-tenant memory policies (GlobalMemoryPolicy thresholds)
    should react to."""

    def __init__(self, parent: MemoryBudget, capacity_bytes: int):
        super().__init__(capacity_bytes)
        self.parent = parent

    def try_reserve(self, n: int) -> bool:
        if not super().try_reserve(n):
            return False
        if not self.parent.try_reserve(n):
            super().release(n)
            return False
        return True

    def release(self, n: int) -> None:
        # release no more from the parent than this tenant actually
        # holds (MemoryBudget.release floors at 0 locally; the parent
        # must see the same clamped amount or shared bytes would leak
        # back twice)
        with self._lock:
            freed = min(self.used_bytes, max(int(n), 0))
            self.used_bytes -= freed
        if freed:
            self.parent.release(freed)


@dataclass
class WindowState:
    """State of one window: ordered blocks split across tiers (Figure 1).

    ``m_blocks``/``p_blocks`` partition ``blocks`` by tier; order inside
    ``blocks`` is append order (event order within a block is arrival
    order, which event-time operators re-sort as needed)."""
    window_start: float
    window_end: float
    width: int
    block_capacity: int
    blocks: List[Block] = field(default_factory=list)
    total_events: int = 0
    late_events: int = 0
    expired: bool = False          # watermark passed window end
    rho_min_blocks: int = 0        # bootstrap set size (policy §3.2)
    last_executed_at: float = -np.inf
    events_at_last_exec: int = 0
    result: Optional[object] = None

    def m_blocks(self) -> List[Block]:
        return [b for b in self.blocks if b.tier == Tier.DEVICE]

    def p_blocks(self) -> List[Block]:
        return [b for b in self.blocks if b.tier != Tier.DEVICE]

    def device_bytes(self) -> int:
        return sum(b.nbytes for b in self.m_blocks())

    def host_bytes(self) -> int:
        return sum(b.nbytes for b in self.blocks if b.tier == Tier.HOST)

    def append_events(self, batch: EventBatch, late: bool) -> List[Block]:
        """Append host-side; returns blocks newly created. Tier placement
        (device vs host) is decided by the policy/staging layer."""
        new_blocks: List[Block] = []
        start = 0
        # fill the last block if it has room and is host-resident
        if self.blocks and not self.blocks[-1].full \
                and self.blocks[-1].tier == Tier.HOST:
            start += self.blocks[-1].append(batch, start)
        while start < len(batch):
            blk = Block.new(self.block_capacity, self.width)
            blk.window_key = (self.window_start, self.window_end)
            taken = blk.append(batch, start)
            start += taken
            self.blocks.append(blk)
            new_blocks.append(blk)
        self.total_events += len(batch)
        if late:
            self.late_events += len(batch)
        return new_blocks

    def events_since_last_exec(self) -> int:
        return self.total_events - self.events_at_last_exec

    def drop_all(self) -> Tuple[int, int]:
        """Predictive cleanup: free every copy. Returns (total bytes
        freed, device bytes the caller must release from the budget)."""
        freed = sum(b.nbytes for b in self.blocks)
        device_bytes = sum(b.drop() for b in self.blocks)
        self.blocks.clear()
        return freed, device_bytes
