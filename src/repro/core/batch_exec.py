"""Batched multi-window execution: one device pass per poll/watermark.

Paper §3 orders work by a strict priority rule — live window executions
first, then late re-executions, with demand staging outranking speculative
pre-staging. The per-window reference path (``StreamEngine.
execute_window``) honors that rule one window at a time, paying a jit
dispatch per block per window; with many concurrent due windows (long
lateness horizons keep many past windows re-executing) the dispatch
overhead — not the fold FLOPs — dominates.

This module keeps the priority rule but batches *within* a priority
class: each ``advance_watermark`` gathers every newly-expired window into
one live batch, and each ``poll`` gathers every due late re-execution
into one late batch — live batches always run before late batches because
the engine calls them in that order, so the rule is preserved at batch
granularity. Re-execution stays a pure function of bucket contents, so
folding N windows in one pass is bitwise-equivalent to N independent
folds up to float associativity (parity-tested in
``tests/test_batch_exec.py`` and ``tests/test_slot_sharding.py``).

Row gathering — the **block-table path** (``AionConfig.block_pool``,
default on): blocks staged by ``core.staging`` live in a persistent
device arena (``core.block_pool``), so a batch over already-resident
blocks is assembled as a *table* of pool-slot indices — O(rows) Python
ints — and the operator's ``fold_batch(..., table=)`` gathers the event
tiles straight from the arena (an in-kernel scalar-prefetch DMA on the
Mosaic backend, one take along the pool axis on the dense backend):
**zero per-batch copies**. Cold p-blocks are demand-staged INTO the pool
at ``PRIO_DEMAND_STAGE`` and that I/O **overlaps** the fold of the
already-resident shard (``pool_overlap_prefetch``): the executor
dispatches the resident block table, waits for the fills, folds the
newly-filled slots as a second table, and merges the partial accumulators
(``WindowOperator.merge_acc``). Blocks that could not be pooled (slot or
budget exhaustion, overlap off) degrade to the legacy stacked gather.

The legacy **stacked path** (``block_pool=False``, and the pooled path's
per-row fallback) re-materializes each batch: m-bucket rows that already
live on the device are stacked with a device concat (``jnp.stack`` —
``AionConfig.device_stacking``; False restores the PR-1 host ``np.stack``
+ one ``device_put``) and cold p-blocks are read host-side through
``IOScheduler.fetch_block_host`` (accounted, simulated-cost-charged).

Multi-device slot sharding (``AionConfig.slot_sharding``): the unpooled
placement round-robins due windows onto device-local slot ranges and
packs rows shard-major padded to a common power-of-two count; the fold
runs under a psum-free ``shard_map`` over the slot axis. The POOLED
placement is hash-based instead (``distributed.sharding.shard_of_window``
— the same map the staging shard hint uses), because pool slots are
assigned at STAGING time, before any batch composition is known: placing
a window on its hash shard is what keeps its block-table rows local to
the device whose arena tile holds them. Rows whose pool slot lands
outside their window's shard (stale placement, cross-range restores) fall
back to the stacked gather rather than being misfolded.

Split-K chunk planning (``AionConfig.splitk_chunk_rows > 0``, operators
with ``supports_splitk``): instead of one stripe per window padded to the
next power of two, a round's pooled rows pad to a multiple of the chunk
size and decompose greedily into launch groups of {8, 4, 2, 1} chunks
(``_plan_table_groups``); each group folds through the split-K kernel
(fixed-shape per-chunk partials, merged on-device) and the cross-group
partial accumulators merge via ``WindowOperator.merge_acc``. Every launch
shape is drawn from a fixed repertoire of at most four, so batch-size
changes across rounds never recompile — the stripe path re-jits at every
new pow2 bucket. Under slot sharding the STACKED fold instead deals rows
round-robin across the mesh (``pack_rows_shard_major(balance=True)``) and
folds full per-slot partials per device — a skewed window's rows spread
over every device instead of serializing on its owner.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import Tier, WindowState
from repro.core.windows import WindowId
from repro.kernels.segment_aggregate import (
    next_pow2, pack_rows_shard_major,
)
from repro.obs import profiler_annotation


# largest split-K launch group, in chunks: greedy pow2 decomposition of a
# round's chunk count into groups of {8, 4, 2, 1} chunks caps the shape
# repertoire at four launch shapes total (e.g. 13 chunks -> 8 + 4 + 1)
_SPLITK_MAX_CHUNKS = 8


@dataclass
class BatchWorkItem:
    """One due window execution (live expiry or late re-execution)."""
    wid: WindowId
    state: WindowState
    late: bool


def snapshot_block_partition(state: WindowState):
    """Atomic (m, p) partition of a window's blocks.

    Shared by the per-window and batched execution paths — the
    double-fold hazard lives here: snapshot BOTH lists before issuing any
    staging request, otherwise the I/O thread can move a block
    device-side between the two snapshots and it would be folded twice.
    """
    m_snapshot = state.m_blocks()
    m_ids = {id(b) for b in m_snapshot}
    p_blocks = [b for b in state.blocks if id(b) not in m_ids]
    return m_snapshot, p_blocks


def plan_slot_placement(num_windows: int, num_devices: int
                        ) -> Tuple[List[int], int, int]:
    """Round-robin due windows onto device-local slot ranges.

    Device ``d`` owns the contiguous global slot range
    ``[d*slots_per, (d+1)*slots_per)``; window ``i`` of the batch lands on
    device ``i % num_devices`` at local slot ``i // num_devices``.
    ``slots_per`` is padded to a power of two so the jitted fold sees
    O(log) distinct shapes. Returns ``(slot_of_window, num_slots,
    slots_per)``; ``num_devices <= 1`` degenerates to the unsharded
    identity placement.
    """
    if num_devices <= 1:
        ns = next_pow2(num_windows)
        return list(range(num_windows)), ns, ns
    slots_per = next_pow2(-(-num_windows // num_devices))
    slot_of = [(i % num_devices) * slots_per + i // num_devices
               for i in range(num_windows)]
    return slot_of, num_devices * slots_per, slots_per


def plan_slot_placement_pooled(wids: List[WindowId], num_devices: int
                               ) -> Tuple[List[int], int, int]:
    """Hash-based placement for the pooled sharded fold.

    A window's pool slots were allocated at staging time in the arena
    range of ``shard_of_window(...)`` — placement must agree with that
    map or every block-table row would be misplaced. Windows group by
    their hash shard; each shard's windows take consecutive local slots,
    padded to a common power-of-two ``slots_per``. Degenerates to the
    identity placement on one device.
    """
    if num_devices <= 1:
        return plan_slot_placement(len(wids), 1)
    from repro.distributed.sharding import shard_of_window
    shards = [shard_of_window(w.start, w.end, num_devices) for w in wids]
    counts = [0] * num_devices
    local = []
    for s in shards:
        local.append(counts[s])
        counts[s] += 1
    slots_per = next_pow2(max(counts + [1]))
    slot_of = [s * slots_per + l for s, l in zip(shards, local)]
    return slot_of, num_devices * slots_per, slots_per


class BatchExecutor:
    """Executes a set of due windows in one vectorized device pass."""

    def __init__(self, engine):
        self.engine = engine
        self._mesh = None
        self._mesh_resolved = False

    # ---------------------------------------------------------- slot mesh
    def _slot_mesh(self):
        """The 1-D slot mesh, or None (sharding off / single device)."""
        if self._mesh_resolved:
            return self._mesh
        self._mesh_resolved = True
        aion = self.engine.aion
        if getattr(aion, "slot_sharding", False):
            from repro.distributed.sharding import make_slot_mesh
            self._mesh = make_slot_mesh(aion.slot_shard_devices,
                                        aion.slot_shard_axis)
        return self._mesh

    @staticmethod
    def _stack(rows: List[Any], device: bool, dtype) -> Any:
        """Stack per-block rows into one [rows, ...] tensor.

        ``device=True``: a device concat — already-resident jax rows are
        consumed in place and host rows are transferred individually, so
        hot m-bucket blocks never round-trip through the host.
        ``device=False``: the PR-1 host stack (one contiguous device_put
        inside the jitted fold).
        """
        if device:
            return jnp.stack([r if isinstance(r, jax.Array)
                              else jnp.asarray(r) for r in rows])
        return np.stack([np.asarray(r, dtype) for r in rows])

    # ------------------------------------------------------------ execute
    def execute(self, items: List[BatchWorkItem], now: float,
                trace_parent=None) -> Dict[WindowId, Any]:
        """Fold all items in one device pass; returns results by window.

        Falls back to the per-window reference path when the operator has
        no batch contract or the batch is trivial (a single window gains
        nothing from stacking). An empty item list is a no-op — no
        degenerate [0, ...] tensors, no metrics.

        ``trace_parent`` is the submitting span (watermark advance, poll
        sweep or pipeline round) handed across threads EXPLICITLY — the
        fold-round span it parents carries launch-group/split-K counts
        and whether this round recompiled.
        """
        eng = self.engine
        op = eng.operator
        if not items:
            return {}
        if not op.supports_batch or len(items) == 1:
            return {it.wid: eng.execute_window(it.wid, now, it.late)
                    for it in items}

        span = eng.tracer.child(
            trace_parent, "fold_round", windows=len(items),
            late=sum(1 for it in items if it.late))
        # pre-round registry reads for per-round span deltas (only when
        # this round is actually sampled — the disabled path stays free)
        cache_fn = getattr(getattr(op, "fold_batch", None),
                           "_cache_size", None)
        cache0 = sk0 = pooled0 = fallback0 = demoted0 = 0
        if span.sampled:
            cache0 = cache_fn() if callable(cache_fn) else 0
            sk0 = eng.metrics.splitk_launches
            pooled0 = eng.metrics.pooled_rows
            fallback0 = eng.metrics.fallback_rows
            demoted0 = eng.metrics.epoch_demoted_rows

        with span:
            t0 = _time.time()

            # 1. snapshot every window atomically (membership is fixed
            #    from here on: each block folds exactly once, whatever
            #    tier it moves to while the batch assembles)
            plans = [(it, sum(snapshot_block_partition(it.state), []))
                     for it in items]

            mesh = self._slot_mesh()
            num_devices = mesh.size if mesh is not None else 1

            with profiler_annotation(
                    f"aion.fold_round[{len(items)}]",
                    enabled=getattr(eng.aion, "profiler_annotations",
                                    False)):
                if eng.pool is not None:
                    results, slot_of, num_slots, dev_dt, gather_dt, \
                        ran_sharded = self._fold_pooled(plans, mesh,
                                                        num_devices)
                else:
                    results, slot_of, num_slots, dev_dt, gather_dt, \
                        ran_sharded = self._fold_stacked(plans, mesh,
                                                         num_devices)

            # per-window bookkeeping, identical to execute_window
            out: Dict[WindowId, Any] = {}
            for i, (it, _) in enumerate(plans):
                result = results[slot_of[i]]
                it.state.result = result
                eng.results[it.wid] = result
                it.state.last_executed_at = now
                it.state.events_at_last_exec = it.state.total_events
                if it.late:
                    eng.metrics.late_executions += 1
                else:
                    eng.metrics.live_executions += 1
                out[it.wid] = result
                eng._post_execute_destage(it.wid, it.state, now)
            eng.metrics.exec_seconds += _time.time() - t0
            eng.metrics.batch_executions += 1
            eng.metrics.batched_windows += len(plans)
            eng.metrics.batch_device_seconds += dev_dt
            eng.metrics.batch_gather_seconds += gather_dt
            eng.metrics.batch_occupancy_series.append(len(plans))
            eng.metrics.fold_seconds.observe(dev_dt)
            if ran_sharded:
                eng.metrics.sharded_batch_executions += 1
            if span.sampled:
                cache1 = cache_fn() if callable(cache_fn) else 0
                span.set(
                    splitk_launches=eng.metrics.splitk_launches - sk0,
                    pooled_rows=eng.metrics.pooled_rows - pooled0,
                    fallback_rows=eng.metrics.fallback_rows - fallback0,
                    epoch_demoted_rows=(
                        eng.metrics.epoch_demoted_rows - demoted0),
                    recompiled=bool(cache1 > cache0),
                    sharded=ran_sharded,
                    device_seconds=round(dev_dt, 6),
                    gather_seconds=round(gather_dt, 6))
                span.event("emit", results=len(out))
        return out

    # ------------------------------------------------------ splitk planning
    def _splitk_chunk(self, num_rows: int, num_devices: int) -> int:
        """Effective split-K chunk size for a round of ``num_rows`` rows,
        or 0 when disabled: the knob is off, the operator's accumulator
        cannot merge arbitrary row partials (``supports_splitk`` False),
        or the round is smaller than one chunk per device (chunking a
        sub-chunk round would only add merge overhead)."""
        op = self.engine.operator
        chunk = getattr(self.engine.aion, "splitk_chunk_rows", 0)
        if chunk <= 0 or not getattr(op, "supports_splitk", False):
            return 0
        if num_rows <= chunk * max(num_devices, 1):
            return 0
        return chunk

    def _plan_table_groups(self, rows, num_devices: int, slots_per: int):
        """Launch groups ``[(table, fills, slots, splitk)]`` for pooled
        (block, window_slot, pool_slot) rows.

        Split-K disabled (or sharded — the sharded layout keeps the
        ownership packing and chunks per shard inside the kernel): one
        legacy pow2-padded group. Single-device split-K: rows pad to a
        chunk multiple (pool slot 0, fill 0 — invalid everywhere,
        including the ±inf min/max identities) and the chunk count
        decomposes greedily into groups of {8, 4, 2, 1} chunks, so every
        launch shape is one of at most four ``{1,2,4,8} * chunk_rows``
        shapes regardless of batch size — zero recompiles as rounds vary,
        where the stripe path re-jits per pow2 bucket. Cross-group
        partials merge via ``op.merge_acc`` in the shared tail."""
        chunk = self._splitk_chunk(len(rows), num_devices)
        if chunk == 0 or num_devices > 1:
            tbl, fills, slots = self._pack_table(rows, num_devices,
                                                 slots_per)
            return [(tbl, fills, slots, chunk)]
        table = [ps for _, _, ps in rows]
        fills = [blk.fill for blk, _, _ in rows]
        slots = [ws for _, ws, _ in rows]
        for _ in range((-len(rows)) % chunk):
            table.append(0)
            fills.append(0)
            slots.append(0)
        groups = []
        off = 0
        remaining = len(table) // chunk
        while remaining:
            g = min(_SPLITK_MAX_CHUNKS, 1 << (remaining.bit_length() - 1))
            n = g * chunk
            groups.append((jnp.asarray(table[off:off + n], jnp.int32),
                           jnp.asarray(fills[off:off + n], jnp.int32),
                           jnp.asarray(slots[off:off + n], jnp.int32),
                           chunk))
            off += n
            remaining -= g
        return groups

    def _fold_table_groups(self, groups, arena_data, num_slots, use_mesh,
                           accs):
        """Dispatch every launch group against one arena snapshot; the
        group accumulators append to ``accs`` (merged in the shared
        tail). Returns the device seconds spent."""
        eng = self.engine
        op = eng.operator
        d0 = _time.time()
        for table, fills, slots, sk in groups:
            accs.append(op.fold_batch(arena_data, fills, slots, num_slots,
                                      mesh=use_mesh, table=table,
                                      splitk=sk))
            if sk:
                eng.metrics.splitk_launches += 1
        return _time.time() - d0

    def _stack_rows(self, rows, num_devices: int, slots_per: int,
                    balance: bool = False):
        """Stacked (data, fills, slots) tensors from (arrays, fill,
        window_slot) rows.

        Shard-major via the same packing helper the parity tests drive:
        rows group by owning shard and every shard pads to a common
        power-of-two row count (invalid rows: fill 0, slot = shard's
        base slot) so row counts divide the mesh and the jitted fold
        sees O(log) distinct shapes. ``num_devices == 1`` degenerates to
        the PR-1 layout (one group, rows padded to pow2). ``balance``
        deals rows round-robin across shards instead (the split-K
        layout): callers must fold through the row-balanced kernel,
        which has no ownership precondition; padding rows take slot 0
        with fill 0 — invalid everywhere. The stack carries keys +
        values only: no batch fold is time-dependent within a window,
        and stacking timestamps would force a D2H pull of every hot
        device-resident row (f64 on host, f32 on device — see the
        fold_batch contract).
        """
        eng = self.engine
        cap = eng.aion.block_size
        w = eng.value_width
        per_shard, rows_per_shard = pack_rows_shard_major(
            [slot for _, _, slot in rows], num_devices, slots_per,
            balance=balance)
        pad_arrs = {
            "keys": np.zeros((cap,), np.int32),
            "values": np.zeros((cap, w), np.float32),
        }
        keys_rows, val_rows = [], []
        fills: List[int] = []
        slots: List[int] = []
        for d, idxs in enumerate(per_shard):
            base_slot = d * slots_per \
                if num_devices > 1 and not balance else 0
            for r in idxs:
                arrs, fill, slot = rows[r]
                keys_rows.append(arrs["keys"])
                val_rows.append(arrs["values"])
                fills.append(fill)
                slots.append(slot)
            for _ in range(rows_per_shard - len(idxs)):
                keys_rows.append(pad_arrs["keys"])
                val_rows.append(pad_arrs["values"])
                fills.append(0)
                slots.append(base_slot)
        device = getattr(eng.aion, "device_stacking", True)
        data = {
            "keys": self._stack(keys_rows, device, np.int32),
            "values": self._stack(val_rows, device, np.float32),
        }
        return (data, jnp.asarray(fills, jnp.int32),
                jnp.asarray(slots, jnp.int32))

    # ----------------------------------------------------- stacked gather
    def _fold_stacked(self, plans, mesh, num_devices):
        """Legacy gather: re-materialize the batch as stacked tensors
        (device concat of resident rows; host reads of cold p-blocks).

        With split-K on under a mesh (operator permitting), the layout
        switches to **row-balanced**: identity slot placement (no per-
        device slot inflation), rows dealt round-robin across devices,
        and the fold runs the balanced sharded kernel — full per-slot
        partials per device, merged after the shard_map — so a skewed
        window's rows never serialize on one device."""
        eng = self.engine
        op = eng.operator
        chunk = getattr(eng.aion, "splitk_chunk_rows", 0)
        balanced = num_devices > 1 and chunk > 0 \
            and getattr(op, "supports_splitk", False)
        if balanced:
            slot_of, num_slots, slots_per = plan_slot_placement(
                len(plans), 1)
        else:
            slot_of, num_slots, slots_per = plan_slot_placement(
                len(plans), num_devices)

        # gather block rows: (arrays, fill, slot) in plan order — with
        # one batched store readahead so cold p-blocks arrive via a
        # sequential segment sweep instead of per-block random reads
        g0 = _time.time()
        eng.io.readahead_blocks(
            [blk for _, blocks in plans for blk in blocks])
        rows: List[Tuple[Dict[str, Any], int, int]] = []
        for i, (it, blocks) in enumerate(plans):
            for blk in blocks:
                if blk.fill == 0:
                    continue
                arrs = eng.io.fetch_block_arrays(blk)
                if arrs is None:         # purged mid-gather
                    continue
                rows.append((arrs, blk.fill, slot_of[i]))

        ran_sharded = False
        dev_dt = 0.0
        if rows:
            data, fills, slots = self._stack_rows(rows, num_devices,
                                                  slots_per,
                                                  balance=balanced)
            gather_dt = _time.time() - g0
            dev_t0 = _time.time()
            results = op.run_batch(data, fills, slots, num_slots,
                                   mesh=mesh,
                                   splitk=chunk if balanced else 0)
            dev_dt = _time.time() - dev_t0
            ran_sharded = mesh is not None
            if balanced:
                eng.metrics.splitk_launches += 1
        else:
            gather_dt = _time.time() - g0
            # every window empty: finalize the identity accumulator
            results = [op.finalize(op.init_acc()) for _ in range(num_slots)]
        return results, slot_of, num_slots, dev_dt, gather_dt, ran_sharded

    # ------------------------------------------------------- pooled gather
    def _pack_table(self, rows, num_devices: int, slots_per: int):
        """Shard-major (table, fills, slots) arrays from (block,
        window_slot, pool_slot) rows, each shard padded to a common
        power-of-two row count (padding: the shard's base pool slot with
        fill 0 — in-range for the shard, invalid for the fold)."""
        pool = self.engine.pool
        per_shard, rows_per_shard = pack_rows_shard_major(
            [ws for _, ws, _ in rows], num_devices, slots_per)
        table: List[int] = []
        fills: List[int] = []
        slots: List[int] = []
        for d, idxs in enumerate(per_shard):
            base_slot = d * slots_per if num_devices > 1 else 0
            base_pool = d * pool.slots_per_shard if num_devices > 1 else 0
            for r in idxs:
                blk, wslot, ps = rows[r]
                table.append(ps)
                fills.append(blk.fill)
                slots.append(wslot)
            for _ in range(rows_per_shard - len(idxs)):
                table.append(base_pool)
                fills.append(0)
                slots.append(base_slot)
        return (jnp.asarray(table, jnp.int32),
                jnp.asarray(fills, jnp.int32),
                jnp.asarray(slots, jnp.int32))

    def _fold_pooled(self, plans, mesh, num_devices):
        """Block-table gather over the persistent pool.

        Three row classes, folded as up to three partial accumulators and
        merged (``op.merge_acc``):
          * resident rows — already in the arena: block table, zero-copy;
          * cold p-blocks — demand pool-fills at PRIO_DEMAND_STAGE whose
            I/O overlaps the resident fold; filled slots fold as a second
            block table, the rest degrade to the stacked fallback;
          * fallback rows — unpoolable (slot/budget exhaustion, misplaced
            shard, legacy device_data): the stacked gather, unsharded.
        """
        eng = self.engine
        op = eng.operator
        pool = eng.pool
        aion = eng.aion
        use_mesh = mesh if num_devices > 1 else None

        slot_of, num_slots, slots_per = plan_slot_placement_pooled(
            [it.wid for it, _ in plans], num_devices)

        g0 = _time.time()
        gather_dt = 0.0
        dev_dt = 0.0
        blocks: List[Tuple[Any, int]] = []        # (block, window index)
        for i, (it, blks) in enumerate(plans):
            for blk in blks:
                if blk.fill:
                    blocks.append((blk, i))

        def well_placed(ps, i):
            return num_devices <= 1 or \
                pool.shard_of_slot(ps) == slot_of[i] // slots_per

        accs: List[Any] = []
        ran_sharded = False
        evs: List[Any] = []
        cold: List[Tuple[Any, int]] = []          # (block, window index)
        fallback: List[Tuple[Any, int]] = []      # (block, wslot)

        # Pin strategy. The legacy (synchronous) path holds ONE pool pin
        # across the whole round — including the demand-fill wait — so
        # every fill that lands mid-round pays the functional copy path.
        # Under the pipelined engine the per-slot epoch scheme
        # (``pool_slot_epochs``) shrinks the pins to the
        # snapshot->dispatch windows: rows are classified OUTSIDE any
        # pin from a (slot, epoch) read, re-validated under a short pin
        # at dispatch (an unchanged epoch proves the captured arena
        # holds the classified data; moved rows demote to the stacked
        # fallback), and the fill wait happens UNPINNED — ingest-time
        # and overlapped demand fills donate in place, O(block).
        epoch_mode = eng.pipeline is not None \
            and getattr(aion, "pool_slot_epochs", True)

        if epoch_mode:
            pairs = pool.slot_epochs([b for b, _ in blocks])
            pooled3: List[Tuple[Any, int, int, int]] = []
            for (blk, i), (ps, ep) in zip(blocks, pairs):
                if ps is not None and well_placed(ps, i):
                    pooled3.append((blk, i, ps, ep))
                elif ps is None and blk.tier != Tier.DEVICE \
                        and aion.pool_overlap_prefetch:
                    cold.append((blk, i))
                else:
                    fallback.append((blk, slot_of[i]))
            if cold:
                by_window: Dict[int, List[Any]] = {}
                for blk, i in cold:
                    by_window.setdefault(i, []).append(blk)
                for i, blks in by_window.items():
                    evs.append(eng.io.request_stage(plans[i][0].state,
                                                    blks, demand=True))
                eng.metrics.demand_pool_fills += len(cold)
                # wait UNPINNED, BEFORE the snapshot: under the
                # pipelined engine inter-round overlap comes from the
                # round queue (round k+1's prefetch staged during round
                # k's fold), so this wait is only the prefetch residual
                # — and folding resident + freshly-filled rows as ONE
                # table keeps the dispatch shape round-invariant (the
                # two-table split re-jits a new staged-table shape
                # whenever the prefetch residual changes). A failed
                # fill aborts the round (StagingError) instead of
                # folding stale tiers.
                w0 = _time.time()
                for ev in evs:
                    ev.wait(timeout=60)
                eng.metrics.batch_stall_seconds += _time.time() - w0
                for ev in evs:
                    ev.check()
                for (blk, i), (ps, ep) in zip(
                        cold, pool.slot_epochs([b for b, _ in cold])):
                    if ps is not None and well_placed(ps, i):
                        pooled3.append((blk, i, ps, ep))
                    else:       # fill could not take a slot: host path
                        fallback.append((blk, slot_of[i]))
            gather_dt += _time.time() - g0

            if pooled3:
                g0 = _time.time()
                # one short pin: capture + validate + pack + dispatch
                with pool.pinned():
                    k_arena, v_arena, ps_now, ep_now = \
                        pool.snapshot_with_epochs(
                            [b for b, _, _, _ in pooled3])
                    pooled: List[Tuple[Any, int, int]] = []
                    for (blk, i, ps, ep), ps2, ep2 in zip(
                            pooled3, ps_now, ep_now):
                        if ps2 == ps and ep2 == ep:
                            pooled.append((blk, slot_of[i], ps))
                        else:
                            # destaged/purged/recycled since the
                            # classify read: fold the block's current
                            # truth through the stacked fallback
                            eng.metrics.epoch_demoted_rows += 1
                            fallback.append((blk, slot_of[i]))
                    if pooled:
                        groups = self._plan_table_groups(
                            pooled, num_devices, slots_per)
                        arena_data = {"keys": k_arena, "values": v_arena}
                        gather_dt += _time.time() - g0
                        dev_dt += self._fold_table_groups(
                            groups, arena_data, num_slots, use_mesh,
                            accs)
                        ran_sharded = ran_sharded or use_mesh is not None
                        eng.metrics.pooled_rows += len(pooled)
                    else:
                        gather_dt += _time.time() - g0
            return self._fold_pooled_tail(
                plans, accs, fallback, slot_of, num_slots, dev_dt,
                gather_dt, ran_sharded)

        # the whole batch runs under ONE pool pin: any fill that lands
        # while a fold may be executing takes the functional (copy) path,
        # which (a) keeps our snapshot references live and (b) never
        # touches the buffer the fold is reading — a donated in-place
        # write here would WAIT on the fold's usage hold and serialize
        # the overlap away. Fills outside a batch (ingest, pre-staging)
        # see no pin and write donated (O(block), in place).
        # deferred_fills batches the round's cold fills into ONE scatter
        # commit at the second snapshot — k overlapped fills cost
        # O(arena + k*block), not k functional O(arena) copies.
        with pool.pinned(), pool.deferred_fills():
            k_arena, v_arena, pslots = pool.snapshot_for(
                [b for b, _ in blocks])
            arena_data = {"keys": k_arena, "values": v_arena}

            pooled: List[Tuple[Any, int, int]] = []  # (blk, wslot, pslot)
            for (blk, i), ps in zip(blocks, pslots):
                if ps is not None and well_placed(ps, i):
                    pooled.append((blk, slot_of[i], ps))
                elif ps is None and blk.tier != Tier.DEVICE \
                        and aion.pool_overlap_prefetch:
                    cold.append((blk, i))
                else:
                    fallback.append((blk, slot_of[i]))

            # demand pool-fills for cold p-blocks: issued BEFORE the
            # resident fold so the I/O executor stages while the device
            # folds (the paper's demand-staging-outranks-prestaging rule,
            # at pool granularity)
            if cold:
                by_window = {}
                for blk, i in cold:
                    by_window.setdefault(i, []).append(blk)
                for i, blks in by_window.items():
                    evs.append(eng.io.request_stage(plans[i][0].state,
                                                    blks, demand=True))
                eng.metrics.demand_pool_fills += len(cold)
            gather_dt += _time.time() - g0

            if pooled:
                g0 = _time.time()
                groups = self._plan_table_groups(pooled, num_devices,
                                                 slots_per)
                gather_dt += _time.time() - g0
                dev_dt += self._fold_table_groups(groups, arena_data,
                                                  num_slots, use_mesh,
                                                  accs)
                ran_sharded = ran_sharded or use_mesh is not None
                eng.metrics.pooled_rows += len(pooled)

            if evs:
                w0 = _time.time()
                for ev in evs:
                    ev.wait(timeout=60)
                eng.metrics.batch_stall_seconds += _time.time() - w0
                for ev in evs:
                    ev.check()       # failed demand fill aborts the round
                g0 = _time.time()
                k2, v2, ps2 = pool.snapshot_for([b for b, _ in cold])
                staged: List[Tuple[Any, int, int]] = []
                for (blk, i), ps in zip(cold, ps2):
                    if ps is not None and well_placed(ps, i):
                        staged.append((blk, slot_of[i], ps))
                    else:
                        # fill failed (budget/pool exhaustion) or landed
                        # in a foreign range: the stacked fallback reads
                        # it (device-preferred, host-accounted)
                        fallback.append((blk, slot_of[i]))
                gather_dt += _time.time() - g0
                if staged:
                    g0 = _time.time()
                    groups = self._plan_table_groups(
                        staged, num_devices, slots_per)
                    arena2 = {"keys": k2, "values": v2}
                    gather_dt += _time.time() - g0
                    dev_dt += self._fold_table_groups(
                        groups, arena2, num_slots, use_mesh, accs)
                    ran_sharded = ran_sharded or use_mesh is not None
                    eng.metrics.pooled_rows += len(staged)

        return self._fold_pooled_tail(plans, accs, fallback, slot_of,
                                      num_slots, dev_dt, gather_dt,
                                      ran_sharded)

    def _fold_pooled_tail(self, plans, accs, fallback, slot_of, num_slots,
                          dev_dt, gather_dt, ran_sharded):
        """Shared tail of both pooled pin strategies: fold the fallback
        rows through the stacked gather, then merge the partial
        accumulators into per-slot results."""
        eng = self.engine
        op = eng.operator
        if fallback:
            g0 = _time.time()
            rows = []
            eng.io.readahead_blocks([blk for blk, _ in fallback])
            for blk, wslot in fallback:
                arrs = eng.io.fetch_block_arrays(blk)
                if arrs is None:          # purged mid-gather
                    continue
                rows.append((arrs, blk.fill, wslot))
            if rows:
                # unsharded fold (any global slot id is valid on one
                # device), rows pow2-padded by the shared stacker
                data, fills, slots = self._stack_rows(rows, 1, num_slots)
                gather_dt += _time.time() - g0
                d0 = _time.time()
                accs.append(op.fold_batch(data, fills, slots, num_slots,
                                          mesh=None))
                dev_dt += _time.time() - d0
                eng.metrics.fallback_rows += len(rows)
            else:
                gather_dt += _time.time() - g0

        if not accs:
            # every window empty: finalize the identity accumulator
            results = [op.finalize(op.init_acc()) for _ in range(num_slots)]
        else:
            d0 = _time.time()
            acc = accs[0]
            for a in accs[1:]:
                acc = op.merge_acc(acc, a)
            results = op.finalize_batch(acc, num_slots)
            dev_dt += _time.time() - d0
        return results, slot_of, num_slots, dev_dt, gather_dt, ran_sharded
