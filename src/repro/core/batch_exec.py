"""Batched multi-window execution: one device pass per poll/watermark.

Paper §3 orders work by a strict priority rule — live window executions
first, then late re-executions, with demand staging outranking speculative
pre-staging. The per-window reference path (``StreamEngine.
execute_window``) honors that rule one window at a time, paying a jit
dispatch per block per window; with many concurrent due windows (long
lateness horizons keep many past windows re-executing) the dispatch
overhead — not the fold FLOPs — dominates.

This module keeps the priority rule but batches *within* a priority
class: each ``advance_watermark`` gathers every newly-expired window into
one live batch, and each ``poll`` gathers every due late re-execution
into one late batch — live batches always run before late batches because
the engine calls them in that order, so the rule is preserved at batch
granularity. A batch stacks the windows' fixed-capacity blocks into
``[rows, block_capacity, W]`` tensors (rows may be blocks of different
windows; a slot vector maps rows back to windows) and folds everything in
a single call of the operator's ``fold_batch`` — which reduces over
composite ``(window_slot, key)`` segment ids through the batched
segment-aggregate kernel. Re-execution stays a pure function of bucket
contents, so folding N windows in one pass is bitwise-equivalent to N
independent folds up to float associativity (parity-tested in
``tests/test_batch_exec.py`` and ``tests/test_slot_sharding.py``).

Row gathering prefers device residency: m-bucket rows that already live
on the device are stacked with a **device concat** (``jnp.stack`` of the
resident arrays — no host round-trip); cold p-blocks are read host-side
through ``IOScheduler.fetch_block_host`` (accounted, and persisted reads
pay the simulated persistent-tier cost). ``AionConfig.device_stacking``
= False restores the PR-1 host-side ``np.stack`` + one contiguous
``device_put``.

Multi-device slot sharding (``AionConfig.slot_sharding``): the placement
step round-robins due windows onto device-local slot ranges — window i of
a batch goes to device ``i % D`` at local slot ``i // D`` — then packs
each device's block rows contiguously (shard-major) and pads every shard
to a common power-of-two row count. The fold runs under a ``shard_map``
over the slot axis; slots are disjoint, so the per-slot result gather is
a pure concatenation with no cross-device reduction (psum-free). On a
single-device host the placement degenerates to the unsharded layout.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import WindowState
from repro.core.windows import WindowId
from repro.kernels.segment_aggregate import (
    next_pow2, pack_rows_shard_major,
)


@dataclass
class BatchWorkItem:
    """One due window execution (live expiry or late re-execution)."""
    wid: WindowId
    state: WindowState
    late: bool


def snapshot_block_partition(state: WindowState):
    """Atomic (m, p) partition of a window's blocks.

    Shared by the per-window and batched execution paths — the
    double-fold hazard lives here: snapshot BOTH lists before issuing any
    staging request, otherwise the I/O thread can move a block
    device-side between the two snapshots and it would be folded twice.
    """
    m_snapshot = state.m_blocks()
    m_ids = {id(b) for b in m_snapshot}
    p_blocks = [b for b in state.blocks if id(b) not in m_ids]
    return m_snapshot, p_blocks


def plan_slot_placement(num_windows: int, num_devices: int
                        ) -> Tuple[List[int], int, int]:
    """Round-robin due windows onto device-local slot ranges.

    Device ``d`` owns the contiguous global slot range
    ``[d*slots_per, (d+1)*slots_per)``; window ``i`` of the batch lands on
    device ``i % num_devices`` at local slot ``i // num_devices``.
    ``slots_per`` is padded to a power of two so the jitted fold sees
    O(log) distinct shapes. Returns ``(slot_of_window, num_slots,
    slots_per)``; ``num_devices <= 1`` degenerates to the unsharded
    identity placement.
    """
    if num_devices <= 1:
        ns = next_pow2(num_windows)
        return list(range(num_windows)), ns, ns
    slots_per = next_pow2(-(-num_windows // num_devices))
    slot_of = [(i % num_devices) * slots_per + i // num_devices
               for i in range(num_windows)]
    return slot_of, num_devices * slots_per, slots_per


class BatchExecutor:
    """Executes a set of due windows in one vectorized device pass."""

    def __init__(self, engine):
        self.engine = engine
        self._mesh = None
        self._mesh_resolved = False

    # ---------------------------------------------------------- slot mesh
    def _slot_mesh(self):
        """The 1-D slot mesh, or None (sharding off / single device)."""
        if self._mesh_resolved:
            return self._mesh
        self._mesh_resolved = True
        aion = self.engine.aion
        if getattr(aion, "slot_sharding", False):
            from repro.distributed.sharding import make_slot_mesh
            self._mesh = make_slot_mesh(aion.slot_shard_devices,
                                        aion.slot_shard_axis)
        return self._mesh

    @staticmethod
    def _stack(rows: List[Any], device: bool, dtype) -> Any:
        """Stack per-block rows into one [rows, ...] tensor.

        ``device=True``: a device concat — already-resident jax rows are
        consumed in place and host rows are transferred individually, so
        hot m-bucket blocks never round-trip through the host.
        ``device=False``: the PR-1 host stack (one contiguous device_put
        inside the jitted fold).
        """
        if device:
            return jnp.stack([r if isinstance(r, jax.Array)
                              else jnp.asarray(r) for r in rows])
        return np.stack([np.asarray(r, dtype) for r in rows])

    # ------------------------------------------------------------ execute
    def execute(self, items: List[BatchWorkItem], now: float
                ) -> Dict[WindowId, Any]:
        """Fold all items in one device pass; returns results by window.

        Falls back to the per-window reference path when the operator has
        no batch contract or the batch is trivial (a single window gains
        nothing from stacking). An empty item list is a no-op — no
        degenerate [0, ...] tensors, no metrics.
        """
        eng = self.engine
        op = eng.operator
        if not items:
            return {}
        if not op.supports_batch or len(items) == 1:
            return {it.wid: eng.execute_window(it.wid, now, it.late)
                    for it in items}

        t0 = _time.time()

        # 1. snapshot every window (m-blocks consumed in place, p-blocks
        #    read host-side — no demand staging is issued)
        plans = [(it, sum(snapshot_block_partition(it.state), []))
                 for it in items]

        # 2. placement: window -> global slot. Unsharded: slot i = i.
        #    Sharded: round-robin onto device-local slot ranges so every
        #    device owns a disjoint contiguous range (psum-free gather).
        mesh = self._slot_mesh()
        num_devices = mesh.size if mesh is not None else 1
        slot_of, num_slots, slots_per = plan_slot_placement(
            len(plans), num_devices)

        # 3. gather block rows: (arrays, fill, slot) in plan order
        rows: List[Tuple[Dict[str, Any], int, int]] = []
        for i, (it, blocks) in enumerate(plans):
            for blk in blocks:
                if blk.fill == 0:
                    continue
                arrs = eng.io.fetch_block_arrays(blk)
                if arrs is None:         # purged mid-gather
                    continue
                rows.append((arrs, blk.fill, slot_of[i]))

        dev_t0 = _time.time()
        ran_sharded = False
        if rows:
            # 4. shard-major stack via the same packing helper the parity
            #    tests drive: rows group by owning shard and every shard
            #    pads to a common power-of-two row count (invalid rows:
            #    fill 0, slot = shard's base slot) so row counts divide
            #    the mesh and the jitted fold sees O(log) distinct
            #    shapes. num_devices == 1 degenerates to the PR-1 layout
            #    (one group, rows padded to pow2).
            cap = eng.aion.block_size
            w = eng.value_width
            per_shard, rows_per_shard = pack_rows_shard_major(
                [slot for _, _, slot in rows], num_devices, slots_per)
            pad_arrs = {
                "keys": np.zeros((cap,), np.int32),
                "values": np.zeros((cap, w), np.float32),
            }
            keys_rows, val_rows = [], []
            fills: List[int] = []
            slots: List[int] = []
            for d, idxs in enumerate(per_shard):
                base_slot = d * slots_per if num_devices > 1 else 0
                for r in idxs:
                    arrs, fill, slot = rows[r]
                    keys_rows.append(arrs["keys"])
                    val_rows.append(arrs["values"])
                    fills.append(fill)
                    slots.append(slot)
                for _ in range(rows_per_shard - len(idxs)):
                    keys_rows.append(pad_arrs["keys"])
                    val_rows.append(pad_arrs["values"])
                    fills.append(0)
                    slots.append(base_slot)

            device = getattr(eng.aion, "device_stacking", True)
            # the batched stack carries keys + values only: no batch fold
            # is time-dependent within a window, and stacking timestamps
            # would force a D2H pull of every hot device-resident row
            # (f64 on host, f32 on device — see the fold_batch contract)
            data = {
                "keys": self._stack(keys_rows, device, np.int32),
                "values": self._stack(val_rows, device, np.float32),
            }
            results = op.run_batch(data, jnp.asarray(fills, jnp.int32),
                                   jnp.asarray(slots, jnp.int32),
                                   num_slots, mesh=mesh)
            ran_sharded = mesh is not None
        else:
            # every window empty: finalize the identity accumulator
            results = [op.finalize(op.init_acc()) for _ in range(num_slots)]
        dev_dt = _time.time() - dev_t0

        # 5. per-window bookkeeping, identical to execute_window
        out: Dict[WindowId, Any] = {}
        for i, (it, _) in enumerate(plans):
            result = results[slot_of[i]]
            it.state.result = result
            eng.results[it.wid] = result
            it.state.last_executed_at = now
            it.state.events_at_last_exec = it.state.total_events
            if it.late:
                eng.metrics.late_executions += 1
            else:
                eng.metrics.live_executions += 1
            out[it.wid] = result
            eng._post_execute_destage(it.wid, it.state, now)
        eng.metrics.exec_seconds += _time.time() - t0
        eng.metrics.batch_executions += 1
        eng.metrics.batched_windows += len(plans)
        eng.metrics.batch_device_seconds += dev_dt
        eng.metrics.batch_occupancy_series.append(len(plans))
        if ran_sharded:
            eng.metrics.sharded_batch_executions += 1
        return out
