"""Batched multi-window execution: one device pass per poll/watermark.

Paper §3 orders work by a strict priority rule — live window executions
first, then late re-executions, with demand staging outranking speculative
pre-staging. The per-window reference path (``StreamEngine.
execute_window``) honors that rule one window at a time, paying a jit
dispatch per block per window; with many concurrent due windows (long
lateness horizons keep many past windows re-executing) the dispatch
overhead — not the fold FLOPs — dominates.

This module keeps the priority rule but batches *within* a priority
class: each ``advance_watermark`` gathers every newly-expired window into
one live batch, and each ``poll`` gathers every due late re-execution
into one late batch — live batches always run before late batches because
the engine calls them in that order, so the rule is preserved at batch
granularity. A batch stacks the windows' fixed-capacity blocks into
``[rows, block_capacity, W]`` tensors (rows may be blocks of different
windows; a slot vector maps rows back to windows) and folds everything in
a single call of the operator's ``fold_batch`` — which reduces over
composite ``(window_slot, key)`` segment ids through the batched
segment-aggregate Pallas kernel. Re-execution stays a pure function of
bucket contents, so folding N windows in one pass is bitwise-equivalent
to N independent folds up to float associativity (parity-tested in
``tests/test_batch_exec.py``).

Unlike the per-window path — which demand-stages p-blocks to the device
and folds them in place — the batched fold consumes one host-side stack
(a single contiguous transfer into the jitted fold), so the gather reads
p-blocks host-side through ``IOScheduler.fetch_block_host`` (accounted,
and persisted reads pay the simulated persistent-tier cost) and pulls
already-resident m-blocks back without issuing new staging. Device-side
gathering of m-bucket rows plus demand staging for a device-side stack
is the TPU follow-up tracked in ROADMAP.md.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.buckets import Block, WindowState
from repro.core.windows import WindowId


@dataclass
class BatchWorkItem:
    """One due window execution (live expiry or late re-execution)."""
    wid: WindowId
    state: WindowState
    late: bool


def _block_arrays(blk: Block, io) -> Optional[Dict[str, Any]]:
    """Full-capacity SoA arrays for one block, wherever it lives.

    Prefers the device-resident copy (no transfer needed to read it back
    on CPU; one is queued anyway by the host stack); otherwise a demand
    host read through the I/O layer (accounted + simulated-cost-charged).
    Returns None only if the block was purged while the batch was being
    gathered.
    """
    dd = blk.device_data
    if dd is not None:
        return dd
    return io.fetch_block_host(blk)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def snapshot_block_partition(state: WindowState):
    """Atomic (m, p) partition of a window's blocks.

    Shared by the per-window and batched execution paths — the
    double-fold hazard lives here: snapshot BOTH lists before issuing any
    staging request, otherwise the I/O thread can move a block
    device-side between the two snapshots and it would be folded twice.
    """
    m_snapshot = state.m_blocks()
    m_ids = {id(b) for b in m_snapshot}
    p_blocks = [b for b in state.blocks if id(b) not in m_ids]
    return m_snapshot, p_blocks


class BatchExecutor:
    """Executes a set of due windows in one vectorized device pass."""

    def __init__(self, engine):
        self.engine = engine

    # ------------------------------------------------------------ execute
    def execute(self, items: List[BatchWorkItem], now: float
                ) -> Dict[WindowId, Any]:
        """Fold all items in one device pass; returns results by window.

        Falls back to the per-window reference path when the operator has
        no batch contract or the batch is trivial (a single window gains
        nothing from stacking).
        """
        eng = self.engine
        op = eng.operator
        if not items:
            return {}
        if not op.supports_batch or len(items) == 1:
            return {it.wid: eng.execute_window(it.wid, now, it.late)
                    for it in items}

        t0 = _time.time()

        # 1. snapshot every window (m-blocks read back in place, p-blocks
        #    read host-side — the fold consumes one host stack, so no
        #    demand staging is issued)
        plans = [(it, sum(snapshot_block_partition(it.state), []))
                 for it in items]

        # 2. stack block rows: [rows, capacity, W] + fills + slot map
        keys_rows, ts_rows, val_rows, fills, slots = [], [], [], [], []
        for slot, (it, blocks) in enumerate(plans):
            for blk in blocks:
                if blk.fill == 0:
                    continue
                arrs = _block_arrays(blk, eng.io)
                if arrs is None:         # purged mid-gather
                    continue
                keys_rows.append(arrs["keys"])
                ts_rows.append(arrs["timestamps"])
                val_rows.append(arrs["values"])
                fills.append(blk.fill)
                slots.append(slot)

        # 3. one device pass over every due window. Rows are stacked
        #    host-side (np.stack of a device row is a pull-back; cheap on
        #    CPU, and one contiguous device_put beats a per-row dispatch
        #    chain — device-side stacking for TPU is a ROADMAP open item).
        #    Row and slot counts are padded to powers of two so the jitted
        #    fold sees O(log) distinct shapes instead of recompiling every
        #    time a window gains a block; padding rows have fill 0 and
        #    contribute nothing.
        num_slots = len(plans)
        dev_t0 = _time.time()
        if fills:
            pad_rows = _next_pow2(len(fills)) - len(fills)
            if pad_rows:
                cap = keys_rows[0].shape[0]
                w = val_rows[0].shape[-1]
                keys_rows.extend([np.zeros((cap,), np.int32)] * pad_rows)
                ts_rows.extend([np.zeros((cap,), np.float64)] * pad_rows)
                val_rows.extend(
                    [np.zeros((cap, w), np.float32)] * pad_rows)
                fills.extend([0] * pad_rows)
                slots.extend([0] * pad_rows)
            data = {
                "keys": np.stack([np.asarray(r) for r in keys_rows]),
                "timestamps": np.stack([np.asarray(r) for r in ts_rows]),
                "values": np.stack([np.asarray(r) for r in val_rows]),
            }
            results = op.run_batch(data, jnp.asarray(fills, jnp.int32),
                                   jnp.asarray(slots, jnp.int32),
                                   _next_pow2(num_slots))
        else:
            # every window empty: finalize the identity accumulator
            results = [op.finalize(op.init_acc()) for _ in range(num_slots)]
        dev_dt = _time.time() - dev_t0

        # 4. per-window bookkeeping, identical to execute_window
        out: Dict[WindowId, Any] = {}
        for slot, (it, _) in enumerate(plans):
            result = results[slot]
            it.state.result = result
            eng.results[it.wid] = result
            it.state.last_executed_at = now
            it.state.events_at_last_exec = it.state.total_events
            if it.late:
                eng.metrics.late_executions += 1
            else:
                eng.metrics.live_executions += 1
            out[it.wid] = result
            eng._post_execute_destage(it.wid, it.state, now)
        eng.metrics.exec_seconds += _time.time() - t0
        eng.metrics.batch_executions += 1
        eng.metrics.batched_windows += num_slots
        eng.metrics.batch_device_seconds += dev_dt
        eng.metrics.batch_occupancy_series.append(num_slots)
        return out
