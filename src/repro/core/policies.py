"""Data-transfer policies (paper §3.2).

Policies decide *which tier* window blocks should live in, in response to
engine events. They are strategy objects with hooks; all actual movement
goes through the prioritized ``IOScheduler``.

* ``StandardPolicy`` — events fill the m-bucket until full, then redirect
  to the p-bucket; on expiry the whole window destages; late events write
  straight to the p-bucket; staging happens at (pre-)execution time.
* ``LocalRhoMinPolicy`` — like standard, but keeps a bootstrap set of
  ``rho_min`` initial blocks resident after destage, and destages idle
  windows after ``tau`` seconds without events or watermarks.
* ``GlobalMemoryPolicy`` — watches overall memory: under *moderate*
  pressure destages expired/idle windows selectively (by descending state
  size for fastest savings, or ascending ingestion rate to minimize delay);
  under *severe* pressure destages everything except bootstrap sets.
* ``InMemoryPolicy`` — the Flink-baseline backend: everything stays in the
  memory tier; when the budget is exhausted the engine OOMs (Q1 baseline).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.buckets import MemoryBudget, Tier, WindowState
from repro.core.staging import IOScheduler
from repro.core.windows import WindowId


class EngineOOM(RuntimeError):
    """Raised by the in-memory baseline when the device budget is exhausted
    (models the paper's baseline crashing under heap pressure)."""


class TransferPolicy:
    name = "abstract"

    def on_append(self, state: WindowState, new_blocks, io: IOScheduler,
                  late: bool, now: float) -> None:
        raise NotImplementedError

    def on_expiry(self, state: WindowState, io: IOScheduler,
                  now: float) -> None:
        raise NotImplementedError

    def on_post_execute(self, state: WindowState, io: IOScheduler,
                        now: float) -> None:
        """m-bucket of a past window is freed after re-execution (paper)."""
        if state.expired:
            io.request_destage(state, keep_bootstrap=state.rho_min_blocks)

    def on_tick(self, windows: Dict[WindowId, WindowState],
                io: IOScheduler, now: float) -> None:
        pass


@dataclass
class StandardPolicy(TransferPolicy):
    name: str = "standard"

    def on_append(self, state, new_blocks, io, late, now):
        if late or state.expired:
            io.request_late_write(state, new_blocks)    # straight to p
            return
        # active window: stage new blocks into the m-bucket while there is
        # budget; once full, subsequent blocks stay host-side (redirect).
        # The shard hint keeps pooled slots in the window's arena range.
        shard = io.shard_of(state)
        for blk in new_blocks:
            if not io.stage_block_sync(blk, shard=shard):
                break

    def on_expiry(self, state, io, now):
        state.rho_min_blocks = 0
        io.request_destage(state)


@dataclass
class LocalRhoMinPolicy(StandardPolicy):
    name: str = "local_rho_min"
    rho_min: float = 0.05
    tau: float = 60.0
    _last_activity: Dict[WindowId, float] = field(default_factory=dict)

    def _bootstrap_blocks(self, state: WindowState) -> int:
        return max(1, math.ceil(len(state.blocks) * self.rho_min))

    def on_append(self, state, new_blocks, io, late, now):
        self._last_activity[WindowId(state.window_start,
                                     state.window_end)] = now
        super().on_append(state, new_blocks, io, late, now)

    def on_expiry(self, state, io, now):
        state.rho_min_blocks = self._bootstrap_blocks(state)
        io.request_destage(state, keep_bootstrap=state.rho_min_blocks)

    def on_tick(self, windows, io, now):
        for wid, state in windows.items():
            last = self._last_activity.get(wid, now)
            if now - last > self.tau and state.device_bytes() > 0:
                state.rho_min_blocks = self._bootstrap_blocks(state)
                io.request_destage(state,
                                   keep_bootstrap=state.rho_min_blocks)
                self._last_activity[wid] = now


@dataclass
class GlobalMemoryPolicy(LocalRhoMinPolicy):
    name: str = "global_memory"
    moderate: float = 0.75
    severe: float = 0.90
    order: str = "size_desc"       # or "ingest_rate_asc"

    def on_tick(self, windows, io, now):
        util = io.budget.utilization
        if util < self.moderate:
            return
        states = [s for s in windows.values() if s.device_bytes() > 0]
        if util >= self.severe:
            for s in states:
                s.rho_min_blocks = self._bootstrap_blocks(s)
                io.request_destage(s, keep_bootstrap=s.rho_min_blocks)
            return
        if self.order == "size_desc":
            states.sort(key=lambda s: -s.device_bytes())
        else:
            states.sort(key=lambda s: s.total_events /
                        max(s.window_end - s.window_start, 1e-9))
        # destage until projected utilization is under the moderate line
        need = io.budget.used_bytes - int(self.moderate
                                          * io.budget.capacity_bytes)
        for s in states:
            if need <= 0:
                break
            s.rho_min_blocks = self._bootstrap_blocks(s)
            freeable = s.device_bytes()
            io.request_destage(s, keep_bootstrap=s.rho_min_blocks)
            need -= freeable


@dataclass
class InMemoryPolicy(TransferPolicy):
    """Flink-baseline backend: all state pinned in the memory tier."""
    name: str = "in_memory_baseline"

    def on_append(self, state, new_blocks, io, late, now):
        shard = io.shard_of(state)
        for blk in new_blocks:
            if not io.stage_block_sync(blk, shard=shard):
                raise EngineOOM(
                    f"in-memory baseline exhausted device budget "
                    f"({io.budget.used_bytes}/{io.budget.capacity_bytes} B)")

    def on_expiry(self, state, io, now):
        pass                                   # never destage

    def on_post_execute(self, state, io, now):
        pass
