from repro.core.batch_exec import BatchExecutor, BatchWorkItem
from repro.core.buckets import Block, MemoryBudget, Tier, WindowState
from repro.core.cleanup import LatenessHistogram, PredictiveCleanup
from repro.core.engine import StreamEngine
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.policies import (
    EngineOOM, GlobalMemoryPolicy, InMemoryPolicy, LocalRhoMinPolicy,
    StandardPolicy,
)
from repro.core.pipeline import (
    EnginePipeline, MultiTenantEngine, PipelineError, ResultFuture,
    TenantSpec,
)
from repro.core.proactive import PrestageScheduler, StagingCostModel
from repro.core.staging import (
    IOScheduler, StagingError, TaskHandle, TransferExecutor,
)
from repro.core.staleness import (
    deltaev_times, deltat_times, executions_for_bound,
    max_staleness_of, minimize_max_staleness,
)
from repro.core.time import PeriodicWatermarkGenerator, WatermarkTracker
from repro.core.triggers import AionStalenessTrigger, DeltaEvTrigger, DeltaTTrigger
from repro.core.windows import (
    CountWindows, SessionWindows, SlidingWindows, TumblingWindows, WindowId,
)

__all__ = [
    "BatchExecutor", "BatchWorkItem",
    "Block", "MemoryBudget", "Tier", "WindowState",
    "LatenessHistogram", "PredictiveCleanup", "StreamEngine", "EventBatch",
    "make_operator", "EngineOOM", "GlobalMemoryPolicy", "InMemoryPolicy",
    "LocalRhoMinPolicy", "StandardPolicy", "PrestageScheduler",
    "StagingCostModel", "IOScheduler", "StagingError", "TaskHandle",
    "TransferExecutor", "EnginePipeline", "MultiTenantEngine",
    "PipelineError", "ResultFuture", "TenantSpec",
    "deltaev_times", "deltat_times",
    "executions_for_bound", "max_staleness_of", "minimize_max_staleness",
    "PeriodicWatermarkGenerator", "WatermarkTracker", "AionStalenessTrigger",
    "DeltaEvTrigger", "DeltaTTrigger", "CountWindows", "SessionWindows",
    "SlidingWindows", "TumblingWindows", "WindowId",
]
