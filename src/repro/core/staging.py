"""Staging/destaging: the single prioritized I/O executor (paper §4).

All tier transfers flow through one executor thread that serializes and
prioritizes requests: **demand staging > pre-staging > readahead >
late-event writes > destaging (m->p)** — staging data is needed
imminently by an executing operator, speculative store readahead should
not delay a concrete staging deadline, and destaging is a background
memory-saving activity. Destage operations are *preemptible at block
granularity*: between blocks the executor yields to any queued
higher-priority work (the paper's "interleaved" operations).

TPU adaptation of the serialization ablations (§5 Q3):
  * multithreaded JSON serialization  ->  chunked multi-buffer transfers
    (``chunk_blocks`` blocks per DMA) vs one monolithic transfer
  * single sequential I/O thread      ->  ``sequential_io=True`` (one
    executor) vs a thread pool issuing transfers concurrently
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import numpy as np

from repro.core.buckets import Block, MemoryBudget, Tier, WindowState
from repro.obs import NULL_SPAN, MetricsRegistry, StatsMap, Tracer
from repro.storage.blockstore import (
    BlockStore, SimulatedCost, is_transient_error,
)

PRIO_DEMAND_STAGE = -1    # staging an operator is *blocked on* right now
PRIO_STAGE = 0            # proactive pre-staging
PRIO_READAHEAD = 1        # speculative store->cache sweeps (prefetch)
PRIO_LATE_WRITE = 2
PRIO_DESTAGE = 3

# priority class -> span/label name (tenant-fairness + tracing taxonomy)
PRIO_NAMES = {
    PRIO_DEMAND_STAGE: "demand_stage",
    PRIO_STAGE: "stage",
    PRIO_READAHEAD: "readahead",
    PRIO_LATE_WRITE: "late_write",
    PRIO_DESTAGE: "destage",
}


def _wkey(window: "WindowState") -> str:
    """Compact window id for span attributes."""
    return f"{window.window_start:g}-{window.window_end:g}"


class StagingError(RuntimeError):
    """A prioritized I/O task failed.

    Raised to waiters that *checked* their handle (``TaskHandle.check``):
    a failed demand stage must abort the fold that depends on it instead
    of silently reading stale tiers."""


class TaskHandle(threading.Event):
    """Completion handle for one submitted I/O task.

    An ``Event`` (so legacy ``submit(...).wait()`` callers keep working)
    plus the task's failure, if any: the executor records the exception
    here *before* setting the event, so a waiter that observes completion
    can always observe the error too."""

    def __init__(self):
        super().__init__()
        self.error: Optional[BaseException] = None

    def check(self) -> None:
        """Raise ``StagingError`` if the task failed."""
        if self.error is not None:
            raise StagingError(
                f"I/O task failed: {type(self.error).__name__}: "
                f"{self.error}") from self.error

    def wait_checked(self, timeout: Optional[float] = None) -> bool:
        """``wait`` + ``check``: returns completion, raises on failure."""
        ok = self.wait(timeout)
        self.check()
        return ok


@dataclass
class _Task:
    fn: Callable
    handle: TaskHandle
    tenant: str
    on_error: Optional[Callable] = None


class TransferExecutor:
    """The shared prioritized transfer executor behind ``IOScheduler``.

    One executor thread serializes transfers by priority class
    (``sequential_io=True``); ``sequential_io=False`` reproduces the
    paper's *no-sqntl-io* ablation (a pool, no ordering). Within a
    priority class, tasks are **tenant-tagged** and served by weighted
    round-robin across tenants: a tenant with weight ``w`` gets ``w``
    consecutive tasks before the cursor moves on, so one tenant's
    destage backlog cannot starve another's staging at the same
    priority (cross-class, the lattice still rules: any higher-priority
    task from any tenant goes first).

    Failures are never swallowed: a task exception is recorded on its
    ``TaskHandle`` (waiters re-raise via ``check()``), counted in
    ``stats["errors"]``, remembered as ``stats["last_error"]``, and
    forwarded to the submitting scheduler's ``on_error`` callback.
    """

    def __init__(self, *, sequential_io: bool = True,
                 max_pool_workers: int = 4,
                 registry: Optional[MetricsRegistry] = None):
        self.sequential_io = sequential_io
        self._cv = threading.Condition()
        # priority -> tenant -> FIFO of tasks
        self._classes: Dict[int, Dict[str, Deque[_Task]]] = {}
        self._weights: Dict[str, int] = {}
        self._rr_tenant: Dict[int, Optional[str]] = {}
        self._rr_served: Dict[int, int] = {}
        self._pending = 0
        self._inflight = 0
        self._stop = False
        # registry-backed stats: `executed`/`errors` are atomic counters
        # and `tenant_executed` a per-tenant labelled counter family, so
        # increments from pool-ablation worker threads (and unlocked
        # reads like fairness_stats) can't lose or tear updates
        self.registry = registry if registry is not None else MetricsRegistry()
        self.stats: StatsMap = StatsMap(self.registry, "aion_executor")
        self.stats.register("errors", "counter",
                            "I/O tasks that raised")
        self.stats.register("executed", "counter",
                            "I/O tasks completed (ok or failed)")
        self.stats.register_raw("last_error", None)
        self.stats.register_tenant_view(
            "tenant_executed",
            self.registry.counter("aion_executor_tenant_tasks",
                                  "I/O tasks completed per tenant",
                                  labelnames=("tenant",)))
        # fault-injection seam (testing.faults.FaultInjector): called
        # with the task before its body runs; may sleep (latency) or
        # raise (a dispatch failure, recorded like any task exception)
        self.fault_hook: Optional[Callable[[_Task], None]] = None
        # failures since the last raising drain — drain(raise_on_error)
        # reports ALL of them at once instead of first-error-wins
        self._failures: Deque[str] = deque(maxlen=64)
        if sequential_io:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
            self._pool = None
        else:
            self._thread = None
            self._pool = ThreadPoolExecutor(max_workers=max_pool_workers)

    def set_weight(self, tenant: str, weight: int) -> None:
        with self._cv:
            self._weights[tenant] = max(int(weight), 1)

    # ------------------------------------------------------------- submit
    def submit(self, priority: int, fn: Callable, *,
               tenant: str = "default",
               on_error: Optional[Callable] = None) -> TaskHandle:
        handle = TaskHandle()
        task = _Task(fn=fn, handle=handle, tenant=tenant,
                     on_error=on_error)
        if self._pool is not None:                 # no-sqntl-io ablation
            with self._cv:
                self._inflight += 1

            def wrap():
                try:
                    hook = self.fault_hook
                    if hook is not None:
                        hook(task)
                    fn()
                except BaseException as exc:       # record, never swallow
                    self._record_failure(task, exc)
                finally:
                    handle.set()
                    with self._cv:
                        self._inflight -= 1
                        self._finish_locked(task)
            self._pool.submit(wrap)
            return handle
        with self._cv:
            cls = self._classes.setdefault(priority, {})
            cls.setdefault(tenant, deque()).append(task)
            self._weights.setdefault(tenant, 1)
            self._pending += 1
            self._cv.notify()
        return handle

    def _record_failure(self, task: _Task, exc: BaseException) -> None:
        """A task raised: remember it everywhere a caller could look —
        the handle (demand waiters), the stats (pollers), the submitting
        scheduler (per-tenant stats). Set BEFORE ``handle.set()`` so no
        waiter can observe completion without the error."""
        task.handle.error = exc
        self.stats.inc("errors")
        with self._cv:
            self.stats["last_error"] = \
                f"{type(exc).__name__}: {exc}"
            self._failures.append(self.stats["last_error"])
        if task.on_error is not None:
            try:
                task.on_error(exc)
            except Exception:
                pass                       # stats callback must not kill us

    def _finish_locked(self, task: _Task) -> None:
        self.stats.inc("executed")
        self.stats.inc_labeled("tenant_executed", task.tenant)
        if not self._pending and not self._inflight:
            self._cv.notify_all()          # wake drain() waiters

    def _pop_locked(self) -> Optional[_Task]:
        """Next task: strictly lowest priority class first; weighted
        round-robin across that class's tenants (``weight`` consecutive
        pops per tenant before the cursor advances, tenant order
        deterministic by name)."""
        active = [p for p, cls in self._classes.items()
                  if any(cls.values())]
        if not active:
            return None
        prio = min(active)
        cls = self._classes[prio]
        names = sorted(t for t, q in cls.items() if q)
        cur = self._rr_tenant.get(prio)
        served = self._rr_served.get(prio, 0)
        if cur not in names or served >= self._weights.get(cur, 1):
            if cur in names:
                cur = names[(names.index(cur) + 1) % len(names)]
            else:
                # stale cursor (tenant's queue emptied): resume rotation
                # at the first name after it, wrapping
                later = [t for t in names if cur is None or t > cur]
                cur = later[0] if later else names[0]
            served = 0
        self._rr_tenant[prio] = cur
        self._rr_served[prio] = served + 1
        self._pending -= 1
        return cls[cur].popleft()

    def _run(self) -> None:
        while True:
            with self._cv:
                task = self._pop_locked()
                while task is None and not self._stop:
                    self._cv.wait(timeout=1.0)
                    task = self._pop_locked()
                if task is None:                   # stopping, queue empty
                    self._cv.notify_all()
                    return
                self._inflight += 1
            try:
                hook = self.fault_hook
                if hook is not None:
                    hook(task)
                task.fn()
            except BaseException as exc:    # record, never kill the thread
                self._record_failure(task, exc)
            finally:
                task.handle.set()
                with self._cv:
                    self._inflight -= 1
                    self._finish_locked(task)

    # ----------------------------------------------------------- queries
    def has_higher_priority_pending(self, priority: int) -> bool:
        with self._cv:
            return any(p < priority and any(cls.values())
                       for p, cls in self._classes.items())

    def drain(self, timeout: float = 30.0,
              raise_on_error: bool = False) -> bool:
        """Block until no task is queued or mid-run, in BOTH modes.

        Returns ``True`` on a clean drain and ``False`` on timeout —
        callers that need an empty queue (close, checkpoint) MUST check
        the result; proceeding after ``False`` races in-flight work.

        ``raise_on_error``: after the wait, raise ONE ``StagingError``
        carrying *every* task failure recorded since the last raising
        drain, sorted — deterministic across thread interleavings, where
        checking ``last_error`` after a drain was first-error-wins (the
        pool ablation runs failures concurrently, so which error a
        single-slot report surfaced was a race)."""
        deadline = time.time() + timeout
        clean = True
        with self._cv:
            while self._pending or self._inflight:
                remaining = deadline - time.time()
                if remaining <= 0:
                    clean = False
                    break
                self._cv.wait(timeout=remaining)
            failures = None
            if raise_on_error and self._failures:
                failures = sorted(self._failures)
                self._failures.clear()
        if failures is not None:
            raise StagingError(
                f"{len(failures)} I/O task(s) failed: "
                + "; ".join(failures))
        return clean

    def shutdown(self) -> None:
        self._stop = True
        with self._cv:
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5)
        if self._pool is not None:
            self._pool.shutdown(wait=True)


class _CommitCoalescer:
    """Group-commits the WAL across I/O tasks.

    Without it, every spill batch and every late-write task pays its own
    ``store.commit()`` (flush + fsync + WAL ack). With it, writer tasks
    append their records, register a *finalizer*, and return; one
    deferred flush task per batch issues a single commit and then runs
    every finalizer with the commit outcome (``ok=False`` on a commit
    failure — finalizers must not acknowledge durability then). FIFO
    order within the flush priority class means every put queued before
    the flush ran is covered by its commit."""

    def __init__(self, scheduler: "IOScheduler", priority: int):
        self.sched = scheduler
        self.priority = priority
        self._lock = threading.Lock()
        self._fins: List[Callable[[bool], None]] = []
        self._flush_queued = False
        self.stats = {"coalesced_commits": 0, "joined_tasks": 0}

    def after_commit(self, fin: Callable[[bool], None]) -> None:
        """Run ``fin(ok)`` after the next group commit (covering every
        record the caller already appended). Queues one flush task per
        batch."""
        with self._lock:
            self._fins.append(fin)
            self.stats["joined_tasks"] += 1
            if self._flush_queued:
                return
            self._flush_queued = True
        self.sched.submit(self.priority, self._flush)

    def _flush(self) -> None:
        with self._lock:
            fins = self._fins
            self._fins = []
            self._flush_queued = False
        if not fins:
            return
        ok = False
        try:
            # transient commit failures retry within this flush (the
            # finalizers below must only see ok=False when the budget is
            # really exhausted — an unwound spill re-queues host copies
            # for a later pass)
            self.sched._with_retries(self.sched.store.commit, "commit")
            ok = True
            self.stats["coalesced_commits"] += 1
        finally:
            # on failure the exception propagates to the flush task's
            # handle/stats; finalizers still run with ok=False so
            # deferred-spill accounting unwinds and no host copy is
            # dropped without durability
            for fin in fins:
                try:
                    fin(ok)
                except Exception as exc:       # keep remaining finalizers
                    self.sched._record_error(exc)


class IOScheduler:
    """Single-threaded prioritized transfer executor.

    ``sequential_io=False`` reproduces the paper's *no-sqntl-io* ablation:
    transfers are issued on a pool with no global ordering or priorities.
    ``simulated_seconds_per_byte`` adds virtual I/O cost accounting so
    benchmarks can model a slow persistent tier deterministically.
    """

    def __init__(self, budget: MemoryBudget, *, sequential_io: bool = True,
                 chunk_blocks: int = 4, spill_dir: Optional[Path] = None,
                 host_budget_bytes: Optional[int] = None,
                 simulated_seconds_per_byte: float = 0.0,
                 pool=None, store: Optional[BlockStore] = None,
                 compact_ratio: float = 2.0,
                 executor: Optional[TransferExecutor] = None,
                 tenant: str = "default", io_weight: int = 1,
                 owns_store: bool = True, wal_coalesce: bool = False,
                 io_retry_limit: int = 4, io_retry_backoff: float = 0.01,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.budget = budget
        # one metrics registry + tracer per engine stack: adopt the shared
        # executor's registry when multiplexed (multi-tenant), else build
        # or accept a private one. Tracing defaults to OFF (rate 0) when
        # no tracer is handed down.
        if registry is None:
            registry = executor.registry if executor is not None \
                else MetricsRegistry()
        self.registry = registry
        self.tracer = tracer if tracer is not None else Tracer()
        # the executor may be SHARED across schedulers (multi-tenant
        # engines multiplex one transfer thread): this scheduler's tasks
        # are tagged with its tenant name and served weighted round-robin
        # within each priority class. A private executor is built (and
        # later shut down) by this scheduler when none is passed.
        self._owns_executor = executor is None
        if executor is None:
            executor = TransferExecutor(sequential_io=sequential_io,
                                        registry=registry)
        self.executor = executor
        self.tenant = tenant
        self.sequential_io = executor.sequential_io
        executor.set_weight(tenant, io_weight)
        self._owns_store = owns_store
        self.chunk_blocks = max(chunk_blocks, 1)
        self.spill_dir = spill_dir
        self.host_budget_bytes = host_budget_bytes
        self.sim_spb = simulated_seconds_per_byte
        self.compact_ratio = compact_ratio
        # persistent tier of the p-bucket: a BlockStore (the engine
        # builds one per AionConfig.store_backend); a bare spill_dir
        # keeps the legacy file-per-block npz semantics
        if store is None and spill_dir is not None:
            from repro.storage import NpzBlockStore
            store = NpzBlockStore(spill_dir,
                                  sim_spb=simulated_seconds_per_byte)
        self.store = store
        # the simulated-cost model lives behind the store interface so
        # every backend prices transfers identically (zero-byte
        # transfers are free by contract); engines without a storage
        # tier still charge destage/late-write costs through a local
        # model
        if store is not None:
            if simulated_seconds_per_byte \
                    and not store.simcost.seconds_per_byte:
                store.simcost.seconds_per_byte = simulated_seconds_per_byte
            self.simcost = store.simcost
        else:
            self.simcost = SimulatedCost(simulated_seconds_per_byte)
        # persistent device block pool (core/block_pool.py); None keeps
        # the legacy per-block device_put staging path
        self.pool = pool
        # registry-backed stats (labelled by tenant so multi-tenant
        # schedulers sharing one registry keep distinct series); the
        # legacy dict API (`stats["staged_blocks"]`) still works, hot
        # increments below use the atomic `.inc()`
        self.stats = StatsMap(registry, "aion_io",
                              labels={"tenant": tenant})
        self.stats.register_many([
            "staged_blocks", "destaged_blocks", "late_write_blocks",
            "stage_seconds", "destage_seconds",
            "stage_events", "simulated_io_seconds",
            "preemptions", "pool_fills", "pool_fallbacks",
            "errors",
            # self-healing path: transient store failures retried (and
            # recovered), retry budgets exhausted (the failure then
            # surfaced honestly), speculative readahead shed instead of
            # retried to exhaustion (the contract calls it best-effort)
            "retries", "gave_up", "readahead_shed",
        ])
        self.stats.register_raw("last_error", None)
        # per-task latency histogram, labelled by priority class
        self._task_hist = registry.histogram(
            "aion_io_task_seconds", "I/O task run time by priority class",
            labelnames=("tenant", "class"))
        # transient-failure retry budget (AionConfig.io_retry_limit /
        # io_retry_backoff); the jitter RNG is seeded per scheduler so
        # fault-injection runs are reproducible
        self.io_retry_limit = max(int(io_retry_limit), 0)
        self.io_retry_backoff = io_retry_backoff
        self._retry_rng = random.Random(0)
        # circuit breaker on store health (core.health.StoreHealth);
        # attached by the engine when the degradation ladder is on
        self.health = None
        self._host_bytes = 0
        # bytes whose spill records are appended but whose group commit
        # (and host-copy drop) is deferred to a coalesced flush —
        # _maybe_spill subtracts them so it doesn't re-spill the same
        # pressure every pass while a flush is queued
        self._pending_spill_bytes = 0
        # WAL commit coalescing across I/O tasks (spills + late writes
        # share one fsync); only meaningful on durable sequential-io
        # stores — the thread-pool ablation has no FIFO commit cover
        self._coalescer: Optional[_CommitCoalescer] = None
        if wal_coalesce and store is not None and store.durable_writes \
                and self.sequential_io:
            self._coalescer = _CommitCoalescer(self, PRIO_LATE_WRITE)
        # spill candidates, cold first (deque: the spill loop pops the
        # head, O(1) instead of list.pop(0)'s O(n))
        self._host_lru: Deque[Block] = deque()
        # guards _host_bytes/_host_lru: both the executor thread and the
        # engine main thread (sync stage calls, demand host reads) account
        # here. Ordering: block.lock may be held when taking _host_lock,
        # never the reverse.
        self._host_lock = threading.Lock()

    # ------------------------------------------------------------- submit
    def submit(self, priority: int, fn: Callable,
               span=NULL_SPAN) -> TaskHandle:
        """Queue ``fn`` at ``priority``, tagged with this scheduler's
        tenant. The returned ``TaskHandle`` is an Event (legacy waiters
        keep working) that additionally carries the task's failure —
        demand waiters call ``check()``/``wait_checked()`` so a failed
        stage aborts the dependent fold instead of folding stale tiers.

        ``span``: the task's trace span (created by the request_*
        methods BEFORE the closure so retries inside it can record
        events). The wrapper marks queue->dispatch, observes the task
        latency histogram by priority class, and ends the span when the
        task finishes on the executor thread."""
        hist = self._task_hist.labels(self.tenant,
                                      PRIO_NAMES.get(priority, str(priority)))

        def run():
            span.event("dispatch")
            t0 = time.time()
            try:
                fn()
            except BaseException as exc:
                span.set(error=type(exc).__name__)
                raise
            finally:
                hist.observe(time.time() - t0)
                span.end()
        return self.executor.submit(priority, run, tenant=self.tenant,
                                    on_error=self._record_error)

    def _task_span(self, parent, name: str, **attrs):
        """Child span for one I/O task (NULL when the parent is unsampled
        or absent — I/O spans never start their own trace)."""
        return self.tracer.child(parent, "io." + name,
                                 tenant=self.tenant, **attrs)

    def _record_error(self, exc: BaseException) -> None:
        self.stats.inc("errors")
        self.stats["last_error"] = f"{type(exc).__name__}: {exc}"

    # ------------------------------------------------------------- retries
    def _with_retries(self, fn: Callable, op: str,
                      shed_ok: bool = False, span=NULL_SPAN) -> Any:
        """Run a store operation with the transient-failure retry budget.

        Transient failures (``storage.is_transient_error``) retry up to
        ``io_retry_limit`` times with exponential backoff + jitter;
        permanent failures and exhausted budgets re-raise (PR 6's honest
        surfacing — a waiter still sees the real error). ``shed_ok``
        marks *speculative* work (readahead sweeps): instead of raising
        on an exhausted/transient failure the operation is SHED (returns
        None, counted in ``stats['readahead_shed']``) — the store
        contract calls readahead best-effort, and a demand load will
        still fetch the data with its own retry budget."""
        attempt = 0
        while True:
            try:
                return fn()
            except BaseException as exc:
                transient = is_transient_error(exc)
                if transient and attempt < self.io_retry_limit:
                    attempt += 1
                    self.stats.inc("retries")
                    delay = self.io_retry_backoff * (2 ** (attempt - 1))
                    if delay > 0:
                        delay *= 0.5 + self._retry_rng.random()  # jitter
                    span.event("retry", op=op, attempt=attempt,
                               delay=round(delay, 6),
                               error=type(exc).__name__)
                    if delay > 0:
                        time.sleep(delay)
                    continue
                if transient and shed_ok:
                    self.stats.inc("readahead_shed")
                    self._record_error(exc)
                    span.event("shed", op=op, error=type(exc).__name__)
                    return None
                if transient:
                    self.stats.inc("gave_up")
                    span.event("gave_up", op=op, attempts=attempt)
                raise

    @property
    def last_error(self) -> Optional[str]:
        """Most recent task failure of THIS scheduler (None if clean)."""
        return self.stats["last_error"]

    def has_higher_priority_pending(self, priority: int) -> bool:
        return self.executor.has_higher_priority_pending(priority)

    def host_bytes_tracked(self) -> int:
        """The host-tier byte figure this scheduler already maintains
        (``_account_host``/spill bookkeeping): destaged + storage-loaded
        host copies. O(1) — metric polls use this instead of re-summing
        every window's blocks per poll. (Fresh ingest-tier host blocks
        are not in it until they first destage; ``StreamEngine.
        host_bytes()`` stays the exact full-sum for callers that need
        that.)"""
        with self._host_lock:
            return self._host_bytes

    def drain(self, timeout: float = 30.0,
              raise_on_error: bool = False) -> bool:
        """Block until the executor's queue is empty and no task is
        mid-run — in BOTH modes (the thread-pool ablation tracks
        in-flight tasks through the same counter).

        Returns ``True`` on a clean drain, ``False`` on timeout. Callers
        that require an empty queue (engine close, checkpoint) must not
        proceed on ``False`` — a checkpoint taken then would race
        in-flight spills. ``raise_on_error`` raises ONE ``StagingError``
        listing every task failure since the last raising drain (see
        ``TransferExecutor.drain``). NOTE: with a shared executor
        (multi-tenant) this waits for ALL tenants' queues, which is what
        the barrier callers need."""
        return self.executor.drain(timeout, raise_on_error=raise_on_error)

    def shutdown(self) -> None:
        if self._owns_executor:
            self.executor.shutdown()
        if self.store is not None and self._owns_store:
            self.store.close()         # final group commit + handles

    # ------------------------------------------------------------ transfers
    def _simulate_io(self, nbytes: int) -> None:
        """Model a slow persistent tier deterministically through the
        store's cost model (one channel: the transfer thread really
        sleeps, so scheduling — priorities, preemption, pre-staging lead
        time — decides who stalls, not host noise). Zero-byte transfers
        (empty blocks) are never charged."""
        if nbytes <= 0:
            return
        self.stats.inc("simulated_io_seconds", self.simcost.charge(nbytes))

    @staticmethod
    def _cost_bytes(block: Block) -> int:
        """Billable transfer size: an empty block moves no event data."""
        return block.nbytes if block.fill > 0 else 0

    def stage_block_sync(self, block: Block,
                         shard: Optional[int] = None,
                         span=NULL_SPAN) -> bool:
        """p->m: move one block to device. Returns False if budget full.

        With a block pool the transfer is an arena fill: allocate a pool
        slot (state free -> filling, in ``shard``'s range when the pooled
        fold is sharded) and dynamic-update-slice the block's keys/values
        into the arena (filling -> resident). A pooled fill costs the
        slot — its bytes were reserved once, at arena construction — so
        there is no per-block budget round-trip. Pool-range exhaustion
        falls back to the legacy per-block ``device_put`` (which DOES
        reserve) — the block is still device-resident, it just rides the
        stacked gather instead of the block table.
        """
        if block.tier == Tier.DEVICE:
            return True
        slot = None
        if self.pool is not None and block.capacity == self.pool.capacity \
                and block.width == self.pool.width:
            slot = self.pool.alloc(shard)
            if slot is None:
                self.stats.inc("pool_fallbacks")
        reserved = False
        if slot is None:
            if not self.budget.try_reserve(block.nbytes):
                return False
            reserved = True

        def fail() -> bool:
            if slot is not None:
                self.pool.free(slot)           # never attached to the block
            if reserved:
                self.budget.release(block.nbytes)
            return False

        t0 = time.time()
        if block.tier == Tier.STORAGE:
            # load under the block lock: a concurrent purge tombstones
            # the store record and would otherwise strand the
            # slot/reservation we hold
            with block.lock:
                if block.dropped or not block.in_storage:
                    return fail()
                try:
                    # transient store read failures retry; an exhausted
                    # budget surrenders the slot/reservation BEFORE
                    # surfacing (otherwise the pool leaks a slot per
                    # failed stage under sustained faults)
                    self._with_retries(block.as_event_batch, "get",
                                       span=span)
                except BaseException:
                    fail()
                    raise
                self._account_host(block)
        host_data = block.host_data
        if host_data is None:
            # block was purged (predictive cleanup) while this stage
            # request was queued — surrender the slot/reservation and skip
            return fail()

        device_data = None
        if slot is None:
            device_data = {
                k: jax.device_put(v) for k, v in host_data.items()}
            for v in device_data.values():
                v.block_until_ready()
        # commit under the block lock: if predictive cleanup dropped the
        # block while the transfer was in flight, the slot/reservation is
        # ours to surrender (the purge only accounts blocks ALREADY on
        # device)
        with block.lock:
            if block.dropped:
                return fail()
            if block.tier == Tier.DEVICE:
                # a concurrent stager (prestage racing a demand stage on
                # the thread-pool ablation) committed first: surrender
                # our duplicate slot/reservation — overwriting would
                # orphan the winner's slot (or double-charge the budget)
                fail()
                return True
            if slot is not None:
                # arena write + slot attach, from the host arrays read
                # above (not block.host_data — a racing spill may have
                # nulled it since)
                self.pool.commit(block, slot, host_data)
                self.stats.inc("pool_fills")
            else:
                block.device_data = device_data
            block.tier = Tier.DEVICE
        if block.persisted:       # reads from the persistent tier pay I/O;
            self._simulate_io(self._cost_bytes(block))  # ingest is direct
        self.stats.inc("staged_blocks")
        self.stats.inc("stage_events", block.fill)
        self.stats.inc("stage_seconds", time.time() - t0)
        return True

    def destage_block_sync(self, block: Block) -> None:
        """m->p: move one block back to host (keeping the host copy is the
        'serialization' step; device buffers are dropped afterwards)."""
        t0 = time.time()
        with block.lock:
            if block.tier != Tier.DEVICE or block.dropped:
                # dropped: the purge already released the device bytes
                return
            was_pooled = block.pool_slot is not None
            if block.host_data is None:
                if block.device_data is not None:
                    block.host_data = {
                        k: np.asarray(v)
                        for k, v in block.device_data.items()}
                elif block.in_storage:
                    # a racing spill wrote the REAL arrays (incl.
                    # timestamps, which the arena does not carry) to
                    # storage; prefer them over a pool read that would
                    # fabricate zero timestamps and later overwrite the
                    # genuine ones on re-spill
                    self._with_retries(block._load_from_storage, "get")
                elif was_pooled:
                    block.host_data = self.pool.read_host(block)
            if was_pooled:
                # resident -> destaged: the slot returns to the free list
                # (the slot IS the pooled block's device accounting — no
                # budget release, the arena reservation is permanent)
                self.pool.release_slot(block)
            block.device_data = None
            block.tier = Tier.HOST
            block.persisted = True
        self._account_host(block)
        if not was_pooled:
            self.budget.release(block.nbytes)
        self._simulate_io(self._cost_bytes(block))
        self.stats.inc("destaged_blocks")
        self.stats.inc("destage_seconds", time.time() - t0)
        self._maybe_spill()

    def _account_host(self, block: Block) -> None:
        """Idempotent host-tier accounting: count a block's host copy
        once and register it as a spill candidate once. Staging keeps
        host copies resident, so a destage/stage/destage round-trip (the
        pooled cold path does one per re-execution) must not re-count
        the same bytes or duplicate the LRU entry; the flag resets when
        a spill actually evicts the copy. A re-destaged block keeps its
        original LRU position (no O(n) refresh — a stale-cold entry just
        spills early, which is safe)."""
        with self._host_lock:
            if block.host_accounted:
                return
            block.host_accounted = True
            self._host_bytes += block.nbytes
            if self.store is not None and not block.in_spill_lru:
                block.in_spill_lru = True
                self._host_lru.append(block)

    def _maybe_spill(self) -> None:
        """Enforce the host budget by spilling cold host blocks to the
        persistent store. Candidates are registered by ``_account_host``
        in first-destage order (oldest = coldest first); each pass pops
        the candidates needed to get under budget and spills them as ONE
        group commit (the log store turns the batch into sequential
        appends + one fsync)."""
        if self.host_budget_bytes is None or self.store is None:
            return
        while True:
            batch: List[Block] = []
            with self._host_lock:
                # bytes already riding a deferred (coalesced) commit are
                # as good as spilled for pressure purposes — without the
                # subtraction every pass until the flush runs would
                # re-spill fresh victims for the same overage
                need = (self._host_bytes - self._pending_spill_bytes
                        - self.host_budget_bytes)
                if need <= 0 or not self._host_lru:
                    return
                while need > 0 and self._host_lru:
                    blk = self._host_lru.popleft()
                    blk.in_spill_lru = False
                    batch.append(blk)
                    need -= blk.nbytes
            self.spill_blocks_sync(batch,
                                   coalesce=self._coalescer is not None)


    def fetch_block_host(self, block: Block
                         ) -> Optional[Dict[str, np.ndarray]]:
        """Demand host-side read of a block's full-capacity arrays for
        folding. Returns None if the block was purged.

        Execution paths that fold a p-bucket block host-side (the batched
        gather; the per-window budget-full fallback) must come through
        here rather than calling ``as_event_batch`` directly: STORAGE
        loads are accounted against the host tier (otherwise the bytes
        never count and the block can never spill again), and reads of
        persisted blocks pay the simulated persistent-tier cost — the
        same price the staging path charges, so simulated-I/O ablations
        don't get free reads on one path. Deliberately no
        ``_maybe_spill``: the caller is about to read ``host_data`` and
        an immediate spill could snatch it back.
        """
        with block.lock:
            if block.dropped:
                return None
            if block.host_data is None and block.in_storage:
                self._with_retries(block.as_event_batch, "get")
                self._account_host(block)
            host_data = block.host_data
        if host_data is not None and block.persisted:
            self._simulate_io(self._cost_bytes(block))
        return host_data

    def readahead_blocks(self, blocks: List[Block],
                         span=NULL_SPAN) -> None:
        """Prefetch storage-resident blocks into the store's read cache
        in one batched, segment-sequential sweep — the demand loads that
        follow become cache hits instead of per-block random reads."""
        if self.store is None:
            return
        keys = [(b.window_key, b.block_id) for b in blocks
                if b.tier == Tier.STORAGE and not b.dropped
                and b.in_storage]
        if keys:
            # speculative: an exhausted retry budget SHEDS the sweep
            # (stats['readahead_shed']) — demand loads still fetch the
            # records with their own budget, nothing is lost but speed
            self._with_retries(lambda: self.store.readahead(keys),
                               "readahead", shed_ok=True, span=span)

    def fetch_block_arrays(self, block: Block):
        """Device-preferred read of a block's full-capacity SoA arrays
        for the batched gather.

        A device-resident (m-bucket) copy is returned as-is — the batched
        stack keeps it device-side (a device concat instead of a host
        round-trip). Pooled blocks read their arena slot (an immutable
        device slice — no host round-trip either). Cold p-blocks fall
        through to ``fetch_block_host`` so the read is accounted and
        persisted blocks pay the simulated persistent-tier cost. Returns
        None only if the block was purged.
        """
        dd = block.device_data
        if dd is not None:
            return dd
        if self.pool is not None and block.pool_slot is not None:
            d = self.pool.read_block(block)
            if d is not None:
                return d
        return self.fetch_block_host(block)

    def spill_block_sync(self, block: Block) -> None:
        self.spill_blocks_sync([block])

    def _unaccount_unspillable(self, block: Block) -> None:
        """The LRU pop consumed this block's registration but it cannot
        spill (purged, empty, or re-staged to device with its host
        shadow kept): un-account it so the next destage re-registers —
        otherwise its bytes would stay counted in _host_bytes while
        being unevictable forever."""
        with self._host_lock:
            if block.host_accounted:
                block.host_accounted = False
                self._host_bytes = max(
                    self._host_bytes - block.nbytes, 0)

    def spill_blocks_sync(self, blocks: List[Block],
                          coalesce: bool = False) -> None:
        """Spill a batch of host blocks to the persistent store under
        ONE group commit: every block's record is appended (buffered),
        the commit makes them durable, and only then are the host copies
        dropped — a crash mid-spill loses nothing, the unacknowledged
        blocks still hold their host data. A block whose exact content
        is already persistent (same fill) skips the rewrite entirely.

        ``coalesce=True`` (only the budget-pressure path passes it)
        defers the commit + finalize to the WAL coalescer so several
        spill batches and late-write tasks share one fsync; direct
        callers keep the synchronous contract (STORAGE tier on
        return)."""
        if self.store is None:
            return
        staged: List[Block] = []
        try:
            for block in blocks:
                # put under the block lock so a concurrent purge can't
                # clear host_data mid-write or have its tombstone undone
                # by a spill that resurrects the record for a dead block
                with block.lock:
                    if block.dropped or block.tier != Tier.HOST \
                            or block.fill == 0:
                        self._unaccount_unspillable(block)
                        continue
                    self._with_retries(
                        lambda b=block: b.put_to_store(self.store), "put")
                staged.append(block)
        except BaseException:
            # exhausted/permanent put: the batch's still-accounted host
            # copies (including the one that failed) go back on the
            # candidate list so they stay evictable, then surface
            self._requeue_spill(staged + [block])
            raise
        if not staged:
            return
        if coalesce and self._coalescer is not None:
            deferred = sum(b.nbytes for b in staged)
            with self._host_lock:
                self._pending_spill_bytes += deferred

            def fin(ok: bool, staged=staged, deferred=deferred) -> None:
                with self._host_lock:
                    self._pending_spill_bytes = max(
                        self._pending_spill_bytes - deferred, 0)
                self._finalize_spill(staged, ok)
            self._coalescer.after_commit(fin)
            return
        try:
            # durability barrier (transient failures retry first)
            self._with_retries(self.store.commit, "commit")
        except BaseException:
            self._requeue_spill(staged)
            raise
        self._finalize_spill(staged, True)

    def _requeue_spill(self, blocks: List[Block]) -> None:
        """Return failed-spill host copies to the candidate list EXACTLY
        once each: the ``in_spill_lru`` membership flag makes the
        re-queue idempotent, so two failing coalesced flushes covering
        the same block (overlapping batches, or a direct spill of a
        block still on the list) cannot duplicate its LRU entry — and
        ``host_accounted`` stays untouched, so ``_host_bytes`` is never
        double-registered."""
        with self._host_lock:
            for block in blocks:
                if block.host_accounted and not block.in_spill_lru:
                    block.in_spill_lru = True
                    self._host_lru.append(block)

    def _finalize_spill(self, staged: List[Block], ok: bool) -> None:
        """Post-commit half of a spill: drop host copies and flip tiers.
        ``ok=False`` (a coalesced commit failed) keeps every host copy —
        durability was not achieved, so the blocks go back on the spill
        candidate list for a later retry."""
        if not ok:
            self._requeue_spill(staged)
            return
        total = 0
        for block in staged:
            with block.lock:
                if block.dropped or block.tier != Tier.HOST:
                    # a purge or re-stage landed between the commit and
                    # this finalize: the record stays (purge already
                    # tombstoned it if it ran), the residency is theirs
                    self._unaccount_unspillable(block)
                    continue
                nbytes = block.nbytes
                block.host_data = None
                block.tier = Tier.STORAGE
                block.persisted = True
            with self._host_lock:
                if block.host_accounted:
                    block.host_accounted = False
                    self._host_bytes = max(self._host_bytes - nbytes, 0)
            total += nbytes
        self._simulate_io(total)

    # ------------------------------------------------------- bulk requests
    def shard_of(self, window: WindowState) -> Optional[int]:
        """Pool shard hint for a window's blocks (None without a sharded
        pool): the same stable window -> shard map the batch executor's
        pooled placement uses, so a window's arena slots always land in
        the range of the device that will fold its block-table rows."""
        if self.pool is None or self.pool.num_shards <= 1:
            return None
        from repro.distributed.sharding import shard_of_window
        return shard_of_window(window.window_start, window.window_end,
                               self.pool.num_shards)

    def request_stage(self, window: WindowState,
                      blocks: Optional[List[Block]] = None,
                      demand: bool = False,
                      parent=None) -> threading.Event:
        """Queue staging of a window's p-blocks, in chunks so independent
        DMAs can overlap (multithread-serialization analog). ``demand``:
        an executing operator is blocked on these blocks — outranks
        speculative pre-staging. With a block pool these are pool fills
        (demand fills are what the batch executor overlaps with the fold
        of the already-resident shard)."""
        blocks = blocks if blocks is not None else window.p_blocks()
        shard = self.shard_of(window)
        span = self._task_span(
            parent, "demand_stage" if demand else "stage",
            window=_wkey(window), blocks=len(blocks))

        def do():
            store = self.store
            if span and store is not None:
                h0 = store.stats.get("readahead_hits", 0)
                m0 = store.stats.get("readahead_misses", 0)
            # batched store readahead first: the per-block loads below
            # then read sequentially-swept cache entries, not one random
            # record each (the proactive-caching path's storage half)
            self.readahead_blocks(blocks, span=span)
            staged = 0
            for blk in blocks:
                if self.stage_block_sync(blk, shard=shard, span=span):
                    staged += 1
            if span and store is not None:
                span.set(
                    staged=staged,
                    readahead_hits=store.stats.get("readahead_hits", 0) - h0,
                    readahead_misses=store.stats.get(
                        "readahead_misses", 0) - m0)
        return self.submit(PRIO_DEMAND_STAGE if demand else PRIO_STAGE, do,
                           span=span)

    def request_readahead(self, window: WindowState,
                          parent=None) -> threading.Event:
        """Queue a storage-only readahead for a window's spilled blocks
        (no host/device residency change): proactive caching drives this
        ahead of the actual pre-stage, so the store's sequential sweep
        runs before the staging deadline instead of inside it."""
        blocks = [b for b in window.blocks if b.tier == Tier.STORAGE]
        span = self._task_span(parent, "readahead",
                               window=_wkey(window), blocks=len(blocks))

        def do():
            self.readahead_blocks(blocks, span=span)
        return self.submit(PRIO_READAHEAD, do, span=span)

    def request_segment_readahead(self, sid: int, keys: List,
                                  on_swept: Optional[Callable] = None,
                                  priority: int = PRIO_READAHEAD,
                                  parent=None) -> threading.Event:
        """Queue ONE sequential sweep over log segment ``sid`` caching
        ``keys``'s records (the learned planner's unit of readahead).
        ``on_swept(seconds, nbytes)`` feeds the measured sweep back into
        the planner's bandwidth model. ``priority`` defaults to the
        speculative readahead class; the pipelined prefetch hook passes
        ``PRIO_STAGE`` so its sweeps run (FIFO) before the stage tasks
        they feed."""
        span = self._task_span(parent, "segment_readahead",
                               segment=sid, keys=len(keys))

        def do():
            if self.store is None:
                return
            before = self.store.stats.get("sweep_bytes_read", 0)
            t0 = time.time()
            # speculative — shed on exhausted transient failures, like
            # readahead_blocks (the demand path still fetches)
            if self._with_retries(
                    lambda: self.store.readahead_segments(sid, keys),
                    "readahead", shed_ok=True, span=span) is None:
                return
            if on_swept is not None:
                nbytes = self.store.stats.get("sweep_bytes_read", 0) \
                    - before
                if nbytes > 0:
                    on_swept(time.time() - t0, nbytes)
        return self.submit(priority, do, span=span)

    def request_coalesce(self, window_keys: List) -> Optional[threading.Event]:
        """Queue a storage-layout coalescing pass (background priority):
        rewrite the given windows' scattered records into contiguous
        runs so their predicted re-stages become single dense sweeps."""
        if self.store is None:
            return None

        def do():
            n = self.store.coalesce_windows(window_keys)
            if n:
                self.stats.inc("coalesced_windows", n)
        return self.submit(PRIO_DESTAGE, do)

    def request_compaction(self, max_ratio: Optional[float] = None
                           ) -> Optional[threading.Event]:
        """Queue background compaction (lowest priority): commit any
        pending tombstones, then reclaim dead log space until the store
        is back under its ratio bound. Driven by the engine after
        predictive-cleanup purges."""
        if self.store is None:
            return None
        ratio = self.compact_ratio if max_ratio is None else max_ratio

        def do():
            self._with_retries(self.store.commit, "commit")
            reclaimed = self.store.compact_if_needed(ratio)
            if reclaimed:
                self.stats.inc("compacted_bytes", reclaimed)
        return self.submit(PRIO_DESTAGE, do)

    def request_destage(self, window: WindowState,
                        keep_bootstrap: int = 0,
                        parent=None) -> threading.Event:
        """Queue destaging (background, lowest priority). Preemptible: the
        executor checks for higher-priority work between chunks."""
        span = self._task_span(parent, "destage", window=_wkey(window))

        def do():
            m = window.m_blocks()
            keep = set(id(b) for b in m[:keep_bootstrap])
            pending = [b for b in m if id(b) not in keep]
            i = 0
            while i < len(pending):
                chunk = pending[i:i + self.chunk_blocks]
                for blk in chunk:
                    self.destage_block_sync(blk)
                i += len(chunk)
                if self.sequential_io and \
                        self.has_higher_priority_pending(PRIO_DESTAGE):
                    # re-queue the remainder and yield (preemption)
                    self.stats.inc("preemptions")
                    span.event("preempted", remaining=len(pending) - i)
                    rest = pending[i:]
                    if rest:
                        self.submit(PRIO_DESTAGE,
                                    lambda r=rest: [self.destage_block_sync(b)
                                                    for b in r])
                    return
        return self.submit(PRIO_DESTAGE, do, span=span)

    def request_late_write(self, window: WindowState, blocks: List[Block],
                           parent=None) -> threading.Event:
        """Late events were appended host-side; this acknowledges/persists
        them at middle priority (and spills if the host tier is over
        budget).

        With a durable store (the log backend) the write is REAL: the
        blocks' records group-commit into the value log, so acknowledged
        late events survive a crash even before any checkpoint. The host
        copy stays resident (tier unchanged) — the record is the
        p-bucket's persistent shadow. The legacy npz backend keeps the
        seed behaviour (flag + simulated cost only)."""
        durable = self.store is not None and self.store.durable_writes
        span = self._task_span(parent, "late_write",
                               window=_wkey(window), blocks=len(blocks),
                               durable=durable)

        def do():
            self.stats.inc("late_write_blocks", len(blocks))
            total = 0
            wrote: List[Block] = []
            for blk in blocks:
                with blk.lock:
                    if blk.dropped:
                        continue
                    if durable and blk.fill > 0 \
                            and blk.host_data is not None:
                        self._with_retries(
                            lambda b=blk: b.put_to_store(self.store),
                            "put", span=span)
                    wrote.append(blk)
                total += self._cost_bytes(blk)

            def fin(ok: bool) -> None:
                if not ok:
                    return       # commit failed: nothing is acknowledged
                for blk in wrote:
                    with blk.lock:
                        if not blk.dropped:
                            blk.persisted = True  # landed in p-bucket
                self._simulate_io(total)
            if durable and self._coalescer is not None:
                # join the coalesced group commit: one fsync covers this
                # late write and any spill batches queued around it
                span.event("coalesced_commit_joined")
                self._coalescer.after_commit(fin)
            else:
                if durable:
                    self._with_retries(self.store.commit, "commit",
                                       span=span)
                fin(True)
        return self.submit(PRIO_LATE_WRITE, do, span=span)
