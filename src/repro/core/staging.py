"""Staging/destaging: the single prioritized I/O executor (paper §4).

All tier transfers flow through one executor thread that serializes and
prioritizes requests: **staging (p->m) > late-event writes > destaging
(m->p)** — staging data is needed imminently by an executing operator,
while destaging is a background memory-saving activity. Destage operations
are *preemptible at block granularity*: between blocks the executor yields
to any queued higher-priority work (the paper's "interleaved" operations).

TPU adaptation of the serialization ablations (§5 Q3):
  * multithreaded JSON serialization  ->  chunked multi-buffer transfers
    (``chunk_blocks`` blocks per DMA) vs one monolithic transfer
  * single sequential I/O thread      ->  ``sequential_io=True`` (one
    executor) vs a thread pool issuing transfers concurrently
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.buckets import Block, MemoryBudget, Tier, WindowState

PRIO_DEMAND_STAGE = -1    # staging an operator is *blocked on* right now
PRIO_STAGE = 0            # proactive pre-staging
PRIO_LATE_WRITE = 1
PRIO_DESTAGE = 2


@dataclass(order=True)
class _Task:
    priority: int
    seq: int
    fn: Callable = field(compare=False)
    done: threading.Event = field(compare=False,
                                  default_factory=threading.Event)


class IOScheduler:
    """Single-threaded prioritized transfer executor.

    ``sequential_io=False`` reproduces the paper's *no-sqntl-io* ablation:
    transfers are issued on a pool with no global ordering or priorities.
    ``simulated_seconds_per_byte`` adds virtual I/O cost accounting so
    benchmarks can model a slow persistent tier deterministically.
    """

    def __init__(self, budget: MemoryBudget, *, sequential_io: bool = True,
                 chunk_blocks: int = 4, spill_dir: Optional[Path] = None,
                 host_budget_bytes: Optional[int] = None,
                 simulated_seconds_per_byte: float = 0.0):
        self.budget = budget
        self.sequential_io = sequential_io
        self.chunk_blocks = max(chunk_blocks, 1)
        self.spill_dir = spill_dir
        self.host_budget_bytes = host_budget_bytes
        self.sim_spb = simulated_seconds_per_byte
        self._seq = itertools.count()
        self._queue: List[_Task] = []
        self._cv = threading.Condition()
        self._stop = False
        self.stats = {
            "staged_blocks": 0, "destaged_blocks": 0, "late_write_blocks": 0,
            "stage_seconds": 0.0, "destage_seconds": 0.0,
            "stage_events": 0, "simulated_io_seconds": 0.0,
            "preemptions": 0,
        }
        self._host_bytes = 0
        self._host_lru: List[Block] = []      # spill candidates, cold first
        # guards _host_bytes/_host_lru: both the executor thread and the
        # engine main thread (sync stage calls, demand host reads) account
        # here. Ordering: block.lock may be held when taking _host_lock,
        # never the reverse.
        self._host_lock = threading.Lock()
        self._sim_lock = threading.Lock()     # one persistent-tier channel
        if sequential_io:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
            self._pool = None
        else:
            self._thread = None
            self._pool = ThreadPoolExecutor(max_workers=4)

    # ------------------------------------------------------------- submit
    def submit(self, priority: int, fn: Callable) -> threading.Event:
        if self._pool is not None:                     # no-sqntl-io ablation
            ev = threading.Event()

            def wrap():
                fn()
                ev.set()
            self._pool.submit(wrap)
            return ev
        task = _Task(priority, next(self._seq), fn)
        with self._cv:
            heapq.heappush(self._queue, task)
            self._cv.notify()
        return task.done

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait(timeout=0.1)
                if self._stop and not self._queue:
                    return
                task = heapq.heappop(self._queue)
            try:
                task.fn()
            except Exception:                      # never kill the executor
                self.stats["errors"] = self.stats.get("errors", 0) + 1
            finally:
                task.done.set()

    def has_higher_priority_pending(self, priority: int) -> bool:
        with self._cv:
            return bool(self._queue) and self._queue[0].priority < priority

    def drain(self, timeout: float = 30.0) -> None:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._cv:
                if not self._queue:
                    return
            time.sleep(0.001)

    def shutdown(self) -> None:
        self._stop = True
        with self._cv:
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5)
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    # ------------------------------------------------------------ transfers
    def _simulate_io(self, nbytes: int) -> None:
        """Model a slow persistent tier deterministically: the transfer
        thread really sleeps, so scheduling (priorities, preemption,
        pre-staging lead time) — not host noise — decides who stalls."""
        if self.sim_spb > 0:
            dt = nbytes * self.sim_spb
            self.stats["simulated_io_seconds"] += dt
            with self._sim_lock:              # single channel: threads queue
                time.sleep(dt)

    def stage_block_sync(self, block: Block) -> bool:
        """p->m: move one block to device. Returns False if budget full."""
        if block.tier == Tier.DEVICE:
            return True
        if not self.budget.try_reserve(block.nbytes):
            return False
        t0 = time.time()
        if block.tier == Tier.STORAGE:
            # load under the block lock: a concurrent purge unlinks the
            # .npz and would otherwise strand the reservation we hold
            with block.lock:
                if block.dropped or block.storage_path is None:
                    self.budget.release(block.nbytes)
                    return False
                block.as_event_batch()                # load from file
                with self._host_lock:
                    self._host_bytes += block.nbytes
        host_data = block.host_data
        if host_data is None:
            # block was purged (predictive cleanup) while this stage request
            # was queued — drop the reservation and skip
            self.budget.release(block.nbytes)
            return False
        device_data = {
            k: jax.device_put(v) for k, v in host_data.items()}
        for v in device_data.values():
            v.block_until_ready()
        # commit under the block lock: if predictive cleanup dropped the
        # block while the transfer was in flight, the reservation is ours
        # to release (the purge only accounts blocks ALREADY on device)
        with block.lock:
            if block.dropped:
                self.budget.release(block.nbytes)
                return False
            block.device_data = device_data
            block.tier = Tier.DEVICE
        if block.persisted:       # reads from the persistent tier pay I/O;
            self._simulate_io(block.nbytes)   # fresh ingest is memory-direct
        self.stats["staged_blocks"] += 1
        self.stats["stage_events"] += block.fill
        self.stats["stage_seconds"] += time.time() - t0
        return True

    def destage_block_sync(self, block: Block) -> None:
        """m->p: move one block back to host (keeping the host copy is the
        'serialization' step; device buffers are dropped afterwards)."""
        t0 = time.time()
        with block.lock:
            if block.tier != Tier.DEVICE or block.dropped:
                # dropped: the purge already released the device bytes
                return
            if block.host_data is None and block.device_data is not None:
                block.host_data = {
                    k: np.asarray(v) for k, v in block.device_data.items()}
            block.device_data = None
            block.tier = Tier.HOST
            block.persisted = True
        with self._host_lock:
            self._host_bytes += block.nbytes
        self.budget.release(block.nbytes)
        self._simulate_io(block.nbytes)
        self.stats["destaged_blocks"] += 1
        self.stats["destage_seconds"] += time.time() - t0
        self.track_host_block(block)
        self._maybe_spill()

    def _maybe_spill(self) -> None:
        """Enforce the host budget by spilling cold host blocks to storage
        (the persistent-storage tier of the p-bucket). Candidates are
        registered by ``track_host_block`` in destage order (oldest =
        coldest first)."""
        if self.host_budget_bytes is None or self.spill_dir is None:
            return
        while True:
            with self._host_lock:
                if self._host_bytes <= self.host_budget_bytes \
                        or not self._host_lru:
                    return
                blk = self._host_lru.pop(0)
            self.spill_block_sync(blk)

    def track_host_block(self, block: Block) -> None:
        """Register a host-resident block as a spill candidate."""
        if self.spill_dir is not None:
            with self._host_lock:
                self._host_lru.append(block)

    def fetch_block_host(self, block: Block
                         ) -> Optional[Dict[str, np.ndarray]]:
        """Demand host-side read of a block's full-capacity arrays for
        folding. Returns None if the block was purged.

        Execution paths that fold a p-bucket block host-side (the batched
        gather; the per-window budget-full fallback) must come through
        here rather than calling ``as_event_batch`` directly: STORAGE
        loads are accounted against the host tier (otherwise the bytes
        never count and the block can never spill again), and reads of
        persisted blocks pay the simulated persistent-tier cost — the
        same price the staging path charges, so simulated-I/O ablations
        don't get free reads on one path. Deliberately no
        ``_maybe_spill``: the caller is about to read ``host_data`` and
        an immediate spill could snatch it back.
        """
        with block.lock:
            if block.dropped:
                return None
            if block.host_data is None and block.storage_path is not None:
                block.as_event_batch()
                with self._host_lock:
                    self._host_bytes += block.nbytes
                    if self.spill_dir is not None:
                        self._host_lru.append(block)
            host_data = block.host_data
        if host_data is not None and block.persisted:
            self._simulate_io(block.nbytes)
        return host_data

    def fetch_block_arrays(self, block: Block):
        """Device-preferred read of a block's full-capacity SoA arrays
        for the batched gather.

        A device-resident (m-bucket) copy is returned as-is — the batched
        stack keeps it device-side (a device concat instead of a host
        round-trip). Cold p-blocks fall through to ``fetch_block_host``
        so the read is accounted and persisted blocks pay the simulated
        persistent-tier cost. Returns None only if the block was purged.
        """
        dd = block.device_data
        if dd is not None:
            return dd
        return self.fetch_block_host(block)

    def spill_block_sync(self, block: Block) -> None:
        if self.spill_dir is None:
            return
        # spill under the block lock so a concurrent purge can't clear
        # host_data mid-write or have its storage unlink undone by a
        # spill that resurrects the .npz for a dead block
        with block.lock:
            if block.dropped or block.tier != Tier.HOST:
                return
            nbytes = block.nbytes
            block.spill_to_storage(self.spill_dir)
        with self._host_lock:
            self._host_bytes = max(self._host_bytes - nbytes, 0)
        self._simulate_io(nbytes)

    # ------------------------------------------------------- bulk requests
    def request_stage(self, window: WindowState,
                      blocks: Optional[List[Block]] = None,
                      demand: bool = False) -> threading.Event:
        """Queue staging of a window's p-blocks, in chunks so independent
        DMAs can overlap (multithread-serialization analog). ``demand``:
        an executing operator is blocked on these blocks — outranks
        speculative pre-staging."""
        blocks = blocks if blocks is not None else window.p_blocks()

        def do():
            for blk in blocks:
                self.stage_block_sync(blk)
        return self.submit(PRIO_DEMAND_STAGE if demand else PRIO_STAGE, do)

    def request_destage(self, window: WindowState,
                        keep_bootstrap: int = 0) -> threading.Event:
        """Queue destaging (background, lowest priority). Preemptible: the
        executor checks for higher-priority work between chunks."""
        def do():
            m = window.m_blocks()
            keep = set(id(b) for b in m[:keep_bootstrap])
            pending = [b for b in m if id(b) not in keep]
            i = 0
            while i < len(pending):
                chunk = pending[i:i + self.chunk_blocks]
                for blk in chunk:
                    self.destage_block_sync(blk)
                i += len(chunk)
                if self.sequential_io and \
                        self.has_higher_priority_pending(PRIO_DESTAGE):
                    # re-queue the remainder and yield (preemption)
                    self.stats["preemptions"] += 1
                    rest = pending[i:]
                    if rest:
                        self.submit(PRIO_DESTAGE,
                                    lambda r=rest: [self.destage_block_sync(b)
                                                    for b in r])
                    return
        return self.submit(PRIO_DESTAGE, do)

    def request_late_write(self, window: WindowState, blocks: List[Block]
                           ) -> threading.Event:
        """Late events were appended host-side; this acknowledges/persists
        them at middle priority (and spills if the host tier is over
        budget)."""
        def do():
            self.stats["late_write_blocks"] += len(blocks)
            for blk in blocks:
                blk.persisted = True   # late events land in the p-bucket
                self._simulate_io(blk.nbytes)
        return self.submit(PRIO_LATE_WRITE, do)
