"""Trigger zoo (paper §2 'Triggering' + §3.4).

A trigger decides *when* a past (expired) window re-executes to fold in
late events. The engine asks ``plan(window)`` once the window expires (and
re-plans when the lateness distribution shifts); the returned offsets are
absolute seconds after expiry.

``AionStalenessTrigger`` uses the staleness optimizer with the adaptive
lateness bound from predictive cleanup: minimum executions to satisfy the
user's max-staleness SLA, placed to balance staleness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.cleanup import PredictiveCleanup
from repro.core.staleness import (
    deltaev_times, deltat_times, executions_for_bound,
    minimize_max_staleness,
)


class Trigger:
    def plan(self, horizon: float) -> np.ndarray:
        """Execution-time offsets in (0, horizon]."""
        raise NotImplementedError


@dataclass
class DeltaTTrigger(Trigger):
    """Re-execute every ``period`` seconds (punctuated periodic baseline)."""
    executions: int = 8

    def plan(self, horizon: float) -> np.ndarray:
        return deltat_times(horizon, self.executions)


@dataclass
class DeltaEvTrigger(Trigger):
    """Re-execute every N/k expected events."""
    executions: int = 8
    cleanup: Optional[PredictiveCleanup] = None

    def _delays(self, horizon: float) -> np.ndarray:
        if self.cleanup is None or self.cleanup.hist.total == 0:
            return np.linspace(0, horizon, 128)
        grid, F = self.cleanup.hist.cdf()
        # sample representative delays from the histogram CDF
        qs = (np.arange(1, 257)) / 257.0
        return np.interp(qs, F, grid) if F[-1] > 0 else grid[:128]

    def plan(self, horizon: float) -> np.ndarray:
        return deltaev_times(self._delays(horizon), horizon,
                             self.executions)


@dataclass
class AionStalenessTrigger(Trigger):
    """Minimum executions meeting ``max_staleness``, optimally placed."""
    cleanup: PredictiveCleanup
    max_staleness: float = 0.05
    k_max: int = 64
    last_k: int = field(default=0, init=False)

    def _delays(self, horizon: float) -> np.ndarray:
        if self.cleanup.hist.total == 0:
            return np.linspace(0, horizon, 128)
        grid, F = self.cleanup.hist.cdf()
        qs = (np.arange(1, 513)) / 513.0
        return np.interp(qs, F, grid) if F[-1] > 0 else grid[:128]

    def plan(self, horizon: float) -> np.ndarray:
        delays = self._delays(horizon)
        k = executions_for_bound(
            lambda kk: minimize_max_staleness(delays, horizon, kk).times,
            delays, horizon, self.max_staleness, self.k_max)
        if k is None:
            k = self.k_max
        self.last_k = k
        return minimize_max_staleness(delays, horizon, k).times
