"""Pipelined asynchronous execution + multi-tenant multiplexing.

The synchronous engine serializes three phases with no cross-window data
dependency: a watermark advance drains staging, then the batched fold
runs, then results emit. This module breaks the fence (ROADMAP item 1):

* ``EnginePipeline`` — a dedicated fold worker consuming *fold rounds*
  (the ``BatchWorkItem`` lists the engine used to execute inline) from a
  FIFO queue. ``StreamEngine.advance_watermark``/``poll`` SUBMIT rounds
  and return immediately, so ingestion keeps appending to per-shard
  arenas while the previous round's fold is in flight; emission is
  futures-based (``ResultFuture`` resolves when the round's device work
  completes, not when the Python loop returns). Rounds execute in
  submission order, which preserves the paper's priority rule at round
  granularity (live batches are submitted before late batches).

* Submit-time staging lookahead (``AionConfig.pipeline_prefetch``): when
  a round is submitted while the worker is busy, the new round's cold
  p-blocks are queued for staging at ``PRIO_STAGE`` right away — the
  running round's ``PRIO_DEMAND_STAGE`` still outranks them, but the
  I/O executor stays continuously fed, so round k+1's staging overlaps
  round k's fold instead of starting after it (Zapridou & Ailamaki's
  continuous-prefetch argument, at round granularity).

* Watermark fences shrink to the slots they close: the only
  synchronization between the main thread and an in-flight round is the
  per-pool-slot epoch scheme (``DeviceBlockPool.slot_epochs``) plus the
  purge guard (``window_in_flight``) — not a global drain.

* ``MultiTenantEngine`` — N independent keyed streams multiplexed onto
  one set of shared resources: one device budget (per-tenant
  ``TenantBudget`` caps inside it), one ``TransferExecutor`` (tenant
  tagged tasks, weighted round-robin within each priority class — the
  fairness dimension of the I/O priority lattice), one block store, one
  device arena, one fold pipeline. Tenant profiles live in
  ``configs.workloads.TENANT_PROFILES``.

Failure semantics: a round that raises (e.g. ``StagingError`` from a
failed demand fill) marks every unresolved future of that round with the
error and records it on the pipeline; ``drain(raise_on_error=True)`` —
called by ``StreamEngine.close()`` and the checkpoint path — re-raises
as ``PipelineError``. Nothing is silently absorbed.
"""
from __future__ import annotations

import contextlib
import threading
import time as _time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional

from repro.core.windows import WindowId
from repro.obs import MetricsRegistry, StatsMap, Tracer


class PipelineError(RuntimeError):
    """A submitted fold round failed (see ``EnginePipeline.drain``)."""


class ResultFuture:
    """Resolves when a submitted round's fold completes for one window."""

    __slots__ = ("_ev", "_value", "error")

    def __init__(self):
        self._ev = threading.Event()
        self._value: Any = None
        self.error: Optional[BaseException] = None

    def set_result(self, value: Any) -> None:
        self._value = value
        self._ev.set()

    def set_error(self, exc: BaseException) -> None:
        if not self._ev.is_set():
            self.error = exc
            self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._ev.wait(timeout):
            raise TimeoutError("fold round still in flight")
        if self.error is not None:
            raise PipelineError(
                f"fold round failed: {type(self.error).__name__}: "
                f"{self.error}") from self.error
        return self._value


@dataclass
class _FoldRound:
    """One submitted batch: executes via the owning engine's executor."""
    engine: Any
    items: List[Any]                       # BatchWorkItem
    now: float
    futures: Dict[WindowId, ResultFuture]
    on_done: Optional[Callable] = None     # post-fold hook (e.g. expiry)
    # submitting span (e.g. the watermark advance) — handed EXPLICITLY
    # across the worker-thread boundary so the fold span parents to it
    trace_parent: Any = None


class EnginePipeline:
    """FIFO fold-round worker shared by one or more engines."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self._cv = threading.Condition()
        self._queue: Deque[_FoldRound] = deque()
        self._inflight_wids: Dict[WindowId, int] = {}
        self._active = 0                   # rounds mid-execution
        # bounded: a long soak with recurring faults must not grow the
        # failure memory without limit; drain() reports and clears
        self._errors: Deque[BaseException] = deque(maxlen=64)
        self._stop = False
        registry = registry if registry is not None else MetricsRegistry()
        self.registry = registry
        self.stats = StatsMap(registry, "aion_pipeline")
        self.stats.register_many(["rounds", "prefetched_rounds",
                                  "round_retries", "round_retry_wins"])
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- submit
    def submit(self, engine, items, now: float,
               on_done: Optional[Callable] = None,
               trace_parent=None
               ) -> Dict[WindowId, ResultFuture]:
        """Queue one fold round; returns a future per window.

        The round folds on the worker thread via the engine's own
        ``BatchExecutor`` — safe because round membership is snapshotted
        by the executor, blocks are append-only (a block's ``fill`` is
        captured once and rows below it never mutate), and ingest only
        appends new blocks. When submitted while another round is in
        flight, the new round's cold blocks start staging immediately
        (PRIO_STAGE — outranked by the running round's demand fills)."""
        futures = {it.wid: ResultFuture() for it in items}
        # only carry a parent that is actually sampled: untraced rounds
        # then dispatch through the legacy 2-arg execute() signature
        # (tests monkeypatch it) and pay zero tracing overhead
        if trace_parent is not None \
                and not getattr(trace_parent, "sampled", False):
            trace_parent = None
        rnd = _FoldRound(engine, list(items), now, futures, on_done,
                         trace_parent)
        with self._cv:
            busy = self._active > 0 or bool(self._queue)
            self._queue.append(rnd)
            for it in items:
                self._inflight_wids[it.wid] = \
                    self._inflight_wids.get(it.wid, 0) + 1
            self._cv.notify()
        if busy and getattr(engine.aion, "pipeline_prefetch", True):
            self.stats.inc("prefetched_rounds")
            engine.prefetch_round(items, parent=trace_parent)
        return futures

    def window_in_flight(self, wid: WindowId) -> bool:
        """True while any queued/executing round references ``wid`` —
        the purge guard: predictive cleanup must not drop a window's
        blocks out from under a round that will fold them."""
        with self._cv:
            return self._inflight_wids.get(wid, 0) > 0

    @property
    def pending_rounds(self) -> int:
        with self._cv:
            return len(self._queue) + self._active

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait(timeout=1.0)
                if not self._queue:                # stopping, queue empty
                    self._cv.notify_all()
                    return
                rnd = self._queue.popleft()
                self._active += 1
            try:
                out = self._execute(rnd)
                self._complete(rnd, out)
            except BaseException as exc:
                failure: Optional[BaseException] = exc
                backup = getattr(rnd.engine, "round_backup", None)
                if backup is not None:
                    # retry the round ONCE through the backup executor:
                    # folds are pure functions of bucket contents
                    # (idempotent), so re-running after a transient
                    # stage/store failure yields the same results the
                    # first attempt would have
                    self.stats.inc("round_retries")
                    try:
                        out = self._execute(rnd, via=backup.run)
                        self._complete(rnd, out)
                        self.stats.inc("round_retry_wins")
                        failure = None
                    except BaseException as exc2:
                        failure = exc2
                if failure is not None:
                    # resolve every unresolved future with the failure
                    # and remember it for drain(): a failed demand stage
                    # aborts the round loudly instead of emitting stale
                    # results
                    for fut in rnd.futures.values():
                        fut.set_error(failure)
                    with self._cv:
                        self._errors.append(failure)
            finally:
                with self._cv:
                    self._active -= 1
                    for it in rnd.items:
                        n = self._inflight_wids.get(it.wid, 1) - 1
                        if n <= 0:
                            self._inflight_wids.pop(it.wid, None)
                        else:
                            self._inflight_wids[it.wid] = n
                    self._cv.notify_all()

    def _execute(self, rnd: _FoldRound,
                 via: Optional[Callable] = None) -> Dict:
        """Fold one round, holding the pool's deferred-fill lease:
        a donated arena write issued while the round's fold is executing
        would WAIT on the fold's usage hold (XLA donation semantics) and
        serialize the I/O thread's overlapped staging — deferring
        buffers those fills and the round's own snapshot (or the lease
        exit, after results are forced) flushes them as one scatter.
        ``via`` routes the call through a wrapper (the engine's backup
        executor on retry)."""
        pool = getattr(rnd.engine, "pool", None)
        lease = pool.deferred_fills() if pool is not None \
            else contextlib.nullcontext()
        with lease:
            if rnd.trace_parent is not None:
                fold = lambda: rnd.engine.batch_exec.execute(
                    rnd.items, rnd.now, trace_parent=rnd.trace_parent)
            else:
                fold = lambda: rnd.engine.batch_exec.execute(
                    rnd.items, rnd.now)
            return via(fold) if via is not None else fold()

    def _complete(self, rnd: _FoldRound, out: Dict) -> None:
        for it in rnd.items:
            rnd.futures[it.wid].set_result(out.get(it.wid))
        rnd.engine.metrics.pipeline_rounds += 1
        self.stats.inc("rounds")
        if rnd.on_done is not None:
            rnd.on_done()

    # -------------------------------------------------------------- drain
    def drain(self, timeout: float = 120.0,
              raise_on_error: bool = True) -> bool:
        """Wait until every submitted round has executed. Returns False
        on timeout. With ``raise_on_error`` (the close/checkpoint
        contract), any round failure recorded since the last drain
        re-raises as ``PipelineError``."""
        deadline = _time.time() + timeout
        with self._cv:
            while self._queue or self._active:
                remaining = deadline - _time.time()
                if remaining <= 0:
                    return False
                self._cv.wait(timeout=remaining)
            errors = list(self._errors)
            self._errors.clear()
        if errors and raise_on_error:
            raise PipelineError(
                f"{len(errors)} fold round(s) failed; first: "
                f"{type(errors[0]).__name__}: {errors[0]}") from errors[0]
        return True

    def close(self) -> None:
        with self._cv:
            self._stop = True
            # rounds never executed resolve their futures with an error
            # (a closed pipeline must not leave waiters hanging)
            abandoned = list(self._queue)
            self._queue.clear()
            self._cv.notify_all()
        exc = PipelineError("pipeline closed before round executed")
        for rnd in abandoned:
            for fut in rnd.futures.values():
                fut.set_error(exc)
        self._thread.join(timeout=10)


# --------------------------------------------------------------- tenancy
@dataclass
class TenantSpec:
    """Runtime description of one tenant stream (see
    ``configs.workloads.TenantProfile`` for the declarative form and
    ``MultiTenantEngine.from_profiles`` for the conversion)."""
    name: str
    assigner: Any                          # WindowAssigner
    operator: Any                          # WindowOperator
    value_width: int = 1
    weight: int = 1                        # I/O fairness weight (WRR)
    device_budget_bytes: int = 64 << 20    # tenant cap inside the shared
    host_budget_bytes: Optional[int] = None
    policy: Any = None
    trigger: Any = None
    cleanup: Any = None


class MultiTenantEngine:
    """N independent keyed streams multiplexed onto one engine's worth
    of shared resources.

    Shared: the device budget (each tenant reserves through a
    ``TenantBudget`` capped slice), the single transfer executor (tasks
    tenant-tagged; weighted round-robin within each priority class),
    the block store (safe: records key by globally-unique block ids),
    the device arena (tenants whose operator has the batch contract and
    whose value width matches the arena's), and the fold pipeline
    (rounds from all tenants serialize in submission order).

    Per tenant: a full ``StreamEngine`` — windows, watermark tracker,
    cleanup histogram, re-execution plans, metrics — so event-time
    semantics never couple across tenants.
    """

    def __init__(self, specs: List[TenantSpec], *,
                 device_budget_bytes: int = 1 << 30,
                 spill_dir=None,
                 aion=None,
                 sequential_io: bool = True,
                 simulated_seconds_per_byte: float = 0.0):
        from repro.configs.base import AionConfig
        from repro.core.buckets import MemoryBudget, TenantBudget
        from repro.core.engine import StreamEngine
        from repro.core.staging import IOScheduler, TransferExecutor
        if not specs:
            raise ValueError("MultiTenantEngine needs at least one tenant")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.aion = aion or AionConfig()
        # ONE registry + tracer for the whole multiplexed stack: per-
        # tenant series are label children, so observability() covers
        # every tenant, the shared executor, store, arena, and pipeline
        self.registry = MetricsRegistry()
        self.tracer = Tracer(sample_rate=self.aion.trace_sample_rate,
                             capacity=self.aion.trace_ring_max)
        self.budget = MemoryBudget(device_budget_bytes)
        self.store = None
        if spill_dir is not None:
            from repro.storage import make_store
            self.store = make_store(
                self.aion.store_backend, spill_dir,
                segment_bytes=self.aion.store_segment_bytes,
                sim_spb=simulated_seconds_per_byte,
                readahead_bytes=self.aion.store_readahead_bytes,
                registry=self.registry)
        self.executor = TransferExecutor(sequential_io=sequential_io,
                                         registry=self.registry)
        # one shared arena, sized for the width most tenant device
        # traffic uses; tenants with another width (or no batch
        # contract) take the legacy per-block path through their
        # TenantBudget — still correct, just unpooled
        self.pool = None
        if self.aion.block_pool and self.aion.batched_execution:
            widths = [s.value_width for s in specs
                      if s.operator.supports_batch]
            if widths:
                from repro.core.block_pool import DeviceBlockPool
                width = max(set(widths), key=widths.count)
                pool = DeviceBlockPool(
                    self.aion.pool_slots, self.aion.block_size, width,
                    max_arena_bytes=device_budget_bytes // 2,
                    registry=self.registry)
                if pool.pool_slots > 0 \
                        and self.budget.try_reserve(pool.arena_bytes):
                    self.pool = pool
        self.pipeline = EnginePipeline(registry=self.registry) \
            if self.aion.pipelined_execution else None
        self.engines: Dict[str, Any] = {}
        for spec in specs:
            budget = TenantBudget(self.budget, spec.device_budget_bytes)
            pool = self.pool if (
                self.pool is not None and spec.operator.supports_batch
                and spec.value_width == self.pool.width) else None
            io = IOScheduler(
                budget, executor=self.executor, tenant=spec.name,
                io_weight=spec.weight,
                host_budget_bytes=spec.host_budget_bytes,
                simulated_seconds_per_byte=simulated_seconds_per_byte,
                pool=pool, store=self.store, owns_store=False,
                compact_ratio=self.aion.store_compact_ratio,
                registry=self.registry, tracer=self.tracer)
            self.engines[spec.name] = StreamEngine(
                assigner=spec.assigner, operator=spec.operator,
                aion=self.aion, value_width=spec.value_width,
                policy=spec.policy, trigger=spec.trigger,
                cleanup=spec.cleanup, io=io, pipeline=self.pipeline,
                simulated_seconds_per_byte=simulated_seconds_per_byte)

    @classmethod
    def from_profiles(cls, profiles, *, device_budget_bytes: int = 1 << 30,
                      host_budget_bytes: Optional[int] = None,
                      spill_dir=None, aion=None, **kw):
        """Build from declarative ``configs.workloads.TenantProfile``
        entries: each profile's workload resolves to its operator/
        assigner and its budget fractions slice the shared totals."""
        from repro.core.operators import make_operator
        from repro.core.windows import TumblingWindows
        from repro.configs.base import AionConfig
        aion = aion or AionConfig()
        specs = []
        for p in profiles:
            w = p.workload
            width = w.resolved_value_width()
            op_kw = {"num_keys": w.num_keys} \
                if w.operator in ("stock", "lrb") else {}
            specs.append(TenantSpec(
                name=p.name,
                assigner=TumblingWindows(w.window_duration),
                operator=make_operator(w.operator, aion.block_size,
                                       width, **op_kw),
                value_width=width,
                weight=p.weight,
                device_budget_bytes=max(
                    int(device_budget_bytes * p.device_budget_frac), 1),
                host_budget_bytes=(
                    max(int(host_budget_bytes * p.host_budget_frac), 1)
                    if host_budget_bytes is not None else None)))
        return cls(specs, device_budget_bytes=device_budget_bytes,
                   spill_dir=spill_dir, aion=aion, **kw)

    # ---------------------------------------------------------- streaming
    def engine(self, tenant: str):
        return self.engines[tenant]

    def ingest(self, tenant: str, batch, now: float) -> None:
        self.engines[tenant].ingest(batch, now)

    def advance_watermark(self, wm: float, now: float,
                          tenant: Optional[str] = None) -> None:
        """Advance one tenant's watermark, or every tenant's (each
        stream has its own event-time domain and tracker)."""
        targets = [self.engines[tenant]] if tenant is not None \
            else self.engines.values()
        for eng in targets:
            eng.advance_watermark(wm, now)

    def poll(self, now: float, tenant: Optional[str] = None) -> None:
        targets = [self.engines[tenant]] if tenant is not None \
            else self.engines.values()
        for eng in targets:
            eng.poll(now)

    def results(self, tenant: str) -> Dict[WindowId, Any]:
        return dict(self.engines[tenant].results)

    def fairness_stats(self) -> Dict[str, int]:
        """Tasks the shared executor ran, by tenant."""
        return dict(self.executor.stats["tenant_executed"])

    def observability(self, export: Optional[str] = None):
        """One snapshot covering every tenant engine plus the shared
        executor, store, pool, pipeline, and tenant fairness. ``export``
        renders it: ``"prometheus"`` -> text exposition of the shared
        registry, ``"json"`` -> JSON string, ``None`` -> nested dict."""
        if export is not None:
            from repro.obs import to_json, to_prometheus
            return to_prometheus(self.registry) if export == "prometheus" \
                else to_json(self.registry)
        snap = {
            "tenants": {name: eng.observability()
                        for name, eng in self.engines.items()},
            "executor": self.executor.stats.copy(),
            "tenant_fairness": self.fairness_stats(),
            "pipeline": self.pipeline.stats.copy()
            if self.pipeline is not None else {},
            "store": self.store.stats.copy()
            if self.store is not None else {},
            "pool": self.pool.stats.copy()
            if self.pool is not None else {},
            "trace": self.tracer.stats(),
            "registry": self.registry.snapshot(),
        }
        return snap

    def close(self) -> None:
        if self.pipeline is not None:
            self.pipeline.drain(raise_on_error=True)
        for eng in self.engines.values():
            eng.close()
        if self.pipeline is not None:
            self.pipeline.close()
        self.executor.shutdown()
        if self.store is not None:
            self.store.close()

