"""The AION streaming engine (paper §3): event-time windows whose state
lives across memory tiers, with proactive caching, predictive cleanup, and
staleness-driven re-execution of past windows.

Control flow (host-side orchestration; operator folds are jit-compiled):

  ingest(batch, now)      assign -> append (policy places blocks) ->
                          late events feed cleanup histogram + re-exec plans
  advance_watermark(wm)   expire windows -> live execution -> destage
  poll(now)               due pre-staging -> due late re-executions (lower
                          priority than live work) -> predictive cleanup ->
                          global-policy pressure tick

Live executions always run before late re-executions (the paper's priority
rule); window re-execution is a pure function of bucket contents, which is
what makes straggler backup execution idempotent (distributed/fault.py).

Execution routing: when ``AionConfig.batched_execution`` is on (default)
and the operator implements the batch contract, all due windows of one
priority class fold in a single device pass through ``core.batch_exec``;
the per-window ``execute_window`` path is retained as the reference.
With ``AionConfig.slot_sharding`` on and more than one local device, that
single pass additionally partitions window slots across a 1-D mesh
(shard_map over the composite (window_slot, key) segment axis, psum-free
— slots are disjoint); see ``core.batch_exec`` for the placement step.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import AionConfig
from repro.core.batch_exec import (
    BatchExecutor, BatchWorkItem, snapshot_block_partition,
)
from repro.core.buckets import Block, MemoryBudget, Tier, WindowState
from repro.core.cleanup import PredictiveCleanup
from repro.core.events import EventBatch
from repro.core.operators import WindowOperator
from repro.core.policies import (
    EngineOOM, InMemoryPolicy, StandardPolicy, TransferPolicy,
)
from repro.core.proactive import PrestageScheduler, StagingCostModel
from repro.core.staging import IOScheduler
from repro.core.time import PeriodicWatermarkGenerator, WatermarkTracker
from repro.core.triggers import AionStalenessTrigger, Trigger
from repro.core.windows import WindowAssigner, WindowId


# BoundedSeries moved to repro.obs.registry (every telemetry surface
# shares it now); re-exported here so existing imports keep working.
from repro.obs import (BoundedSeries, MetricsRegistry, Tracer,  # noqa: E402
                       NULL_SPAN)


class EngineMetrics:
    """Engine counters, registry-backed behind the legacy attribute API.

    Every scalar below lives in a shared :class:`~repro.obs.MetricsRegistry`
    (labelled by tenant), so ``engine.observability()`` and the Prometheus
    exporter see the same numbers the legacy ``metrics.ingested += 1``
    call sites maintain — attribute reads/writes route through
    ``__getattr__``/``__setattr__`` onto the instruments and no call site
    changes. The list-valued series stay plain (bounded) lists: tests
    slice them, and ``ladder_transitions`` must support aliasing to
    ``StoreHealth.transitions``.
    """

    #: scalar field -> instrument kind
    _SCALARS = {
        "ingested": "counter", "ingested_late": "counter",
        "dropped": "counter",
        "live_executions": "counter", "late_executions": "counter",
        "purged_windows": "counter", "purged_bytes": "counter",
        "fetch_stall_seconds": "counter", "exec_seconds": "counter",
        # batched execution path: one entry per device pass
        "batch_executions": "counter", "batched_windows": "counter",
        # device passes that ran slot-sharded across a multi-device mesh
        "sharded_batch_executions": "counter",
        "batch_device_seconds": "counter",
        # batch assembly outside the fold call (row stack / table build)
        "batch_gather_seconds": "counter",
        # waiting on overlapped demand pool-fills (I/O the fold hid)
        "batch_stall_seconds": "counter",
        # block-table rows folded straight from the pool arena vs rows
        # that degraded to the stacked gather; demand fills issued by
        # the executor
        "pooled_rows": "counter", "fallback_rows": "counter",
        "demand_pool_fills": "counter",
        # pipelined execution: rounds folded by the pipeline worker;
        # rows whose pool-slot epoch moved between classification and
        # dispatch (demoted to the stacked fallback)
        "pipeline_rounds": "counter", "epoch_demoted_rows": "counter",
        # split-K chunked fold launches
        "splitk_launches": "counter",
        # self-healing ladder: current rung + per-rung shed footprint
        "degradation_level": "gauge",
        "shed_readahead_drives": "counter",
        "shed_prefetch_rounds": "counter",
        "demoted_sync_rounds": "counter",
        "deferred_events": "counter", "readmitted_events": "counter",
        # per-poll byte samples double as gauges (set by snapshot())
        "device_bytes": "gauge", "host_bytes": "gauge",
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tenant: str = "default", series_max: int = 0):
        d = self.__dict__
        if registry is None:
            registry = MetricsRegistry()
        d["registry"] = registry
        d["tenant"] = tenant
        insts = {}
        for name, kind in self._SCALARS.items():
            fam = registry.gauge(f"aion_engine_{name}",
                                 labelnames=("tenant",)) \
                if kind == "gauge" else \
                registry.counter(f"aion_engine_{name}",
                                 labelnames=("tenant",))
            insts[name] = fam.labels(tenant)
        d["_inst"] = insts
        # ladder_transitions aliases StoreHealth.transitions once the
        # engine builds its breaker (single source of truth for the shed
        # order); bounded here too for breaker-less engines
        d["ladder_transitions"] = BoundedSeries(series_max)
        d["batch_occupancy_series"] = BoundedSeries(series_max)
        d["device_bytes_series"] = BoundedSeries(series_max)
        d["host_bytes_series"] = BoundedSeries(series_max)
        # fold-round latency histogram (observed by the batch executor)
        d["fold_seconds"] = registry.histogram(
            "aion_fold_round_seconds", "device seconds per fold round",
            labelnames=("tenant",)).labels(tenant)

    def __getattr__(self, name):
        try:
            return self.__dict__["_inst"][name].value
        except KeyError:
            raise AttributeError(name) from None

    def __setattr__(self, name, value) -> None:
        inst = self.__dict__["_inst"].get(name)
        if inst is not None:
            inst.set(value)
        else:
            object.__setattr__(self, name, value)

    @classmethod
    def bounded(cls, maxlen: int) -> "EngineMetrics":
        """Metrics whose per-poll series hold at most ``maxlen`` recent
        entries (``AionConfig.metrics_series_max``) — a long-running
        engine must not leak memory through its own telemetry."""
        return cls(series_max=maxlen)

    def scalars(self) -> Dict[str, Any]:
        """Flat {field: value} view of every registry-backed scalar."""
        return {name: inst.value
                for name, inst in self.__dict__["_inst"].items()}

    def snapshot(self, now: float, device_bytes: int, host_bytes: int):
        self.device_bytes_series.append((now, device_bytes))
        self.host_bytes_series.append((now, host_bytes))
        self.device_bytes = device_bytes       # registry gauges
        self.host_bytes = host_bytes

    @property
    def mean_batch_occupancy(self) -> float:
        """Windows folded per device pass (1.0 == no batching win)."""
        if not self.batch_occupancy_series:
            return 0.0
        return float(np.mean(self.batch_occupancy_series))

    @property
    def device_seconds_per_execution(self) -> float:
        if not self.batched_windows:
            return 0.0
        return self.batch_device_seconds / self.batched_windows


@dataclass
class _ReexecPlan:
    times: List[float]          # absolute processing times
    next_idx: int = 0


class StreamEngine:
    def __init__(self, *,
                 assigner: WindowAssigner,
                 operator: WindowOperator,
                 aion: Optional[AionConfig] = None,
                 value_width: int = 1,
                 policy: Optional[TransferPolicy] = None,
                 trigger: Optional[Trigger] = None,
                 cleanup: Optional[PredictiveCleanup] = None,
                 watermark_gen: Optional[PeriodicWatermarkGenerator] = None,
                 device_budget_bytes: int = 1 << 30,
                 spill_dir: Optional[Path] = None,
                 host_budget_bytes: Optional[int] = None,
                 prestage_enabled: bool = True,
                 sequential_io: bool = True,
                 chunk_blocks: int = 4,
                 punctuated: bool = False,
                 simulated_seconds_per_byte: float = 0.0,
                 store=None,
                 io: Optional[IOScheduler] = None,
                 pipeline=None):
        self.aion = aion or AionConfig()
        self.assigner = assigner
        self.operator = operator
        self.value_width = value_width
        self._owns_io = io is None
        if io is not None:
            # shared-infrastructure mode (MultiTenantEngine): the caller
            # built the scheduler, and with it the budget, device pool
            # and store this engine must use — and owns their lifecycle
            # (close() will not shut them down). The observability plane
            # is shared the same way: adopt the scheduler's registry and
            # tracer so every tenant's metrics land in one snapshot.
            self.io = io
            self.budget = io.budget
            self.pool = io.pool
            self.store = io.store if store is None else store
            self.registry = io.registry
            self.tracer = io.tracer
        else:
            self.registry = MetricsRegistry()
            self.tracer = Tracer(
                sample_rate=self.aion.trace_sample_rate,
                capacity=self.aion.trace_ring_max)
            # persistent tier of the p-bucket: an explicit BlockStore,
            # or one built from the config backend under spill_dir
            # ('log' by default — the legacy file-per-block npz backend
            # stays available as AionConfig.store_backend='npz')
            if store is None and spill_dir is not None:
                from repro.storage import make_store
                store = make_store(
                    self.aion.store_backend, spill_dir,
                    segment_bytes=self.aion.store_segment_bytes,
                    sim_spb=simulated_seconds_per_byte,
                    readahead_bytes=self.aion.store_readahead_bytes,
                    registry=self.registry)
            self.store = store
            self.budget = MemoryBudget(device_budget_bytes)
            # persistent device block pool: staging becomes arena fills
            # and the batched fold consumes block tables (zero-copy
            # gather). The pool shards its slot ranges to the slot mesh
            # so a window's arena rows live on the device that folds
            # them. Only built when the batched path can actually
            # consume block tables — per-window engines (batching off,
            # or a no-contract operator like percentile) keep the legacy
            # device_data fast path. The arena's bytes are reserved from
            # the device budget up front; pooled fills then cost a slot,
            # not a second reservation.
            self.pool = None
            if self.aion.block_pool and self.aion.batched_execution \
                    and operator.supports_batch:
                from repro.core.block_pool import DeviceBlockPool
                shards = 1
                if self.aion.slot_sharding:
                    from repro.distributed.sharding import make_slot_mesh
                    m = make_slot_mesh(self.aion.slot_shard_devices,
                                       self.aion.slot_shard_axis)
                    shards = m.size if m is not None else 1
                # the arena may take at most HALF the budget: the legacy
                # per-block path keeps headroom, and utilization-driven
                # policies (GlobalMemoryPolicy's moderate/severe
                # thresholds) can always get below their lines by
                # destaging per-block reservations — an arena sized to
                # the full budget would pin utilization at 100% forever
                # (destaging a pooled block frees a slot, not budget
                # bytes)
                pool = DeviceBlockPool(
                    self.aion.pool_slots, self.aion.block_size,
                    value_width, num_shards=shards,
                    max_arena_bytes=device_budget_bytes // 2,
                    registry=self.registry)
                if pool.pool_slots > 0 \
                        and self.budget.try_reserve(pool.arena_bytes):
                    self.pool = pool
                # else: a budget too small to back even one slot per
                # shard within the half-budget cap — degrade to the
                # legacy per-block path
            self.io = IOScheduler(
                self.budget, sequential_io=sequential_io,
                chunk_blocks=chunk_blocks, spill_dir=spill_dir,
                host_budget_bytes=host_budget_bytes,
                simulated_seconds_per_byte=simulated_seconds_per_byte,
                pool=self.pool, store=self.store,
                compact_ratio=self.aion.store_compact_ratio,
                wal_coalesce=self.aion.wal_coalesce_commits,
                io_retry_limit=self.aion.io_retry_limit,
                io_retry_backoff=self.aion.io_retry_backoff,
                registry=self.registry, tracer=self.tracer)
        self.policy = policy or StandardPolicy()
        self.cleanup = cleanup or PredictiveCleanup(
            coverage=self.aion.cleanup_coverage,
            confidence=self.aion.cleanup_confidence)
        self.trigger = trigger or AionStalenessTrigger(
            cleanup=self.cleanup, max_staleness=self.aion.max_staleness)
        self.watermark_gen = watermark_gen
        self.tracker = WatermarkTracker()
        self.prestage_enabled = prestage_enabled
        # pre-stage lead time floor: a quarter of the watermark period
        # (the paper starts the first pre-staging a full window early)
        self.prestage_margin = 0.25 * (
            watermark_gen.period if watermark_gen is not None
            else self.aion.watermark_period)
        if self.aion.prefetch_backend == "learned":
            from repro.prefetch import LearnedPrestageScheduler
            self.prestage = LearnedPrestageScheduler(
                self.aion, punctuated=punctuated,
                margin=self.prestage_margin)
        else:
            self.prestage = PrestageScheduler(StagingCostModel(),
                                              punctuated=punctuated)
        self.windows: Dict[WindowId, WindowState] = {}
        self.reexec_plans: Dict[WindowId, _ReexecPlan] = {}
        self.metrics = EngineMetrics(
            registry=self.registry, tenant=self.io.tenant,
            series_max=self.aion.metrics_series_max)
        self.results: Dict[WindowId, Any] = {}
        self.batch_exec = BatchExecutor(self)
        # pipelined execution (core/pipeline.py): fold rounds submit to
        # a worker instead of running inline; results additionally
        # resolve through result_futures. A passed-in pipeline is shared
        # infrastructure (multi-tenant) and not closed by this engine.
        # Only meaningful on the batched path — a no-contract operator
        # keeps the synchronous reference loop.
        self._owns_pipeline = False
        if pipeline is not None:
            self.pipeline = pipeline if self.batching_enabled else None
        elif self.aion.pipelined_execution and self.batching_enabled:
            from repro.core.pipeline import EnginePipeline
            self.pipeline = EnginePipeline(registry=self.registry)
            self._owns_pipeline = True
        else:
            self.pipeline = None
        self.result_futures: Dict[WindowId, Any] = {}
        # --- self-healing I/O path -------------------------------------
        # circuit breaker on store health driving the degradation ladder
        # (core/health.py); per-engine, so only built when this engine
        # owns its scheduler (a shared multi-tenant scheduler would get
        # conflicting breakers). breaker_error_threshold=0 disables.
        self.health = None
        if self._owns_io and self.aion.breaker_error_threshold > 0:
            from repro.core.health import StoreHealth
            self.health = StoreHealth(
                error_threshold=self.aion.breaker_error_threshold,
                cooldown_ticks=self.aion.breaker_cooldown_ticks,
                registry=self.registry,
                max_transitions=self.aion.health_transitions_max,
                tenant=self.io.tenant)
            self.io.health = self.health
            # single source of truth for the shed order: the metrics
            # field aliases the breaker's transition log
            self.metrics.ladder_transitions = self.health.transitions
        self._health_signal_last = 0
        # ingest backpressure (ladder rung 4): deferred (batch, now)
        # pairs readmitted by poll() once the breaker steps back down —
        # deferral is bounded ADMISSION, not loss: every deferred batch
        # is eventually folded (flush_deferred() is the drain barrier)
        self._deferred: List[Tuple[EventBatch, float]] = []
        # failed pipelined fold rounds retry ONCE through a backup
        # executor (folds are pure functions of bucket contents —
        # idempotent). min_deadline is large so the straggler race never
        # issues a CONCURRENT duplicate against this engine's pool state;
        # the retry itself (after the primary failed) is sequential.
        self.round_backup = None
        if self.pipeline is not None and self.aion.fold_round_retry:
            from repro.distributed.fault import BackupExecutor
            self.round_backup = BackupExecutor(workers=2,
                                               min_deadline=30.0)

    @property
    def batching_enabled(self) -> bool:
        """Batched path is on AND the operator implements the contract."""
        return self.aion.batched_execution and self.operator.supports_batch

    # ------------------------------------------------------------- helpers
    @property
    def is_baseline(self) -> bool:
        return isinstance(self.policy, InMemoryPolicy)

    def _state_for(self, wid: WindowId) -> WindowState:
        st = self.windows.get(wid)
        if st is None:
            st = WindowState(wid.start, wid.end, self.value_width,
                             self.aion.block_size)
            self.windows[wid] = st
        return st

    def device_bytes(self) -> int:
        return self.budget.used_bytes

    def host_bytes(self) -> int:
        return sum(s.host_bytes() for s in self.windows.values())

    # -------------------------------------------------------------- ingest
    def ingest(self, batch: EventBatch, now: float) -> int:
        """Admit a batch of events. Returns the number of events
        DEFERRED by ingest backpressure (0 = fully admitted): at the
        ladder's top rung admission is bounded and overflow batches park
        in the deferral queue, to be readmitted by ``poll`` when the
        breaker steps down (or force-drained by ``flush_deferred``).
        Deferral is visible, not silent — callers that care (soak
        drivers, serving layers) can count what was deferred."""
        if len(batch) == 0:
            return 0
        span = self.tracer.root("ingest", events=len(batch))
        if self.health is not None and self.health.backpressures():
            self._deferred.append((batch, now))
            self.metrics.deferred_events += len(batch)
            span.end(deferred=len(batch))
            return len(batch)
        with span:
            self._admit(batch, now, span=span)
        return 0

    def _admit(self, batch: EventBatch, now: float,
               span=NULL_SPAN) -> None:
        if self.watermark_gen is not None:
            self.watermark_gen.observe(batch.timestamps)
        wm = self.tracker.watermark
        late_mask = batch.timestamps < wm
        lateness = wm - batch.timestamps[late_mask]
        if len(lateness):
            self.cleanup.observe(lateness)
        self.metrics.ingested += len(batch)
        n_late = int(late_mask.sum())
        self.metrics.ingested_late += n_late
        if span.sampled:
            span.set(late=n_late, watermark=wm)

        identity = None
        for wid, idx in self.assigner.assign(batch.timestamps):
            # select by the index list DIRECTLY (fancy indexing keeps
            # order and duplicates). The old mask-based selection took
            # the whole batch whenever len(idx) == len(batch) — which
            # misfiles events for any assigner whose full-length index
            # list is not the identity — and silently deduplicated
            # repeated indices. Only a verified identity skips the copy.
            idx = np.asarray(idx, np.intp)
            if len(idx) == len(batch):
                if identity is None:
                    identity = np.arange(len(batch))
                sub = batch if np.array_equal(idx, identity) \
                    else batch.select(idx)
            else:
                sub = batch.select(idx)
            state = self._state_for(wid)
            late = wid.end <= wm
            new_blocks = state.append_events(sub, late)
            self.policy.on_append(state, new_blocks, self.io, late, now)
            if late:
                self.io.request_late_write(state, new_blocks, parent=span)
                self._plan_reexecutions(wid, state, now)
                if self.prestage_enabled and len(sub) and np.isfinite(wm):
                    # per-key lateness samples for the learned prefetch
                    # backend's CDF fits (no-op on the fixed scheduler)
                    self.prestage.observe_late(
                        wid, sub.keys,
                        np.maximum(wm - sub.timestamps, 1e-9))
                if self.prestage_enabled:
                    plan = self.reexec_plans.get(wid)
                    if plan and plan.next_idx < len(plan.times):
                        self.prestage.plan(wid, state,
                                           plan.times[plan.next_idx], now,
                                           self.prestage_margin)

        if self.watermark_gen is not None:
            wm_new = self.watermark_gen.maybe_emit(now)
            if wm_new is not None:
                self.advance_watermark(wm_new, now, trace_parent=span)

    def flush_deferred(self, now: Optional[float] = None) -> int:
        """Force-admit every backpressure-deferred batch (each at its
        original ingest time unless ``now`` overrides). The drain
        barrier paths (close, checkpoint, end-of-stream sweeps) call
        this so deferral never turns into loss. Returns events
        admitted."""
        n = 0
        while self._deferred:
            batch, t = self._deferred.pop(0)
            n += len(batch)
            self.metrics.readmitted_events += len(batch)
            self._admit(batch, now if now is not None else t)
        return n

    def _readmit_deferred(self, now: float) -> None:
        """Per-poll backpressure drain: below the top rung the whole
        queue readmits (the breaker closed — service resumes); at the
        top rung one oldest batch trickles through per poll so deferred
        events still make progress under sustained pressure."""
        if not self._deferred:
            return
        if self.health is not None and self.health.backpressures():
            batch, t = self._deferred.pop(0)
            self.metrics.readmitted_events += len(batch)
            self._admit(batch, t)
            return
        self.flush_deferred()

    def _health_tick(self) -> None:
        """Feed the breaker one poll tick: the delta of I/O errors +
        retries since the last tick is the health signal (a store that
        stopped failing produces zero and cools the ladder down)."""
        if self.health is None:
            return
        sig = self.io.stats["errors"] + self.io.stats["retries"]
        delta = sig - self._health_signal_last
        self._health_signal_last = sig
        self.metrics.degradation_level = self.health.tick(delta)

    def _plan_reexecutions(self, wid: WindowId, state: WindowState,
                           now: float) -> None:
        if wid in self.reexec_plans and \
                self.reexec_plans[wid].next_idx < len(self.reexec_plans[wid].times):
            return
        horizon = max(self.cleanup.current_bound(), 1e-6)
        offsets = np.asarray(self.trigger.plan(horizon), np.float64)
        expiry_time = state.last_executed_at if np.isfinite(
            state.last_executed_at) else now
        times = [max(expiry_time + o, now) for o in offsets if
                 expiry_time + o > now - 1e-9]
        if not times:
            times = [now]
        self.reexec_plans[wid] = _ReexecPlan(times=times)

    # ----------------------------------------------------------- watermark
    def advance_watermark(self, wm: float, now: float,
                          trace_parent=None) -> None:
        if not self.tracker.advance(wm):
            return
        # root span unless ingest's maybe_emit handed us its span — the
        # explicit parent is what lets a late event's trace follow the
        # advance onto the pipeline worker thread (no thread-locals)
        span = (self.tracer.child(trace_parent, "watermark_advance", wm=wm)
                if trace_parent is not None
                else self.tracer.root("watermark_advance", wm=wm))
        due = [wid for wid in sorted(self.windows)
               if not self.windows[wid].expired and wid.end <= wm]
        if span.sampled:
            span.set(due=len(due))
        demote = (self.pipeline is not None and self.health is not None
                  and self.health.demotes_rounds())
        if demote and due:
            # ladder rung 3: the pipeline would QUEUE rounds against a
            # failing store — demote to the synchronous batched path (no
            # overlap, but nothing in flight to lose either)
            self.metrics.demoted_sync_rounds += 1
            span.event("demoted_sync")
            for wid in due:
                self.windows[wid].expired = True
            self.batch_exec.execute(
                [BatchWorkItem(wid, self.windows[wid], False)
                 for wid in due], now, trace_parent=span)
            for wid in due:
                self.policy.on_expiry(self.windows[wid], self.io, now)
        elif self.pipeline is not None and due:
            # pipelined: the watermark advance fences only the slots it
            # closes — the round (and the expiry destages, which must
            # run AFTER the fold reads the blocks) executes on the
            # pipeline worker while ingestion keeps appending; results
            # resolve through result_futures
            for wid in due:
                self.windows[wid].expired = True
            self._submit_round(
                [BatchWorkItem(wid, self.windows[wid], False)
                 for wid in due], now, expiry=True, parent=span)
        elif self.batching_enabled and len(due) > 1:
            # live batch: every newly-expired window folds in one pass
            for wid in due:
                self.windows[wid].expired = True
            self.batch_exec.execute(
                [BatchWorkItem(wid, self.windows[wid], False)
                 for wid in due], now, trace_parent=span)
            for wid in due:
                self.policy.on_expiry(self.windows[wid], self.io, now)
        else:
            for wid in due:
                state = self.windows[wid]
                state.expired = True
                self.execute_window(wid, now, late=False)
                self.policy.on_expiry(state, self.io, now)
        span.end()

    def _submit_round(self, items: List[BatchWorkItem], now: float,
                      expiry: bool = False, parent=None) -> None:
        """Submit one fold round to the pipeline; with ``expiry`` the
        transfer policy's on_expiry hooks run on the worker after the
        round folds (same order the synchronous path guarantees —
        destaging a window before its fold read the blocks would turn
        the whole round cold)."""
        on_done = None
        if expiry:
            states = [it.state for it in items]

            def on_done():
                for st in states:
                    self.policy.on_expiry(st, self.io, now)
        futs = self.pipeline.submit(self, items, now, on_done=on_done,
                                    trace_parent=parent)
        self.result_futures.update(futs)

    # ----------------------------------------------------------- execution
    def execute_window(self, wid: WindowId, now: float, late: bool) -> Any:
        state = self.windows[wid]
        t0 = _time.time()
        stall = 0.0

        # lazy block iteration: consume m-blocks while staging p-blocks
        # (the shared snapshot helper keeps the double-fold hazard logic
        # in one place)
        m_snapshot, p_blocks = snapshot_block_partition(state)
        stage_done = None
        stage_t0 = _time.time()
        staged_events = sum(b.fill for b in p_blocks)
        if p_blocks:
            if self.operator.blocking:
                ev = self.io.request_stage(state, p_blocks, demand=True)
                w0 = _time.time()
                ev.wait(timeout=60)
                stall += _time.time() - w0
                ev.check()      # a failed demand stage aborts the fold
            else:
                stage_done = self.io.request_stage(state, p_blocks,
                                                   demand=True)

        acc = self.operator.init_acc()
        # pass 1: blocks already on device (fetch_block_arrays prefers
        # device residency — per-block device_data or the pool arena —
        # and falls back to the accounted host read; None = purged)
        for blk in m_snapshot:
            data = self.io.fetch_block_arrays(blk)
            if data is None:
                continue                        # purged mid-execution
            acc = self.operator.fold(acc, data, blk.fill)
        # pass 2: blocks arriving from the p-bucket (staging that could
        # not reserve budget leaves them host-side; same fetch logic)
        if stage_done is not None:
            w0 = _time.time()
            stage_done.wait(timeout=60)
            stall += max(_time.time() - w0 - 0.0, 0.0)
            stage_done.check()  # surface a failed demand stage
        for blk in p_blocks:
            data = self.io.fetch_block_arrays(blk)
            if data is None:
                continue                        # purged mid-execution
            acc = self.operator.fold(acc, data, blk.fill)
        if p_blocks and staged_events:
            self.prestage.cost.observe(_time.time() - stage_t0,
                                       staged_events)

        result = self.operator.finalize(acc)
        state.result = result
        self.results[wid] = result
        state.last_executed_at = now
        state.events_at_last_exec = state.total_events
        self.metrics.fetch_stall_seconds += stall
        self.metrics.exec_seconds += _time.time() - t0
        if late:
            self.metrics.late_executions += 1
        else:
            self.metrics.live_executions += 1
        self._post_execute_destage(wid, state, now)
        return result

    def _post_execute_destage(self, wid: WindowId, state: WindowState,
                              now: float) -> None:
        # keep the m-bucket resident if another re-execution is imminent
        # (avoids destage/restage thrash between planned executions)
        plan = self.reexec_plans.get(wid)
        next_soon = (plan is not None
                     and plan.next_idx + 1 < len(plan.times)
                     and plan.times[plan.next_idx + 1] - now
                     <= 2 * self.prestage_margin)
        if not next_soon:
            self.policy.on_post_execute(state, self.io, now)

    # ----------------------------------------------------------------- poll
    def poll(self, now: float) -> None:
        # 0. breaker tick + backpressure drain: the ladder reacts to the
        #    error/retry delta of the LAST interval, and any deferred
        #    ingest readmits as soon as (and as far as) the rung allows
        span = self.tracer.root("poll", now=now)
        with span:
            self._health_tick()
            self._readmit_deferred(now)
            # 1. due late re-executions first (their demand staging
            #    outranks the speculative pre-staging issued below; live
            #    execution in advance_watermark always went before either)
            if self.batching_enabled:
                self._poll_reexec_batched(now, parent=span)
            else:
                self._poll_reexec_reference(now)
            self._poll_tail(now, parent=span)

    def _poll_reexec_reference(self, now: float) -> None:
        """Per-window reference path: one execution per due plan time."""
        for wid, plan in list(self.reexec_plans.items()):
            state = self.windows.get(wid)
            if state is None:
                del self.reexec_plans[wid]
                continue
            while plan.next_idx < len(plan.times) and \
                    plan.times[plan.next_idx] <= now:
                self.execute_window(wid, now, late=True)
                plan.next_idx += 1
                if self.prestage_enabled and plan.next_idx < len(plan.times):
                    self.prestage.plan(wid, state,
                                       plan.times[plan.next_idx], now,
                                       self.prestage_margin)

    def _poll_reexec_batched(self, now: float, parent=NULL_SPAN) -> None:
        """Batched path: every window with due re-executions folds in ONE
        device pass. A window's multiple already-due plan times collapse
        into a single execution — re-execution is a pure function of
        bucket contents, so executing once at ``now`` yields the same
        result as executing at each elapsed time."""
        due: List[Tuple[WindowId, WindowState, _ReexecPlan]] = []
        for wid, plan in list(self.reexec_plans.items()):
            state = self.windows.get(wid)
            if state is None:
                del self.reexec_plans[wid]
                continue
            n_due = 0
            while plan.next_idx + n_due < len(plan.times) and \
                    plan.times[plan.next_idx + n_due] <= now:
                n_due += 1
            if n_due:
                # leave next_idx on the LAST due time so the imminence
                # check in _post_execute_destage sees the first future one
                plan.next_idx += n_due - 1
                due.append((wid, state, plan))
        if not due:
            return
        items = [BatchWorkItem(wid, state, True) for wid, state, _ in due]
        demote = (self.pipeline is not None and self.health is not None
                  and self.health.demotes_rounds())
        if demote:
            # ladder rung 3 (see advance_watermark): fold inline
            self.metrics.demoted_sync_rounds += 1
            self.batch_exec.execute(items, now, trace_parent=parent)
        elif self.pipeline is not None:
            # late rounds queue behind any live round submitted this
            # tick (FIFO worker = the paper's live-before-late rule at
            # round granularity); plan bookkeeping advances immediately
            # — re-execution is a pure function of bucket contents, so
            # the fold's timing doesn't change its result
            self._submit_round(items, now, parent=parent)
        else:
            self.batch_exec.execute(items, now, trace_parent=parent)
        for wid, state, plan in due:
            plan.next_idx += 1
            if self.prestage_enabled and plan.next_idx < len(plan.times):
                self.prestage.plan(wid, state, plan.times[plan.next_idx],
                                   now, self.prestage_margin)

    def prefetch_round(self, items, parent=None) -> None:
        """Pipelined staging lookahead (``EnginePipeline.submit`` while
        a round is in flight): start staging the new round's cold blocks
        so their I/O overlaps the running fold. With the learned
        prefetch backend the storage half goes first — one sequential
        sweep per log segment, queued in the SAME priority class as the
        stage tasks that follow (FIFO runs the sweeps first), so the
        pool fills read cache hits instead of per-record seeks."""
        states = [it.state for it in items if it.state.p_blocks()]
        if not states:
            return
        if self.health is not None and self.health.sheds_prefetch():
            # ladder rung 2: next-round prefetch is speculative load on
            # a struggling store — the round's own demand staging will
            # still fetch what the fold needs
            self.metrics.shed_prefetch_rounds += 1
            return
        readahead_now = getattr(self.prestage, "readahead_now", None)
        if readahead_now is not None and self.io.store is not None:
            readahead_now(self.io, states)
        for state in states:
            self.io.request_stage(state, parent=parent)

    def _poll_tail(self, now: float, parent=NULL_SPAN) -> None:
        # 2. due pre-staging (for future re-executions), preceded by
        #    store readahead for the pre-stagings coming up within the
        #    lead margin: proactive caching drives the persistent tier's
        #    sequential sweep BEFORE the staging deadline, so the stage
        #    itself reads cache hits
        if self.prestage_enabled:
            if self.health is not None and self.health.sheds_readahead():
                # ladder rung 1: speculative readahead sweeps go FIRST —
                # they are pure optimization, and every sweep against a
                # failing store is another error/retry feeding the
                # breaker. Due pre-staging below still runs (it has a
                # concrete deadline).
                self.metrics.shed_readahead_drives += 1
            else:
                # polymorphic seam: the fixed scheduler issues per-window
                # point readahead; the learned one plans segment sweeps +
                # coalescing against its lateness/bandwidth models
                self.prestage.drive_readahead(self, now,
                                              self.prestage_margin)
            for wid in self.prestage.due(now):
                state = self.windows.get(wid)
                if state is not None and state.p_blocks():
                    self.io.request_stage(state, parent=parent)
        # 3. predictive cleanup: purge emits store tombstones; the
        #    compaction request after the loop consumes them (bounded
        #    storage, paper §3.4)
        purged_any = False
        wm = self.tracker.watermark
        if np.isfinite(wm):
            for wid in list(self.windows):
                state = self.windows[wid]
                if self.pipeline is not None \
                        and self.pipeline.window_in_flight(wid):
                    # a queued/executing fold round references this
                    # window — purging now would fold empty state; the
                    # next poll retries once the round completes
                    continue
                if state.expired and self.cleanup.should_purge(wid.end, wm):
                    # drop_all reports the device bytes committed at drop
                    # time; an in-flight stage that commits later sees the
                    # dropped flag and releases its own reservation
                    freed, device_bytes = state.drop_all()
                    self.budget.release(device_bytes)
                    self.metrics.purged_windows += 1
                    self.metrics.purged_bytes += freed
                    self.prestage.cancel(wid)
                    self.reexec_plans.pop(wid, None)
                    del self.windows[wid]
                    purged_any = True
        if purged_any:
            self.io.request_compaction()
        # 4. policy tick (idle destaging / memory-pressure handling)
        self.policy.on_tick(self.windows, self.io, now)
        # per-poll byte sample: the scheduler's O(1) tracked figure
        # (destaged/storage-loaded host copies), NOT the O(windows)
        # re-sum of host_bytes() — a long-running engine polls this
        # every tick; exact full sums stay available via host_bytes()
        self.metrics.snapshot(now, self.device_bytes(),
                              self.io.host_bytes_tracked())

    # -------------------------------------------------------- observability
    def observability(self, export: Optional[str] = None):
        """One call, every surface: engine counters, I/O scheduler +
        transfer executor, store, device pool, breaker ladder and the
        trace ring's own accounting — all read off the shared metrics
        registry, so this is the same data the exporters serialize.

        ``export='prometheus'`` returns the text exposition of the whole
        registry; ``export='json'`` its flat JSON snapshot; ``None``
        (default) a nested dict keyed by subsystem.
        """
        if export is not None:
            from repro.obs import to_json, to_prometheus
            if export == "prometheus":
                return to_prometheus(self.registry)
            if export == "json":
                return to_json(self.registry)
            raise ValueError(f"unknown export format: {export!r}")
        eng = self.metrics.scalars()
        eng["mean_batch_occupancy"] = self.metrics.mean_batch_occupancy
        eng["device_seconds_per_execution"] = \
            self.metrics.device_seconds_per_execution
        snap: Dict[str, Any] = {
            "engine": eng,
            "io": self.io.stats.copy(),
            "executor": self.io.executor.stats.copy(),
            "store": (self.store.stats.copy()
                      if self.store is not None else {}),
            "pool": {},
            "health": {},
            "pipeline": (self.pipeline.stats.copy()
                         if self.pipeline is not None else {}),
            "fold": {},
            "trace": self.tracer.stats(),
        }
        if self.pool is not None:
            snap["pool"] = dict(self.pool.stats.copy(),
                                free_slots=self.pool.free_slots(),
                                pool_slots=self.pool.pool_slots,
                                arena_bytes=self.pool.arena_bytes)
        if self.health is not None:
            snap["health"] = dict(self.health.stats.copy(),
                                  level=self.health.level,
                                  level_name=self.health.name,
                                  transitions=list(self.health.transitions))
        cache_size = getattr(getattr(self.operator, "fold_batch", None),
                             "_cache_size", None)
        if callable(cache_size):
            snap["fold"]["cache_size"] = cache_size()
        return snap

    # ------------------------------------------------------------ shutdown
    def close(self, drain_timeout: float = 30.0) -> None:
        """Drain pipeline + I/O and shut down owned infrastructure.

        Raises: ``PipelineError`` if a pipelined round failed (or the
        pipeline cannot drain), ``RuntimeError`` if the I/O executor
        did not drain in time — close must not silently discard
        in-flight work."""
        # backpressure-deferred ingest folds BEFORE the drains: deferral
        # bounds admission, it never loses events
        self.flush_deferred()
        try:
            if self.pipeline is not None:
                from repro.core.pipeline import PipelineError
                if not self.pipeline.drain(timeout=drain_timeout * 4,
                                           raise_on_error=True):
                    raise PipelineError(
                        "fold pipeline failed to drain before close")
                if self._owns_pipeline:
                    self.pipeline.close()
        finally:
            # after the drain — queued rounds may still retry through it
            if self.round_backup is not None:
                self.round_backup.shutdown()
                self.round_backup = None
        if not self.io.drain(timeout=drain_timeout):
            raise RuntimeError(
                "I/O executor failed to drain before close "
                f"(last_error={self.io.stats['last_error']!r})")
        if self._owns_io:
            self.io.shutdown()

    # -------------------------------------------------- engine checkpointing
    def restore_state(self, snap: Dict[str, Any]) -> None:
        """Restore from ``checkpoint_state()`` output: watermark, lateness
        histogram, and window bucket contents.

        Blocks are rebuilt 1:1 — same fill boundaries, block ids and
        ``persisted`` flags as at checkpoint time — rather than
        re-appended (which would re-pack events into different blocks and
        lose the on-time/late provenance). Inline-data blocks restore
        into the host tier; manifest blocks (``stored: True`` — written
        by ``checkpoint_state(include_stored_data=False)``) restore into
        the STORAGE tier, re-linked to their records in the engine's
        (reopened) store, and load lazily on demand. After the rebuild
        the store is reconciled: records not referenced by any restored
        block are orphans (post-checkpoint spills of a crashed run, or
        purges whose tombstones never committed) and get tombstoned so
        compaction can reclaim them."""
        import jax.numpy as _jnp
        from repro.core.buckets import _BLOCK_IDS
        store = self.io.store
        self.tracker.watermark = snap["watermark"]
        self.cleanup.hist.counts = _jnp.asarray(
            np.asarray(snap["hist_counts"], np.float32))
        self.cleanup.hist.total = snap["hist_total"]
        self.windows.clear()
        max_bid = 0
        live_keys = []
        for w in snap["windows"]:
            wid = WindowId(w["start"], w["end"])
            st = self._state_for(wid)
            st.expired = w["expired"]
            for b in w["blocks"]:
                data = b.get("data")
                fill = int(b["fill"])
                stored = bool(b.get("stored", False))
                if fill == 0 or (not data and not stored):
                    continue
                blk = Block.new(st.block_capacity, st.width)
                blk.window_key = (wid.start, wid.end)
                if "block_id" in b:
                    blk.block_id = int(b["block_id"])
                    max_bid = max(max_bid, blk.block_id)
                blk.fill = fill
                blk.persisted = bool(b.get(
                    "persisted", b.get("tier") != Tier.DEVICE.value))
                if stored and not data:
                    # manifest block: the record IS the data — verify it
                    # survived (WAL recovery guarantees acknowledged
                    # commits did) and restore cold
                    if store is None or store.current_fill(
                            blk.window_key, blk.block_id) != fill:
                        raise KeyError(
                            f"checkpoint references store record "
                            f"{blk.window_key}/{blk.block_id} (fill "
                            f"{fill}) that the store does not hold")
                    blk.store = store
                    blk.storage_ref = store.locate(blk.window_key,
                                                   blk.block_id)
                    blk.host_data = None
                    blk.tier = Tier.STORAGE
                    live_keys.append((blk.window_key, blk.block_id))
                else:
                    blk.host_data["keys"][:fill] = \
                        np.asarray(data["keys"], np.int32)[:fill]
                    blk.host_data["timestamps"][:fill] = \
                        np.asarray(data["timestamps"], np.float64)[:fill]
                    blk.host_data["values"][:fill] = \
                        np.asarray(data["values"], np.float32)[:fill]
                st.blocks.append(blk)
            st.total_events = w["total_events"]
            st.late_events = w["late_events"]
        # new blocks must never collide with restored ids (the store
        # keys records by them)
        _BLOCK_IDS.bump_to(max_bid)
        if store is not None:
            store.reconcile(live_keys)

    @staticmethod
    def _block_ckpt_data(b: Block) -> Dict[str, Any]:
        """Serializable event arrays for one block, whatever its tier
        (spilled blocks are read back through the store without mutating
        the block's residency).

        Read order is race-critical vs the concurrent destage thread:
        grab the device dict reference FIRST (destage clears the
        reference, not the dict), then prefer the host copy — destage
        writes host_data before dropping device_data, so at least one of
        the two snapshots is always complete."""
        dd = b.device_data
        hd = b.host_data
        if hd is not None:
            return {k: np.asarray(v).tolist() for k, v in hd.items()}
        if dd is not None:
            return {k: np.asarray(v).tolist() for k, v in dd.items()}
        if b.in_storage:
            # checked BEFORE the pool: a persistent copy carries the
            # real timestamps, which the arena does not
            if b.store is not None and b.storage_ref is not None:
                d = b.store.get(b.window_key, b.block_id)
                if d is not None:
                    return {k: np.asarray(v).tolist()
                            for k, v in d.items()}
            if b.storage_path is not None and b.storage_path.exists():
                with np.load(b.storage_path) as z:
                    return {k: z[k].tolist()
                            for k in ("keys", "timestamps", "values")}
        if b.pool is not None and b.pool_slot is not None:
            # pooled blocks normally keep their host copy; this covers a
            # defensively-rebuilt one (timestamps restore as zeros)
            d = b.pool.read_host(b)
            if d is not None:
                return {k: np.asarray(v).tolist() for k, v in d.items()}
        return {}

    def _block_ckpt_entry(self, b: Block,
                          include_stored_data: bool) -> Dict[str, Any]:
        entry = {"fill": b.fill, "tier": b.tier.value,
                 "persisted": b.persisted, "block_id": b.block_id}
        store = self.io.store
        # manifest references require a crash-durable backend: the npz
        # fallback loses fill/window metadata across a reopen (its
        # on-disk layout is the bare arrays), so its checkpoints always
        # inline the data
        if not include_stored_data and store is not None \
                and store.durable_writes \
                and b.in_storage and b.store is store \
                and store.current_fill(b.window_key,
                                       b.block_id) == b.fill:
            # the store's record IS this block's exact content (fill
            # identifies it — blocks are append-only): a manifest
            # reference replaces the inline copy, and restore reads it
            # back from the recovered log
            entry["stored"] = True
            entry["data"] = {}
        else:
            entry["data"] = self._block_ckpt_data(b)
        return entry

    def checkpoint_state(self, include_stored_data: bool = True,
                         drain_timeout: float = 30.0) -> Dict[str, Any]:
        """Serializable engine state for fault tolerance (bucket manifests,
        watermark, lateness histogram, re-execution plans).

        ``include_stored_data=False`` writes *manifest* checkpoints:
        blocks whose exact content is already durable in the persistent
        store serialize as ``(window, block_id, fill)`` references
        instead of inline arrays — the checkpoint shrinks to metadata
        for everything the value log already holds, and restore +
        WAL recovery reassemble the state (``tests/
        test_storage_recovery.py`` drives the crash matrix). The final
        group commit below makes that sound: the store index reflects
        ``put`` (pre-ack), so a referenced record might otherwise still
        be sitting in an unacknowledged tail a crash would truncate —
        committing before the checkpoint is handed out guarantees every
        reference is durable."""
        # deferred ingest must be IN the checkpoint (it was acknowledged
        # to the caller as deferred, not dropped)
        self.flush_deferred()
        if self.pipeline is not None:
            from repro.core.pipeline import PipelineError
            # a checkpoint must capture post-fold state: wait out (and
            # surface failures of) every submitted round first
            if not self.pipeline.drain(timeout=drain_timeout * 4,
                                       raise_on_error=True):
                raise PipelineError(
                    "fold pipeline failed to drain before checkpoint")
        if not include_stored_data:
            # manifest checkpoints reference store records by (id, fill)
            # — an in-flight spill/late-write racing the snapshot could
            # commit a record AFTER the manifest captured a different
            # fill. drain() returning False used to be silently ignored
            # here (it returned None); now a failed drain aborts the
            # checkpoint instead of handing out racy references.
            if not self.io.drain(timeout=drain_timeout):
                raise RuntimeError(
                    "I/O executor failed to drain before manifest "
                    "checkpoint (last_error="
                    f"{self.io.stats['last_error']!r})")
        snap = {
            "watermark": self.tracker.watermark,
            "hist_counts": np.asarray(self.cleanup.hist.counts).tolist(),
            "hist_total": self.cleanup.hist.total,
            "windows": [
                {
                    "start": wid.start, "end": wid.end,
                    "total_events": st.total_events,
                    "late_events": st.late_events,
                    "expired": st.expired,
                    "blocks": [
                        self._block_ckpt_entry(b, include_stored_data)
                        for b in st.blocks
                    ],
                }
                for wid, st in self.windows.items()
            ],
        }
        if not include_stored_data and self.io.store is not None:
            self.io.store.commit()
        return snap
