"""Persistent device block pool: the arena behind the block-table fold.

The KV-cache idiom (flash-decoding's ``block_tables`` over a paged cache)
applied to Aion's m-bucket: instead of a per-block ``device_put`` whose
buffers are re-stacked into ``[rows, cap, W]`` tensors on every batched
fold, staging writes each block ONCE into a preallocated device arena —

    keys_arena    [pool_slots, block_capacity]      int32
    values_arena  [pool_slots, block_capacity, W]   float32

— at a free pool slot (a dynamic-update-slice), and the batched fold
consumes a *block table* of slot indices. Hot m-bucket blocks never leave
the arena between executions, so a batch over resident blocks launches
with zero per-batch copies: the gather is one take along the pool axis
(dense backend) or an in-kernel scalar-prefetch DMA (Mosaic backend).

Slot lifecycle (see ROADMAP "Persistent device block pool"):

    free -> filling -> resident -> folding -> destaged(free)

Concurrency contract (engine main thread + I/O executor thread):

* Arena updates are **in-place by default** (``dynamic_update_slice``
  with input donation — O(block) per fill, not O(arena)); computations
  already dispatched against the arena are protected by the runtime's
  buffer usage holds (a donation waits for in-flight readers), so a fold
  that is executing never observes a slot rewritten under it.
* What donation DOES invalidate is python-level references: donating
  deletes every live ``jax.Array`` alias of the old arena. The executor
  therefore brackets each snapshot -> fold-dispatch section with
  ``pinned()``; while any pin is held, writes take the **functional**
  (copy) path, so a pinned snapshot stays live until it has been handed
  to the runtime. Outside pins (ingest-time fills, destage churn) writes
  are donated and cheap.
* ``commit`` (write + ``block.pool_slot`` assignment) and
  ``snapshot_for`` (arena objects + slot reads) are atomic under the pool
  lock, so a snapshot either sees a slot with its data already in the
  captured arena, or no slot at all (the row falls back to the host
  path). ``release_slot`` clears ``block.pool_slot`` under the same lock,
  which makes a slot return to the free list exactly once even when a
  purge races an in-flight stage (both sides run under ``block.lock`` and
  surrender the slot through here).
* Timestamps are deliberately not pooled — no batch fold is
  time-dependent within a window (see the ``fold_batch`` contract); the
  host copy keeps them for checkpoints.

Slots partition into ``num_shards`` contiguous ranges for the slot-sharded
fold: a window's blocks are allocated in the range of the shard that
``distributed.sharding.shard_of_window`` assigns the window to, so the
block table a shard receives only ever references its own arena range
(the shard_map passes each device its ``[pool_slots/D, ...]`` arena tile).
"""
from __future__ import annotations

import contextlib
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry, StatsMap


def _write_fn(k_arena, v_arena, slot, keys, values):
    return (jax.lax.dynamic_update_slice(k_arena, keys[None], (slot, 0)),
            jax.lax.dynamic_update_slice(v_arena, values[None], (slot, 0, 0)))


def _read_fn(k_arena, v_arena, slot):
    cap = k_arena.shape[1]
    w = v_arena.shape[2]
    return (jax.lax.dynamic_slice(k_arena, (slot, 0), (1, cap))[0],
            jax.lax.dynamic_slice(v_arena, (slot, 0, 0), (1, cap, w))[0])


_write_jit = jax.jit(_write_fn)
# donated variant: XLA aliases input -> output and updates the slot in
# place — O(block) per fill instead of an O(arena) copy. Platforms that
# cannot donate silently fall back to the copy (still correct).
_write_donated_jit = jax.jit(_write_fn, donate_argnums=(0, 1))
_read_jit = jax.jit(_read_fn)


def _scatter_fn(k_arena, v_arena, slots, keys, values):
    """Batched multi-slot commit: ONE scatter along the pool axis for a
    whole round of fills — O(arena + k*block) instead of k functional
    O(arena) copies when the pin forces the copy path."""
    return k_arena.at[slots].set(keys), v_arena.at[slots].set(values)


_scatter_jit = jax.jit(_scatter_fn)
_scatter_donated_jit = jax.jit(_scatter_fn, donate_argnums=(0, 1))


class DeviceBlockPool:
    """Preallocated device arena + per-shard slot free lists."""

    def __init__(self, pool_slots: int, block_capacity: int, width: int,
                 num_shards: int = 1,
                 max_arena_bytes: Optional[int] = None,
                 registry: Optional[MetricsRegistry] = None):
        num_shards = max(int(num_shards), 1)
        pool_slots = max(int(pool_slots), num_shards)
        # round up to a multiple of the shard count so the arena splits
        # evenly under shard_map (P(axis) on the slot axis)
        pool_slots = -(-pool_slots // num_shards) * num_shards
        row_bytes = block_capacity * (4 + 4 * width)
        if max_arena_bytes is not None and row_bytes > 0:
            # round DOWN to the shard multiple: the arena must never
            # exceed max_arena_bytes (the engine's at-most-half-budget
            # guarantee for utilization-driven policies); a cap below
            # one slot per shard disables the pool entirely — callers
            # check ``pool_slots == 0`` and fall back to the legacy path
            fit = (max_arena_bytes // row_bytes) // num_shards * num_shards
            pool_slots = min(pool_slots, fit)
        self.pool_slots = pool_slots
        self.capacity = block_capacity
        self.width = width
        # physical device bytes the arenas occupy — charged ONCE against
        # the engine's device budget at construction; a pooled fill then
        # costs a slot, not a second per-block reservation (the legacy
        # device_put fallback still reserves per block)
        self.arena_bytes = pool_slots * row_bytes
        self.num_shards = num_shards
        self.slots_per_shard = pool_slots // num_shards
        self._lock = threading.Lock()
        self._pins = 0                     # live snapshot sections
        self._deferred = 0                 # live deferred-fill sections
        # slot -> (keys, values) commits buffered while deferred; flushed
        # as ONE batched scatter at the next snapshot/read
        self._pending: Dict[int, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        self._free: List[deque] = [
            deque(range(d * self.slots_per_shard,
                        (d + 1) * self.slots_per_shard))
            for d in range(num_shards)]
        self._rr = 0                       # round-robin for shard=None
        # per-slot epoch/sequence scheme (ROADMAP: carried from PR 4):
        # a slot's epoch bumps whenever its CONTENTS or OWNERSHIP change
        # (commit, release, free) — never on alloc, which only removes
        # the slot from the free list. The pipelined executor classifies
        # rows from an unpinned (slot, epoch) read, then re-validates the
        # pairs under a short pin at dispatch: an unchanged epoch proves
        # the captured arena holds exactly the data the row was
        # classified against, so the pin only needs to span
        # snapshot -> dispatch instead of the whole fold round (and
        # ingest-time fills in between donate in place, O(block)).
        self._slot_epoch: List[int] = [0] * pool_slots
        self.seq = 0                       # global epoch counter
        self.keys = jnp.zeros((pool_slots, block_capacity), jnp.int32)
        self.values = jnp.zeros((pool_slots, block_capacity, width),
                                jnp.float32)
        registry = registry if registry is not None else MetricsRegistry()
        self.registry = registry
        self.stats = StatsMap(registry, "aion_pool")
        self.stats.register_many([
            "allocs", "frees", "exhausted", "writes",
            "copy_writes", "deferred_fills",
            "batched_fill_commits", "epoch_bumps"])
        # occupancy gauges are cheaper polled than maintained: the
        # registry snapshot calls back into the pool under its lock
        registry.register_callback(lambda: {
            "aion_pool_free_slots": self.free_slots(),
            "aion_pool_slots": self.pool_slots,
            "aion_pool_arena_bytes": self.arena_bytes,
        })

    def _bump_epoch_locked(self, slot: int) -> None:
        self._slot_epoch[slot] += 1
        self.seq += 1
        self.stats.inc("epoch_bumps")

    @contextlib.contextmanager
    def deferred_fills(self):
        """Batch-commit lease for a fold round's cold fills: while held,
        ``commit`` buffers (slot, data) pairs instead of writing the
        arena per block, and the next ``snapshot_for``/``read_block`` —
        or the lease exit — flushes them as ONE batched scatter. Under a
        concurrent ``pinned()`` section each per-block commit would be a
        functional O(arena) copy; the batch makes a round of k fills
        O(arena + k*block). Slot attachment stays immediate (a pending
        slot is resident for placement purposes); reads always flush
        first, so no path can observe a slot without its data."""
        with self._lock:
            self._deferred += 1
        try:
            yield
        finally:
            with self._lock:
                self._deferred -= 1
                if self._deferred == 0:
                    self._flush_pending_locked()

    def _flush_pending_locked(self) -> None:
        """One scatter commit for every buffered fill (caller holds the
        pool lock). Functional while pinned (snapshot references stay
        live), donated otherwise."""
        if not self._pending:
            return
        slots = list(self._pending)
        # pad the batch to a power of two by repeating the first entry
        # (same slot, same data: an idempotent duplicate scatter row) so
        # the jitted scatter sees O(log) distinct shapes
        n = 1
        while n < len(slots):
            n <<= 1
        slots = slots + [slots[0]] * (n - len(slots))
        ks = jnp.stack([self._pending[s][0] for s in slots])
        vs = jnp.stack([self._pending[s][1] for s in slots])
        idx = jnp.asarray(slots, jnp.int32)
        scatter = _scatter_jit if self._pins else _scatter_donated_jit
        if self._pins:
            self.stats.inc("copy_writes")
        self.keys, self.values = scatter(self.keys, self.values, idx,
                                         ks, vs)
        self.stats.inc("batched_fill_commits")
        self._pending.clear()

    @contextlib.contextmanager
    def pinned(self):
        """Snapshot-stability lease: while any pin is held, arena writes
        take the functional (copy) path so python references returned by
        ``snapshot_for`` stay live. Bracket snapshot -> fold-dispatch
        sections with this; once the fold is dispatched the runtime's
        usage holds protect it and the pin can drop (letting overlapped
        demand fills write in place)."""
        with self._lock:
            self._pins += 1
        try:
            yield
        finally:
            with self._lock:
                self._pins -= 1

    # ------------------------------------------------------------ slot mgmt
    def shard_of_slot(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def alloc(self, shard: Optional[int] = None) -> Optional[int]:
        """Take a free slot from ``shard``'s range (state: free -> filling).

        ``shard=None`` round-robins across shards (unsharded pools have a
        single shard, so this is simply "any slot"). A full shard range
        returns None — no cross-shard stealing, since a slot outside the
        window's shard range could never appear in that shard's block
        table; the caller falls back to the legacy device_put path.
        """
        with self._lock:
            if shard is None:
                for off in range(self.num_shards):
                    d = (self._rr + off) % self.num_shards
                    if self._free[d]:
                        self._rr = (d + 1) % self.num_shards
                        self.stats.inc("allocs")
                        return self._free[d].popleft()
                self.stats.inc("exhausted")
                return None
            d = shard % self.num_shards
            if not self._free[d]:
                self.stats.inc("exhausted")
                return None
            self.stats.inc("allocs")
            return self._free[d].popleft()

    def free(self, slot: int) -> None:
        """Return an unattached slot (alloc'd but never committed)."""
        with self._lock:
            self._pending.pop(slot, None)
            self._free[self.shard_of_slot(slot)].append(slot)
            self._bump_epoch_locked(slot)
            self.stats.inc("frees")

    def release_slot(self, block) -> Optional[int]:
        """Surrender ``block``'s slot back to the free list, exactly once.

        Callers hold ``block.lock`` (destage / drop / aborted stage), so
        concurrent surrenders serialize there; the None-check under the
        pool lock makes a double call harmless anyway. A buffered
        deferred fill for the slot is discarded — the block is leaving
        the device tier, its data must not land after the slot is
        reused.
        """
        with self._lock:
            slot = block.pool_slot
            if slot is None:
                return None
            block.pool_slot = None
            self._pending.pop(slot, None)
            self._free[self.shard_of_slot(slot)].append(slot)
            self._bump_epoch_locked(slot)
            self.stats.inc("frees")
            return slot

    def free_slots(self) -> int:
        with self._lock:
            return sum(len(f) for f in self._free)

    # ------------------------------------------------------------- transfers
    def commit(self, block, slot: int,
               host_data: Dict[str, np.ndarray]) -> None:
        """Write ``host_data`` into ``slot`` and attach it to ``block``
        (state: filling -> resident). Atomic vs ``snapshot_for`` so a
        snapshot never sees a slot whose data is not in its captured
        arena. Caller holds ``block.lock`` (the drop-race handoff) and
        passes the host arrays it validated — re-reading
        ``block.host_data`` here would race a concurrent spill that just
        nulled it (spill keeps the same bytes on storage, so committing
        the caller's snapshot stays correct, exactly like the legacy
        ``device_put`` path)."""
        keys = jnp.asarray(np.asarray(host_data["keys"], np.int32))
        vals = jnp.asarray(np.asarray(host_data["values"], np.float32))
        with self._lock:
            if self._deferred:
                # a fold round's fills batch into one scatter at the
                # next snapshot/read (see ``deferred_fills``)
                self._pending[slot] = (keys, vals)
                self.stats.inc("deferred_fills")
            else:
                write = _write_jit if self._pins else _write_donated_jit
                if self._pins:
                    self.stats.inc("copy_writes")
                self.keys, self.values = write(self.keys, self.values,
                                               slot, keys, vals)
            block.pool_slot = slot
            block.pool = self
            self._bump_epoch_locked(slot)
            self.stats.inc("writes")

    def slot_epochs(self, blocks) -> List[Tuple[Optional[int], int]]:
        """One consistent ``(pool_slot, epoch)`` read per block — NO
        arena capture, NO pin required. The pipelined executor
        classifies rows from this, issues demand fills, and only then
        takes the short ``pinned()`` section: ``snapshot_with_epochs``
        re-reads the pairs under the pin, and any row whose pair moved
        (destaged, purged, slot recycled to another block) demotes to
        the stacked fallback instead of folding a stale slot."""
        with self._lock:
            out: List[Tuple[Optional[int], int]] = []
            for b in blocks:
                s = b.pool_slot
                out.append((s, self._slot_epoch[s]) if s is not None
                           else (None, -1))
            return out

    def snapshot_with_epochs(self, blocks) -> Tuple[
            jnp.ndarray, jnp.ndarray, List[Optional[int]], List[int]]:
        """``snapshot_for`` + the epoch of each block's slot, one atomic
        read. Call inside a ``pinned()`` section; comparing the returned
        (slot, epoch) pairs against an earlier ``slot_epochs`` read
        proves (or disproves) that the captured arena still holds the
        data each row was classified against."""
        with self._lock:
            self._flush_pending_locked()
            slots = [b.pool_slot for b in blocks]
            epochs = [self._slot_epoch[s] if s is not None else -1
                      for s in slots]
            return self.keys, self.values, slots, epochs

    def snapshot_for(self, blocks) -> Tuple[jnp.ndarray, jnp.ndarray,
                                            List[Optional[int]]]:
        """(keys_arena, values_arena, slot-per-block) — one consistent
        view. Call inside a ``pinned()`` section: while pinned, writes
        are functional so the returned references stay live; after the
        consuming fold is dispatched the pin can drop (usage holds take
        over) and subsequent writes may donate the buffers."""
        with self._lock:
            self._flush_pending_locked()
            return self.keys, self.values, [b.pool_slot for b in blocks]

    def read_block(self, block) -> Optional[Dict[str, jnp.ndarray]]:
        """Device view of one resident block ({keys, values}), or None if
        the block holds no slot. Used by the per-window fold path.

        The slice is dispatched UNDER the pool lock: once enqueued, the
        runtime's usage holds keep the read consistent even if a donated
        write lands right after — but a write between snapshot and
        dispatch would delete the reference, so the two must be atomic.
        """
        with self._lock:
            slot = block.pool_slot
            if slot is None:
                return None
            self._flush_pending_locked()
            k, v = _read_jit(self.keys, self.values, slot)
        return {"keys": k, "values": v}

    def read_host(self, block) -> Optional[Dict[str, np.ndarray]]:
        """Host copy of a resident block's pooled arrays (destage path
        when the host copy was lost)."""
        d = self.read_block(block)
        if d is None:
            return None
        out = {k: np.asarray(v) for k, v in d.items()}
        # timestamps are not pooled (no batch fold is time-dependent);
        # a defensively-rebuilt host copy carries zeros so the SoA schema
        # stays uniform for checkpoints
        out["timestamps"] = np.zeros((self.capacity,), np.float64)
        return out
