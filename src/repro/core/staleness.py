"""Staleness-minimizing trigger (paper §3.4, evaluated in Q4).

Staleness between consecutive executions at times ``0 = x_0 < x_1 < ... <
x_K = T`` of a past window is

    st_i = (x_i - x_{i-1}) / T  *  (F(x_i) - F(x_{i-1}))      (= t·n / (T·N))

where F is the CDF of late-event arrival delays. Given a budget of K
executions, the trigger places x_1..x_{K-1} (x_K = T is the final
execution at maximum allowed lateness) to minimize ``max_i st_i``.

Algorithm (faithful to the paper):
  1. *Seed* execution times where the distribution has high relative
     density — equal-mass placement x_i = F^{-1}(i/K). (This seed equals
     the ``deltaev`` trigger; the optimizer strictly improves on it.)
  2. *Balance* by a variation of gradient descent: descend the smoothed
     max (temperature-annealed logsumexp) of the staleness vector w.r.t.
     the execution times, projecting back to monotonic order, until the
     standard deviation of the st_i is ~0 or an iteration cap is reached.

Everything is pure JAX (grad + while_loop) so the trigger itself can run
device-side inside the engine's control program.

Reference triggers (paper Fig. 9): ``deltat`` executes every T/K seconds;
``deltaev`` every N/K events.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def empirical_cdf(delays: np.ndarray, horizon: float,
                  grid_size: int = 512) -> Tuple[np.ndarray, np.ndarray]:
    """Empirical CDF of delays clipped to [0, horizon], on a uniform grid
    (interp-friendly representation shared by all triggers)."""
    delays = np.asarray(delays, np.float64)
    delays = delays[(delays > 0) & np.isfinite(delays)]
    grid = np.linspace(0.0, horizon, grid_size)
    if len(delays) == 0:
        return grid, grid / max(horizon, 1e-12)     # degenerate: uniform
    delays = np.clip(delays, 0.0, horizon)
    F = np.searchsorted(np.sort(delays), grid, side="right") / len(delays)
    return grid, F


def _interp_cdf(x, grid, F):
    return jnp.interp(x, grid, F)


def staleness_profile(times: jnp.ndarray, grid, F, horizon) -> jnp.ndarray:
    """st_i for the execution-time vector (K entries, last must be T)."""
    xs = jnp.concatenate([jnp.zeros((1,)), times])
    dt = jnp.diff(xs) / horizon
    dF = jnp.diff(_interp_cdf(xs, grid, F))
    return dt * dF


@partial(jax.jit, static_argnames=("k", "max_iters"))
def _optimize(grid: jnp.ndarray, F: jnp.ndarray, horizon: float, k: int,
              max_iters: int, tol: float, lr: float):
    # --- seed: equal-mass placement (high relative density regions)
    qs = (jnp.arange(1, k) / k)
    seed_inner = jnp.interp(qs, F, grid)      # F^{-1}(i/k)
    seed_inner = jnp.clip(seed_inner, horizon * 1e-4, horizon * (1 - 1e-4))
    seed_inner = jnp.sort(seed_inner)

    def full_times(inner):
        return jnp.concatenate([inner, jnp.array([horizon])])

    def smooth_max_loss(inner, tau):
        st = staleness_profile(full_times(inner), grid, F, horizon)
        return tau * jax.nn.logsumexp(st / tau)

    grad_fn = jax.grad(smooth_max_loss)

    def cond(carry):
        i, inner, best_inner, best_val, stall = carry
        return (i < max_iters) & (stall < 64)

    def body(carry):
        i, inner, best_inner, best_val, stall = carry
        st = staleness_profile(full_times(inner), grid, F, horizon)
        # anneal the temperature toward a hard max
        tau = jnp.maximum(jnp.max(st) * 0.5 ** (i / 64.0 + 1), 1e-12)
        g = grad_fn(inner, tau)
        step = lr * horizon
        new_inner = inner - step * g / (jnp.max(jnp.abs(g)) + 1e-12)
        # project to monotonic order inside (0, T)
        new_inner = jnp.clip(jnp.sort(new_inner),
                             horizon * 1e-6, horizon * (1 - 1e-6))
        new_st = staleness_profile(full_times(new_inner), grid, F, horizon)
        new_val = jnp.max(new_st)
        improved = new_val < best_val - tol * 0.0
        best_inner2 = jnp.where(improved, new_inner, best_inner)
        best_val2 = jnp.minimum(new_val, best_val)
        stall2 = jnp.where(new_val < best_val - 1e-12, 0, stall + 1)
        # stop when staleness is balanced (std ~ 0)
        balanced = jnp.std(new_st) < tol * jnp.maximum(jnp.mean(new_st), 1e-12)
        stall2 = jnp.where(balanced, 1_000_000, stall2)
        return (i + 1, new_inner, best_inner2, best_val2, stall2)

    st0 = staleness_profile(full_times(seed_inner), grid, F, horizon)
    init = (jnp.int32(0), seed_inner, seed_inner, jnp.max(st0), jnp.int32(0))
    _, _, best_inner, best_val, _ = jax.lax.while_loop(cond, body, init)
    return full_times(best_inner), best_val


@dataclass
class StalenessTriggerResult:
    times: np.ndarray          # K execution times in (0, T]
    max_staleness: float


def minimize_max_staleness(delays: np.ndarray, horizon: float, k: int,
                           max_iters: int = 512, tol: float = 1e-3,
                           lr: float = 0.02,
                           grid_size: int = 512) -> StalenessTriggerResult:
    """AION trigger: place k executions minimizing max staleness."""
    if k < 1:
        raise ValueError("need at least one execution")
    grid, F = empirical_cdf(delays, horizon, grid_size)
    if k == 1:
        times = np.array([horizon])
        st = float(np.max(np.asarray(
            staleness_profile(jnp.asarray(times), jnp.asarray(grid),
                              jnp.asarray(F), horizon))))
        return StalenessTriggerResult(times, st)
    times, val = _optimize(jnp.asarray(grid), jnp.asarray(F),
                           float(horizon), int(k), int(max_iters),
                           float(tol), float(lr))
    return StalenessTriggerResult(np.asarray(times), float(val))


# ----------------------------------------------------------------- baselines

def deltat_times(horizon: float, k: int) -> np.ndarray:
    """Periodic in processing time: every T/k."""
    return np.linspace(horizon / k, horizon, k)


def deltaev_times(delays: np.ndarray, horizon: float, k: int) -> np.ndarray:
    """Every N/k events: equal-mass quantiles of the arrival distribution."""
    grid, F = empirical_cdf(delays, horizon)
    qs = np.arange(1, k + 1) / k
    t = np.interp(qs, F, grid)
    t[-1] = horizon
    return np.maximum.accumulate(t)


def max_staleness_of(times: np.ndarray, delays: np.ndarray,
                     horizon: float) -> float:
    grid, F = empirical_cdf(delays, horizon)
    st = staleness_profile(jnp.asarray(np.asarray(times, np.float64)),
                           jnp.asarray(grid), jnp.asarray(F), horizon)
    return float(jnp.max(st))


def executions_for_bound(trigger: Callable[[int], np.ndarray],
                         delays: np.ndarray, horizon: float, bound: float,
                         k_max: int = 64) -> Optional[int]:
    """Minimum number of executions for which max staleness <= bound
    (paper Fig. 9 right: compared across triggers and distributions)."""
    for k in range(1, k_max + 1):
        times = trigger(k)
        if max_staleness_of(times, delays, horizon) <= bound:
            return k
    return None
