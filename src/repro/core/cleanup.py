"""Predictive cleanup (paper §3.4): adaptively bound allowed lateness from
the observed distribution of late-event delays, and purge window state that
is very unlikely to receive more events.

The engine starts with a conservatively large bound; once a representative
history is collected, the bound is adjusted for newly created windows to
cover a target fraction of late events (e.g. 99%) *within a confidence
interval*: we take a one-sided Dvoretzky–Kiefer–Wolfowitz band on the
empirical CDF, i.e. pick the smallest delay T with

    F_hat(T) - sqrt(ln(1/delta) / (2 n))  >=  coverage

so that with confidence (1 - delta) the true CDF at T is >= coverage.
The distribution keeps updating with new observations (including events
later than the current bound), keeping the estimate current.

The delay histogram itself is maintained in JAX (pure function updated
under jit) so it can live device-side next to the operators.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class LatenessHistogram:
    """Streaming log-spaced histogram of late-event delays (seconds)."""
    min_delay: float = 1e-3
    max_delay: float = 1e6
    num_bins: int = 256
    counts: jnp.ndarray = None
    total: int = 0

    def __post_init__(self):
        if self.counts is None:
            self.counts = jnp.zeros((self.num_bins,), jnp.float32)
        lo, hi = math.log(self.min_delay), math.log(self.max_delay)
        self._edges = np.exp(np.linspace(lo, hi, self.num_bins + 1))

    @property
    def edges(self) -> np.ndarray:
        return self._edges

    def update(self, delays: np.ndarray) -> None:
        # host-side numpy: delay batches have ragged shapes, and a jit'd
        # update would recompile per shape (the jax variant below serves
        # fixed-shape device-side use)
        delays = np.asarray(delays, np.float64)
        delays = delays[delays > 0]
        if len(delays) == 0:
            return
        idx = np.clip(np.searchsorted(self._edges, delays) - 1, 0,
                      self.num_bins - 1)
        counts = np.asarray(self.counts).copy()
        np.add.at(counts, idx, 1.0)
        self.counts = jnp.asarray(counts)
        self.total += len(delays)

    def cdf(self) -> Tuple[np.ndarray, np.ndarray]:
        """(delay_grid, F_hat) at bin upper edges."""
        c = np.asarray(self.counts, np.float64)
        tot = c.sum()
        if tot == 0:
            return self._edges[1:], np.zeros(self.num_bins)
        return self._edges[1:], np.cumsum(c) / tot

    def quantile(self, q: float) -> float:
        grid, F = self.cdf()
        idx = np.searchsorted(F, q)
        return float(grid[min(idx, len(grid) - 1)])


@jax.jit
def _hist_update(counts: jnp.ndarray, delays: jnp.ndarray,
                 edges: jnp.ndarray) -> jnp.ndarray:
    idx = jnp.clip(jnp.searchsorted(edges, delays) - 1, 0, counts.shape[0] - 1)
    return counts.at[idx].add(1.0)


@dataclass
class PredictiveCleanup:
    """Maintains the adaptive allowed-lateness bound and purge decisions."""
    coverage: float = 0.99
    confidence: float = 0.95
    initial_bound: float = 3600.0     # conservative start (paper)
    min_history: int = 200            # 'representative history'
    hist: LatenessHistogram = field(default_factory=LatenessHistogram)
    _bound: float = None

    def __post_init__(self):
        if self._bound is None:
            self._bound = self.initial_bound

    def observe(self, delays: np.ndarray) -> None:
        self.hist.update(delays)

    def current_bound(self) -> float:
        """Smallest T with DKW-lower-bounded coverage; falls back to the
        conservative initial bound until history is representative."""
        n = self.hist.total
        if n < self.min_history:
            return self._bound
        eps = math.sqrt(math.log(1.0 / (1.0 - self.confidence)) / (2.0 * n))
        grid, F = self.hist.cdf()
        ok = F - eps >= self.coverage
        if not ok.any():
            return self._bound
        self._bound = float(grid[int(np.argmax(ok))])
        return self._bound

    def expected_late_fraction_after(self, delay: float) -> float:
        """1 - F_hat(delay): the residual-usefulness estimate."""
        grid, F = self.hist.cdf()
        idx = np.searchsorted(grid, delay)
        if idx >= len(F):
            return 0.0
        return float(1.0 - F[idx])

    def should_purge(self, window_end: float, watermark: float) -> bool:
        """Purge when the window has been expired longer than the adaptive
        bound (more late events are unlikely at the target coverage)."""
        return (watermark - window_end) > self.current_bound()
