"""Store-health circuit breaker driving the graceful-degradation ladder.

The engine feeds one *signal delta* per poll tick — how many new I/O
errors + retries the scheduler recorded since the last tick. The breaker
turns that stream into a discrete **degradation level**:

    0  healthy         — nothing shed
    1  SHED_READAHEAD  — speculative readahead sweeps stop first
    2  SHED_PREFETCH   — pipelined next-round prefetch stops
    3  SYNC_ROUNDS     — fold rounds demote from the pipeline to the
                         synchronous path (no overlap, but no queued
                         rounds to lose either)
    4  BACKPRESSURE    — ingest admission is bounded; overflow batches
                         are deferred and readmitted when the store heals

Escalation: a tick whose delta reaches ``error_threshold`` climbs one
rung. De-escalation: ``cooldown_ticks`` consecutive *clean* ticks
(delta == 0) step one rung back down — the ladder is reversible, and
every transition is recorded so tests can assert the shed ORDER, not
just the final level. Purely tick-driven (no wall clocks): runs are
deterministic under fault injection.
"""
from __future__ import annotations

from typing import List, Tuple

#: ladder rungs, least- to most-disruptive (shed speculative work first,
#: demand-path service last)
LEVEL_HEALTHY = 0
LEVEL_SHED_READAHEAD = 1
LEVEL_SHED_PREFETCH = 2
LEVEL_SYNC_ROUNDS = 3
LEVEL_BACKPRESSURE = 4
MAX_LEVEL = LEVEL_BACKPRESSURE

LEVEL_NAMES = ("healthy", "shed-readahead", "shed-prefetch",
               "sync-rounds", "backpressure")


class StoreHealth:
    """Tick-based circuit breaker over the I/O error/retry stream.

    ``error_threshold <= 0`` disables the breaker entirely (``tick``
    never leaves level 0), which is how ``AionConfig.
    breaker_error_threshold = 0`` turns the ladder off.
    """

    def __init__(self, error_threshold: int = 8,
                 cooldown_ticks: int = 2):
        self.error_threshold = int(error_threshold)
        self.cooldown_ticks = max(int(cooldown_ticks), 1)
        self.level = LEVEL_HEALTHY
        self._clean_ticks = 0
        #: every (from_level, to_level) move, in order — the shed-order
        #: evidence ("readahead went first") chaos tests assert on
        self.transitions: List[Tuple[int, int]] = []
        self.stats = {"ticks": 0, "escalations": 0, "recoveries": 0}

    # ------------------------------------------------------------ breaker
    def tick(self, signal_delta: int) -> int:
        """Advance one poll tick with ``signal_delta`` new error/retry
        events; returns the (possibly new) degradation level."""
        self.stats["ticks"] += 1
        if self.error_threshold <= 0:
            return self.level
        if signal_delta >= self.error_threshold:
            self._clean_ticks = 0
            if self.level < MAX_LEVEL:
                self._move(self.level + 1)
                self.stats["escalations"] += 1
        elif signal_delta == 0:
            self._clean_ticks += 1
            if self._clean_ticks >= self.cooldown_ticks \
                    and self.level > LEVEL_HEALTHY:
                self._clean_ticks = 0
                self._move(self.level - 1)
                self.stats["recoveries"] += 1
        else:
            # sub-threshold noise: neither escalate nor count as clean
            self._clean_ticks = 0
        return self.level

    def _move(self, new_level: int) -> None:
        self.transitions.append((self.level, new_level))
        self.level = new_level

    # ------------------------------------------------------------ queries
    @property
    def name(self) -> str:
        return LEVEL_NAMES[self.level]

    def sheds_readahead(self) -> bool:
        return self.level >= LEVEL_SHED_READAHEAD

    def sheds_prefetch(self) -> bool:
        return self.level >= LEVEL_SHED_PREFETCH

    def demotes_rounds(self) -> bool:
        return self.level >= LEVEL_SYNC_ROUNDS

    def backpressures(self) -> bool:
        return self.level >= LEVEL_BACKPRESSURE
