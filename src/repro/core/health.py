"""Store-health circuit breaker driving the graceful-degradation ladder.

The engine feeds one *signal delta* per poll tick — how many new I/O
errors + retries the scheduler recorded since the last tick. The breaker
turns that stream into a discrete **degradation level**:

    0  healthy         — nothing shed
    1  SHED_READAHEAD  — speculative readahead sweeps stop first
    2  SHED_PREFETCH   — pipelined next-round prefetch stops
    3  SYNC_ROUNDS     — fold rounds demote from the pipeline to the
                         synchronous path (no overlap, but no queued
                         rounds to lose either)
    4  BACKPRESSURE    — ingest admission is bounded; overflow batches
                         are deferred and readmitted when the store heals

Escalation: a tick whose delta reaches ``error_threshold`` climbs one
rung. De-escalation: ``cooldown_ticks`` consecutive *clean* ticks
(delta == 0) step one rung back down — the ladder is reversible, and
every transition is recorded so tests can assert the shed ORDER, not
just the final level. Purely tick-driven (no wall clocks): runs are
deterministic under fault injection.
"""
from __future__ import annotations

from typing import Optional

from repro.obs import BoundedSeries, MetricsRegistry, StatsMap

#: ladder rungs, least- to most-disruptive (shed speculative work first,
#: demand-path service last)
LEVEL_HEALTHY = 0
LEVEL_SHED_READAHEAD = 1
LEVEL_SHED_PREFETCH = 2
LEVEL_SYNC_ROUNDS = 3
LEVEL_BACKPRESSURE = 4
MAX_LEVEL = LEVEL_BACKPRESSURE

LEVEL_NAMES = ("healthy", "shed-readahead", "shed-prefetch",
               "sync-rounds", "backpressure")


class StoreHealth:
    """Tick-based circuit breaker over the I/O error/retry stream.

    ``error_threshold <= 0`` disables the breaker entirely (``tick``
    never leaves level 0), which is how ``AionConfig.
    breaker_error_threshold = 0`` turns the ladder off.
    """

    def __init__(self, error_threshold: int = 8,
                 cooldown_ticks: int = 2,
                 registry: Optional[MetricsRegistry] = None,
                 max_transitions: int = 4096,
                 tenant: str = "default"):
        self.error_threshold = int(error_threshold)
        self.cooldown_ticks = max(int(cooldown_ticks), 1)
        self.level = LEVEL_HEALTHY
        self._clean_ticks = 0
        #: every (from_level, to_level) move, in order — the shed-order
        #: evidence ("readahead went first") chaos tests assert on.
        #: Bounded: a long-running engine under flapping faults would
        #: otherwise grow this without limit (the ladder is the one
        #: legacy list EngineMetrics.bounded() never capped).
        self.transitions = BoundedSeries(max_transitions)
        registry = registry if registry is not None else MetricsRegistry()
        self.stats = StatsMap(registry, "aion_health",
                              labels={"tenant": tenant})
        self.stats.register_many(["ticks", "escalations", "recoveries"])
        self._level_gauge = registry.gauge(
            "aion_health_level", "degradation ladder rung (0=healthy)",
            labelnames=("tenant",)).labels(tenant)

    # ------------------------------------------------------------ breaker
    def tick(self, signal_delta: int) -> int:
        """Advance one poll tick with ``signal_delta`` new error/retry
        events; returns the (possibly new) degradation level."""
        self.stats.inc("ticks")
        if self.error_threshold <= 0:
            return self.level
        if signal_delta >= self.error_threshold:
            self._clean_ticks = 0
            if self.level < MAX_LEVEL:
                self._move(self.level + 1)
                self.stats.inc("escalations")
        elif signal_delta == 0:
            self._clean_ticks += 1
            if self._clean_ticks >= self.cooldown_ticks \
                    and self.level > LEVEL_HEALTHY:
                self._clean_ticks = 0
                self._move(self.level - 1)
                self.stats.inc("recoveries")
        else:
            # sub-threshold noise: neither escalate nor count as clean
            self._clean_ticks = 0
        return self.level

    def _move(self, new_level: int) -> None:
        self.transitions.append((self.level, new_level))
        self.level = new_level
        self._level_gauge.set(new_level)

    # ------------------------------------------------------------ queries
    @property
    def name(self) -> str:
        return LEVEL_NAMES[self.level]

    def sheds_readahead(self) -> bool:
        return self.level >= LEVEL_SHED_READAHEAD

    def sheds_prefetch(self) -> bool:
        return self.level >= LEVEL_SHED_PREFETCH

    def demotes_rounds(self) -> bool:
        return self.level >= LEVEL_SYNC_ROUNDS

    def backpressures(self) -> bool:
        return self.level >= LEVEL_BACKPRESSURE
