"""Windowed operators: the paper's evaluation workloads as block folds.

Operators consume window state *block by block* from the m-bucket (lazy
iteration): non-blocking operators fold incrementally so compute overlaps
staging; blocking operators (§3.3) must see the whole window before
finalizing. Folds are jit-compiled over fixed block shapes.

  average      non-blocking  mean of a stream of numbers
  bigrams      non-blocking  co-occurrence counts over token payloads
                             (2-3 orders more compute, like the paper)
  stock        non-blocking  per-symbol rolling min/max/mean + 5% alerts
  lrb          non-blocking  Linear Road: per-segment vehicle counts, avg
                             speed, accident detection -> toll
  percentile   BLOCKING      exact percentiles (needs the full window)

Batched contract: operators may additionally implement ``fold_batch`` /
``finalize_batch`` — a vectorized path that folds the blocks of MANY
windows in one device pass by reducing over composite ``(window_slot,
key)`` segment ids through the batched segment-aggregate kernel.
``average``, ``stock``, and ``lrb`` implement it; ``bigrams`` and the
blocking ``percentile`` fall back to the per-window reference path.

  fold_batch(data, fills, slots, num_slots, mesh=None) -> acc
      data   {"keys": [B, cap] i32, "values": [B, cap, W] f32}
             (B stacked blocks, padded). Timestamps are deliberately NOT
             stacked: no batch fold is time-dependent within a window,
             and stacking them would pull every hot device-resident row
             back to the host (f64 host-side, f32 once staged). A future
             time-aware operator must extend the executor's gather.
      fills  [B] i32   valid events per block (ragged fills)
      slots  [B] i32   block row -> window slot (several blocks of one
                       window share a slot)
      mesh   optional 1-D device mesh (static): slot-sharded execution —
             rows arrive shard-major, slots partition across devices, and
             the kernel gathers per-slot tiles with no cross-device
             reduction (see kernels.segment_aggregate)
  finalize_batch(acc, num_slots) -> [per-window result] * num_slots
      element i is equal (up to float assoc.) to the per-window
      ``finalize(fold(...))`` over slot i's blocks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class WindowOperator:
    name: str
    blocking: bool
    init_acc: Callable[[], Any]
    fold: Callable[[Any, Dict[str, jnp.ndarray], jnp.ndarray], Any]
    finalize: Callable[[Any], Any]
    # vectorized multi-window contract (see module docstring); None ->
    # the engine falls back to per-window execution for this operator
    fold_batch: Optional[Callable[..., Any]] = None
    finalize_batch: Optional[Callable[[Any, int], list]] = None

    @property
    def supports_batch(self) -> bool:
        return self.fold_batch is not None and \
            self.finalize_batch is not None

    def run(self, blocks, fills) -> Any:
        """Reference path: fold over (block_data, fill) pairs."""
        acc = self.init_acc()
        for data, fill in zip(blocks, fills):
            acc = self.fold(acc, data, fill)
        return self.finalize(acc)

    def run_batch(self, data, fills, slots, num_slots: int,
                  mesh=None) -> list:
        """Batched path: one device pass over stacked blocks of many
        windows; returns one finalized result per slot. ``mesh`` routes
        the fold through the slot-sharded multi-device kernel (the
        contract requires fold_batch to accept it, default None)."""
        assert self.supports_batch
        acc = self.fold_batch(data, fills, slots, num_slots, mesh=mesh)
        return self.finalize_batch(acc, num_slots)


def _valid_mask(n: int, fill) -> jnp.ndarray:
    return jnp.arange(n) < fill


def _batch_valid(cap: int, fills) -> jnp.ndarray:
    """[B, cap] ragged-fill mask from per-block fills."""
    return jnp.arange(cap)[None, :] < fills[:, None]


def _per_slot_finalize(finalize: Callable[[Any], Any]):
    """finalize_batch from a per-window finalize: slice the batched acc
    (dict of [num_slots, ...] arrays) per slot and finalize each."""
    def finalize_batch(acc, num_slots):
        acc = {k: np.asarray(v) for k, v in acc.items()}
        return [finalize({k: v[i] for k, v in acc.items()})
                for i in range(num_slots)]
    return finalize_batch


# ------------------------------------------------------------------ average

def make_average(block_capacity: int, width: int) -> WindowOperator:
    from repro.kernels import segment_aggregate_batched

    def init_acc():
        return {"sum": jnp.zeros((), jnp.float32),
                "count": jnp.zeros((), jnp.float32)}

    @jax.jit
    def fold(acc, data, fill):
        mask = _valid_mask(data["values"].shape[0], fill)
        v = jnp.where(mask, data["values"][:, 0], 0.0)
        return {"sum": acc["sum"] + jnp.sum(v, dtype=jnp.float32),
                "count": acc["count"] + jnp.sum(mask, dtype=jnp.float32)}

    def finalize(acc):
        return float(acc["sum"] / jnp.maximum(acc["count"], 1.0))

    @partial(jax.jit, static_argnames=("num_slots", "mesh"))
    def fold_batch(data, fills, slots, num_slots, mesh=None):
        cap = data["values"].shape[1]
        valid = _batch_valid(cap, jnp.asarray(fills))
        # single segment per window: the composite id IS the slot
        out = segment_aggregate_batched(
            jnp.asarray(data["values"][:, :, :1], jnp.float32),
            jnp.zeros((data["values"].shape[0], cap), jnp.int32), 1,
            valid=valid, slot_ids=jnp.asarray(slots, jnp.int32),
            num_slots=num_slots, stats=("sum", "count"), mesh=mesh)
        return {"sum": out["sum"][:, 0, 0], "count": out["count"][:, 0]}

    def finalize_batch(acc, num_slots):
        s = np.asarray(acc["sum"])
        c = np.asarray(acc["count"])
        return [float(s[i] / max(c[i], 1.0)) for i in range(num_slots)]

    return WindowOperator("average", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=finalize_batch)


# ------------------------------------------------------------------ bigrams

def make_bigrams(block_capacity: int, width: int,
                 vocab: int = 256) -> WindowOperator:
    """Token payloads: each event's value row is a mini-document of
    ``width`` token ids; counts a dense [vocab, vocab] co-occurrence —
    deliberately compute-heavy like the paper's bigrams workload."""

    def init_acc():
        return jnp.zeros((vocab, vocab), jnp.float32)

    @jax.jit
    def fold(acc, data, fill):
        toks = jnp.abs(data["values"]).astype(jnp.int32) % vocab  # [n, w]
        mask = _valid_mask(toks.shape[0], fill)[:, None]
        a = jnp.where(mask[:, :1] & jnp.ones_like(toks[:, :-1], bool),
                      toks[:, :-1], 0)
        b = jnp.where(mask[:, :1] & jnp.ones_like(toks[:, 1:], bool),
                      toks[:, 1:], 0)
        onehot_a = jax.nn.one_hot(a, vocab, dtype=jnp.float32)   # [n,w-1,V]
        onehot_b = jax.nn.one_hot(b, vocab, dtype=jnp.float32)
        contrib = jnp.einsum("nwa,nwb->ab", onehot_a, onehot_b)
        contrib = contrib * (jnp.sum(mask) > 0)
        return acc + contrib

    def finalize(acc):
        return np.asarray(acc)

    return WindowOperator("bigrams", False, init_acc, fold, finalize)


# -------------------------------------------------------------------- stock

def make_stock(block_capacity: int, width: int,
               num_keys: int = 128,
               use_kernel: bool = False) -> WindowOperator:
    """Rolling per-symbol aggregates + price-warning alerts (>=5% swing).

    ``use_kernel=True`` folds each block through the ``segment_aggregate``
    Pallas kernel (interpret-mode on CPU, Mosaic on TPU) instead of the
    jnp scatter path — the engine hot loop on the MXU."""

    def init_acc():
        return {
            "min": jnp.full((num_keys,), jnp.inf, jnp.float32),
            "max": jnp.full((num_keys,), -jnp.inf, jnp.float32),
            "sum": jnp.zeros((num_keys,), jnp.float32),
            "count": jnp.zeros((num_keys,), jnp.float32),
        }

    if use_kernel:
        from repro.kernels import segment_aggregate

        @jax.jit
        def fold(acc, data, fill):
            n = data["values"].shape[0]
            mask = _valid_mask(n, fill)
            keys = jnp.asarray(data["keys"], jnp.int32) % num_keys
            out = segment_aggregate(
                jnp.asarray(data["values"][:, :1], jnp.float32), keys,
                num_keys, valid=mask)
            return {
                "min": jnp.minimum(acc["min"], out["min"][:, 0]),
                "max": jnp.maximum(acc["max"], out["max"][:, 0]),
                "sum": acc["sum"] + out["sum"][:, 0],
                "count": acc["count"] + out["count"],
            }
    else:
        @jax.jit
        def fold(acc, data, fill):
            n = data["values"].shape[0]
            mask = _valid_mask(n, fill)
            keys = jnp.where(mask, data["keys"], 0) % num_keys
            price = data["values"][:, 0]
            big = jnp.where(mask, price, -jnp.inf)
            small = jnp.where(mask, price, jnp.inf)
            return {
                "min": acc["min"].at[keys].min(jnp.where(mask, small, jnp.inf)),
                "max": acc["max"].at[keys].max(jnp.where(mask, big, -jnp.inf)),
                "sum": acc["sum"].at[keys].add(jnp.where(mask, price, 0.0)),
                "count": acc["count"].at[keys].add(mask.astype(jnp.float32)),
            }

    def finalize(acc):
        mean = np.asarray(acc["sum"] / jnp.maximum(acc["count"], 1.0))
        mx, mn = np.asarray(acc["max"]), np.asarray(acc["min"])
        with np.errstate(invalid="ignore"):
            alerts = (mx - mn) / np.where(mn > 0, mn, np.inf) >= 0.05
        return {"mean": mean, "min": mn, "max": mx, "alerts": alerts}

    from repro.kernels import segment_aggregate_batched

    @partial(jax.jit, static_argnames=("num_slots", "mesh"))
    def fold_batch(data, fills, slots, num_slots, mesh=None):
        cap = data["values"].shape[1]
        valid = _batch_valid(cap, jnp.asarray(fills))
        keys = jnp.asarray(data["keys"], jnp.int32) % num_keys
        out = segment_aggregate_batched(
            jnp.asarray(data["values"][:, :, :1], jnp.float32), keys,
            num_keys, valid=valid, slot_ids=jnp.asarray(slots, jnp.int32),
            num_slots=num_slots, mesh=mesh)
        return {"min": out["min"][:, :, 0], "max": out["max"][:, :, 0],
                "sum": out["sum"][:, :, 0], "count": out["count"]}

    return WindowOperator("stock", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=_per_slot_finalize(finalize))


# ---------------------------------------------------------------------- lrb

def make_lrb(block_capacity: int, width: int,
             num_segments: int = 256) -> WindowOperator:
    """Linear Road: values[:,0]=speed, values[:,1]=lane; per-segment vehicle
    count + average speed + accident flag (stopped vehicles) -> toll."""

    def init_acc():
        return {
            "count": jnp.zeros((num_segments,), jnp.float32),
            "speed_sum": jnp.zeros((num_segments,), jnp.float32),
            "stopped": jnp.zeros((num_segments,), jnp.float32),
        }

    @jax.jit
    def fold(acc, data, fill):
        n = data["values"].shape[0]
        mask = _valid_mask(n, fill)
        seg = jnp.where(mask, data["keys"], 0) % num_segments
        speed = data["values"][:, 0]
        stopped = mask & (speed <= 1e-3)
        return {
            "count": acc["count"].at[seg].add(mask.astype(jnp.float32)),
            "speed_sum": acc["speed_sum"].at[seg].add(
                jnp.where(mask, speed, 0.0)),
            "stopped": acc["stopped"].at[seg].add(stopped.astype(jnp.float32)),
        }

    def finalize(acc):
        count = np.asarray(acc["count"])
        avg_speed = np.asarray(acc["speed_sum"]) / np.maximum(count, 1.0)
        accident = np.asarray(acc["stopped"]) >= 2
        base = 2.0
        congestion = np.maximum(count - 50, 0.0)
        toll = np.where(accident, 0.0, base * congestion ** 2 * 1e-4)
        return {"count": count, "avg_speed": avg_speed,
                "accident": accident, "toll": toll}

    from repro.kernels import segment_aggregate_batched

    @partial(jax.jit, static_argnames=("num_slots", "mesh"))
    def fold_batch(data, fills, slots, num_slots, mesh=None):
        cap = data["values"].shape[1]
        valid = _batch_valid(cap, jnp.asarray(fills))
        seg = jnp.asarray(data["keys"], jnp.int32) % num_segments
        speed = jnp.asarray(data["values"][:, :, 0], jnp.float32)
        stopped = (valid & (speed <= 1e-3)).astype(jnp.float32)
        # width-2 payload: the segment-sum of [speed, stopped] yields both
        # speed_sum and the stopped-vehicle count in one kernel pass
        vals = jnp.stack([speed, stopped], axis=-1)
        out = segment_aggregate_batched(
            vals, seg, num_segments, valid=valid,
            slot_ids=jnp.asarray(slots, jnp.int32), num_slots=num_slots,
            stats=("sum", "count"), mesh=mesh)
        return {"count": out["count"], "speed_sum": out["sum"][:, :, 0],
                "stopped": out["sum"][:, :, 1]}

    return WindowOperator("lrb", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=_per_slot_finalize(finalize))


# --------------------------------------------------------------- percentile

def make_percentile(block_capacity: int, width: int,
                    qs=(0.5, 0.95, 0.99)) -> WindowOperator:
    """BLOCKING operator (paper §3.3): the full window must be resident
    before the percentiles can be computed."""

    def init_acc():
        return []

    def fold(acc, data, fill):
        # blocking: accumulate device blocks; compute happens in finalize
        acc.append((data["values"][:, 0], fill))
        return acc

    def finalize(acc):
        if not acc:
            return {q: float("nan") for q in qs}
        vals = jnp.concatenate([
            jnp.where(_valid_mask(v.shape[0], f), v, jnp.nan)
            for v, f in acc])
        vals = vals[~jnp.isnan(vals)]
        return {q: float(jnp.quantile(vals, q)) for q in qs}

    return WindowOperator("percentile", True, init_acc, fold, finalize)


OPERATORS = {
    "average": make_average,
    "bigrams": make_bigrams,
    "stock": make_stock,
    "lrb": make_lrb,
    "percentile": make_percentile,
}


def make_operator(name: str, block_capacity: int, width: int,
                  **kw) -> WindowOperator:
    if name not in OPERATORS:
        raise KeyError(f"unknown operator {name!r}")
    return OPERATORS[name](block_capacity, width, **kw)
