"""Windowed operators: the paper's evaluation workloads as block folds.

Operators consume window state *block by block* from the m-bucket (lazy
iteration): non-blocking operators fold incrementally so compute overlaps
staging; blocking operators (§3.3) must see the whole window before
finalizing. Folds are jit-compiled over fixed block shapes.

  average      non-blocking  mean of a stream of numbers
  bigrams      non-blocking  co-occurrence counts over token payloads
                             (2-3 orders more compute, like the paper)
  stock        non-blocking  per-symbol rolling min/max/mean + 5% alerts
  lrb          non-blocking  Linear Road: per-segment vehicle counts, avg
                             speed, accident detection -> toll
  percentile   BLOCKING      exact percentiles (needs the full window)

Batched contract: operators may additionally implement ``fold_batch`` /
``finalize_batch`` — a vectorized path that folds the blocks of MANY
windows in one device pass by reducing over composite ``(window_slot,
key)`` segment ids through the batched segment-aggregate kernel.
All five operators implement it — including the blocking ``percentile``,
whose accumulator is a per-slot sorted run merged by sorted-merge.

  fold_batch(data, fills, slots, num_slots, mesh=None, table=None,
             splitk=0) -> acc
      data   table is None: {"keys": [B, cap] i32, "values": [B, cap, W]
             f32} — B stacked blocks, padded (the legacy device-concat /
             host-stack gather).
             table given: the persistent pool ARENAS — {"keys":
             [pool_slots, cap] i32, "values": [pool_slots, cap, W] f32};
             rows are *referenced* by the table, never stacked.
             Timestamps are deliberately NOT part of either layout: no
             batch fold is time-dependent within a window, and carrying
             them would pull every hot device-resident row back to the
             host (f64 host-side, f32 once staged). A future time-aware
             operator must extend the executor's gather.
      fills  [B] i32   valid events per block (ragged fills)
      slots  [B] i32   block row -> window slot (several blocks of one
                       window share a slot)
      mesh   optional 1-D device mesh (static): slot-sharded execution —
             rows arrive shard-major, slots partition across devices, and
             the kernel gathers per-slot tiles with no cross-device
             reduction (see kernels.segment_aggregate)
      table  optional [B] i32 pool-slot indices (the block-table path):
             the fold gathers event tiles straight from the arena —
             in-kernel on the Mosaic backend, one take along the pool
             axis on the dense backend (zero per-batch host copies)
      splitk optional chunk size (static): > 0 routes block-table folds
             through the split-K kernel (fixed-shape chunks of ``splitk``
             rows, per-chunk partial accumulators merged on-device), and
             with a mesh routes stacked folds through the row-balanced
             sharded variant. Operators whose fold cannot reduce into
             plain per-slot partials must ignore it and declare
             ``supports_splitk=False`` (the bigram scatter masks rows by
             slot ownership — balanced rows would be silently dropped).
  finalize_batch(acc, num_slots) -> [per-window result] * num_slots
      element i is equal (up to float assoc.) to the per-window
      ``finalize(fold(...))`` over slot i's blocks.
  merge_acc(a, b) -> acc
      combines two partial batch accumulators over the SAME slot layout —
      what lets the executor fold the already-resident block table while
      demand pool-fills are in flight (then fold the newly-filled slots
      and merge), and what merges the split-K executor's per-chunk-group
      partials. Default (``default_merge_acc``): dict values merge by
      key — 'min' -> elementwise minimum, 'max' -> maximum, everything
      else adds; correct for every built-in *reduction* accumulator.
      Accumulators with a different merge identity MUST override via the
      ``merge`` field — percentile's sorted runs concatenate and re-sort
      (adding them would corrupt the state).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def default_merge_acc(a: Dict[str, Any], b: Dict[str, Any]
                      ) -> Dict[str, Any]:
    """Combine two partial batch accumulators (dicts of per-slot arrays):
    'min' -> elementwise minimum, 'max' -> maximum, everything else adds.
    Every built-in batch accumulator conforms (sums, counts, extrema)."""
    out = {}
    for k in a:
        if k == "min":
            out[k] = jnp.minimum(a[k], b[k])
        elif k == "max":
            out[k] = jnp.maximum(a[k], b[k])
        else:
            out[k] = a[k] + b[k]
    return out


@dataclass
class WindowOperator:
    name: str
    blocking: bool
    init_acc: Callable[[], Any]
    fold: Callable[[Any, Dict[str, jnp.ndarray], jnp.ndarray], Any]
    finalize: Callable[[Any], Any]
    # vectorized multi-window contract (see module docstring); None ->
    # the engine falls back to per-window execution for this operator
    fold_batch: Optional[Callable[..., Any]] = None
    finalize_batch: Optional[Callable[[Any, int], list]] = None
    # partial-accumulator combine for the overlapped pooled fold; None ->
    # ``default_merge_acc`` (dict accs merging by key semantics)
    merge: Optional[Callable[[Any, Any], Any]] = None
    # split-K safety: True when fold_batch reduces into plain per-slot
    # partial accumulators, so rows may be chunked/balanced arbitrarily
    # and partials merged via merge_acc. False for folds that mask rows
    # by slot ownership (the big-vocab bigram scatter) — the executor
    # must not balance their rows or chunk their tables.
    supports_splitk: bool = False

    @property
    def supports_batch(self) -> bool:
        return self.fold_batch is not None and \
            self.finalize_batch is not None

    def merge_acc(self, a: Any, b: Any) -> Any:
        if self.merge is not None:
            return self.merge(a, b)
        return default_merge_acc(a, b)

    def run(self, blocks, fills) -> Any:
        """Reference path: fold over (block_data, fill) pairs."""
        acc = self.init_acc()
        for data, fill in zip(blocks, fills):
            acc = self.fold(acc, data, fill)
        return self.finalize(acc)

    def run_batch(self, data, fills, slots, num_slots: int,
                  mesh=None, table=None, splitk: int = 0) -> list:
        """Batched path: one device pass over the blocks of many windows;
        returns one finalized result per slot. ``mesh`` routes the fold
        through the slot-sharded multi-device kernel; ``table`` switches
        ``data`` from stacked rows to the pool arenas; ``splitk`` chunks
        the fold into fixed-shape partials (the contract requires
        fold_batch to accept all three, defaults None/0)."""
        assert self.supports_batch
        acc = self.fold_batch(data, fills, slots, num_slots, mesh=mesh,
                              table=table, splitk=splitk)
        return self.finalize_batch(acc, num_slots)


def _valid_mask(n: int, fill) -> jnp.ndarray:
    return jnp.arange(n) < fill


def _batch_valid(cap: int, fills) -> jnp.ndarray:
    """[B, cap] ragged-fill mask from per-block fills."""
    return jnp.arange(cap)[None, :] < fills[:, None]


def _per_slot_finalize(finalize: Callable[[Any], Any]):
    """finalize_batch from a per-window finalize: slice the batched acc
    (dict of [num_slots, ...] arrays) per slot and finalize each."""
    def finalize_batch(acc, num_slots):
        acc = {k: np.asarray(v) for k, v in acc.items()}
        return [finalize({k: v[i] for k, v in acc.items()})
                for i in range(num_slots)]
    return finalize_batch


# ------------------------------------------------------------------ average

def make_average(block_capacity: int, width: int) -> WindowOperator:
    from repro.kernels import (
        segment_aggregate_batched, segment_aggregate_block_table,
        segment_aggregate_block_table_splitk,
    )

    def init_acc():
        return {"sum": jnp.zeros((), jnp.float32),
                "count": jnp.zeros((), jnp.float32)}

    @jax.jit
    def fold(acc, data, fill):
        mask = _valid_mask(data["values"].shape[0], fill)
        v = jnp.where(mask, data["values"][:, 0], 0.0)
        return {"sum": acc["sum"] + jnp.sum(v, dtype=jnp.float32),
                "count": acc["count"] + jnp.sum(mask, dtype=jnp.float32)}

    def finalize(acc):
        return float(acc["sum"] / jnp.maximum(acc["count"], 1.0))

    @partial(jax.jit, static_argnames=("num_slots", "mesh", "splitk"))
    def fold_batch(data, fills, slots, num_slots, mesh=None, table=None,
                   splitk=0):
        cap = data["values"].shape[1]
        valid = _batch_valid(cap, jnp.asarray(fills))
        slots = jnp.asarray(slots, jnp.int32)
        # single segment per window: the composite id IS the slot
        if table is not None:
            # full arena + num_cols: the width-1 selection happens after
            # the in-launch gather, never as an arena-wide slice copy
            if splitk > 0:
                out = segment_aggregate_block_table_splitk(
                    data["values"],
                    jnp.zeros((table.shape[0], cap), jnp.int32), table, 1,
                    splitk, valid=valid, slot_ids=slots,
                    num_slots=num_slots, stats=("sum", "count"),
                    mesh=mesh, num_cols=1)
            else:
                out = segment_aggregate_block_table(
                    data["values"],
                    jnp.zeros((table.shape[0], cap), jnp.int32), table, 1,
                    valid=valid, slot_ids=slots, num_slots=num_slots,
                    stats=("sum", "count"), mesh=mesh, num_cols=1)
        else:
            out = segment_aggregate_batched(
                jnp.asarray(data["values"][:, :, :1], jnp.float32),
                jnp.zeros((data["values"].shape[0], cap), jnp.int32), 1,
                valid=valid, slot_ids=slots,
                num_slots=num_slots, stats=("sum", "count"), mesh=mesh,
                splitk=splitk)
        return {"sum": out["sum"][:, 0, 0], "count": out["count"][:, 0]}

    def finalize_batch(acc, num_slots):
        s = np.asarray(acc["sum"])
        c = np.asarray(acc["count"])
        return [float(s[i] / max(c[i], 1.0)) for i in range(num_slots)]

    return WindowOperator("average", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=finalize_batch,
                          supports_splitk=True)


# ------------------------------------------------------------------ bigrams

def _bigram_segment_count(ids, pval, slots, num_slots: int, vocab: int,
                          mesh) -> jnp.ndarray:
    """Composite (window_slot, pair) segment COUNT via one scatter —
    the big-vocab bigram path, where the one-hot matmul's
    [rows, num_slots * vocab^2] operand is memory-infeasible.

    ids [B, P] local pair ids (a * vocab + b), pval [B, P] pair validity,
    slots [B] window slots -> [num_slots, vocab^2] counts. With a mesh
    the scatter shards exactly like the dense kernel: rows arrive
    shard-major, each device rewrites its slots to shard-local indices
    and scatters into its own [slots_per * vocab^2] tile — psum-free
    (slots are disjoint), so sharded bigram batches genuinely
    distribute rather than silently falling back to one device.
    """
    v2 = vocab * vocab

    def flat_count(ids_, pv_, sl_, ns):
        total = ns * v2
        sid = (sl_.astype(jnp.int32)[:, None] * v2 + ids_).reshape(-1)
        sid = jnp.where(pv_.reshape(-1), sid, total)      # park invalid
        return jax.ops.segment_sum(
            pv_.reshape(-1).astype(jnp.float32), sid,
            num_segments=total + 1)[:total].reshape(ns, v2)

    if mesh is None or mesh.size <= 1:
        return flat_count(ids, pval, slots, num_slots)
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import shard_map_compat
    axis = mesh.axis_names[0]
    num_devices = mesh.shape[axis]
    if ids.shape[0] % num_devices or num_slots % num_devices:
        # rows/slots that don't divide the mesh (callers outside the
        # executor's packed layout): correct unsharded fallback
        return flat_count(ids, pval, slots, num_slots)
    slots_per = num_slots // num_devices

    def shard_fn(ids_, pv_, sl_):
        base = jax.lax.axis_index(axis) * slots_per
        local = sl_.astype(jnp.int32) - base
        own = (local >= 0) & (local < slots_per)
        local = jnp.where(own, local, 0)
        return flat_count(ids_, pv_ & own[:, None], local, slots_per)

    f = shard_map_compat(shard_fn, mesh,
                         (P(axis, None), P(axis, None), P(axis)),
                         P(axis, None))
    return f(ids, pval.astype(bool), slots)


def make_bigrams(block_capacity: int, width: int,
                 vocab: int = 256) -> WindowOperator:
    """Token payloads: each event's value row is a mini-document of
    ``width`` token ids; counts a dense [vocab, vocab] co-occurrence —
    deliberately compute-heavy like the paper's bigrams workload.

    Batch contract: every adjacent token pair is an "event" with the
    composite segment id ``(window_slot, a * vocab + b)`` and the bigram
    table is the per-slot segment COUNT — so bigrams ride the batched /
    pooled path through the same count-only kernel as the keyed
    operators (block-diagonal over slots: a pair only lands in its own
    window's [vocab, vocab] tile). The one-hot formulation materializes
    ``[rows, num_slots * vocab^2]``, which is only feasible for small
    vocab x slot products; above ``_BIGRAM_ONEHOT_LIMIT`` columns the
    fold switches to an equivalent one-launch ``segment_sum`` scatter
    (same composite ids, no one-hot temps).
    """
    from repro.kernels import segment_aggregate_batched

    _BIGRAM_ONEHOT_LIMIT = 8192

    def init_acc():
        return jnp.zeros((vocab, vocab), jnp.float32)

    @jax.jit
    def fold(acc, data, fill):
        toks = jnp.abs(data["values"]).astype(jnp.int32) % vocab  # [n, w]
        mask = _valid_mask(toks.shape[0], fill)
        onehot_a = jax.nn.one_hot(toks[:, :-1], vocab,
                                  dtype=jnp.float32)             # [n,w-1,V]
        # masking one side of the product suffices: an invalid row's
        # pairs contribute nothing anywhere (previously they collapsed
        # onto (0, 0) and were phantom-counted)
        onehot_a = onehot_a * mask[:, None, None]
        onehot_b = jax.nn.one_hot(toks[:, 1:], vocab, dtype=jnp.float32)
        contrib = jnp.einsum("nwa,nwb->ab", onehot_a, onehot_b)
        return acc + contrib

    def finalize(acc):
        return np.asarray(acc)

    @partial(jax.jit, static_argnames=("num_slots", "mesh", "splitk"))
    def fold_batch(data, fills, slots, num_slots, mesh=None, table=None,
                   splitk=0):
        # splitk deliberately ignored (supports_splitk=False): the
        # big-vocab scatter masks rows by slot ownership, so balanced or
        # chunk-padded rows would be silently dropped
        vals = data["values"]
        if table is not None:
            # pool gather: one take along the arena's pool axis (the
            # pair ids are derived values, so unlike the keyed folds the
            # tokens cannot be gathered in-kernel)
            vals = jnp.take(vals, table, axis=0)
        b, cap, w = vals.shape
        slots = jnp.asarray(slots, jnp.int32)
        if w < 2:
            return {"pairs": jnp.zeros((num_slots, vocab, vocab),
                                       jnp.float32)}
        toks = jnp.abs(vals).astype(jnp.int32) % vocab        # [B, cap, w]
        pair = toks[:, :, :-1] * vocab + toks[:, :, 1:]       # [B, cap, w-1]
        valid = _batch_valid(cap, jnp.asarray(fills))         # [B, cap]
        pvalid = jnp.broadcast_to(valid[:, :, None], pair.shape)
        ids = pair.reshape(b, cap * (w - 1))
        pval = pvalid.reshape(b, cap * (w - 1))
        if num_slots * vocab * vocab <= _BIGRAM_ONEHOT_LIMIT:
            ones = jnp.ones((b, cap * (w - 1), 1), jnp.float32)
            out = segment_aggregate_batched(
                ones, ids, vocab * vocab, valid=pval, slot_ids=slots,
                num_slots=num_slots, stats=("count",), mesh=mesh)
            cnt = out["count"]
        else:
            cnt = _bigram_segment_count(ids, pval, slots, num_slots,
                                        vocab, mesh)
        return {"pairs": cnt.reshape(num_slots, vocab, vocab)}

    def finalize_batch(acc, num_slots):
        pairs = np.asarray(acc["pairs"])
        return [pairs[i] for i in range(num_slots)]

    return WindowOperator("bigrams", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=finalize_batch)


# -------------------------------------------------------------------- stock

def make_stock(block_capacity: int, width: int,
               num_keys: int = 128,
               use_kernel: bool = False) -> WindowOperator:
    """Rolling per-symbol aggregates + price-warning alerts (>=5% swing).

    ``use_kernel=True`` folds each block through the ``segment_aggregate``
    Pallas kernel (interpret-mode on CPU, Mosaic on TPU) instead of the
    jnp scatter path — the engine hot loop on the MXU."""

    def init_acc():
        return {
            "min": jnp.full((num_keys,), jnp.inf, jnp.float32),
            "max": jnp.full((num_keys,), -jnp.inf, jnp.float32),
            "sum": jnp.zeros((num_keys,), jnp.float32),
            "count": jnp.zeros((num_keys,), jnp.float32),
        }

    if use_kernel:
        from repro.kernels import segment_aggregate

        @jax.jit
        def fold(acc, data, fill):
            n = data["values"].shape[0]
            mask = _valid_mask(n, fill)
            keys = jnp.asarray(data["keys"], jnp.int32) % num_keys
            out = segment_aggregate(
                jnp.asarray(data["values"][:, :1], jnp.float32), keys,
                num_keys, valid=mask)
            return {
                "min": jnp.minimum(acc["min"], out["min"][:, 0]),
                "max": jnp.maximum(acc["max"], out["max"][:, 0]),
                "sum": acc["sum"] + out["sum"][:, 0],
                "count": acc["count"] + out["count"],
            }
    else:
        @jax.jit
        def fold(acc, data, fill):
            n = data["values"].shape[0]
            mask = _valid_mask(n, fill)
            keys = jnp.where(mask, data["keys"], 0) % num_keys
            price = data["values"][:, 0]
            big = jnp.where(mask, price, -jnp.inf)
            small = jnp.where(mask, price, jnp.inf)
            return {
                "min": acc["min"].at[keys].min(jnp.where(mask, small, jnp.inf)),
                "max": acc["max"].at[keys].max(jnp.where(mask, big, -jnp.inf)),
                "sum": acc["sum"].at[keys].add(jnp.where(mask, price, 0.0)),
                "count": acc["count"].at[keys].add(mask.astype(jnp.float32)),
            }

    def finalize(acc):
        mean = np.asarray(acc["sum"] / jnp.maximum(acc["count"], 1.0))
        mx, mn = np.asarray(acc["max"]), np.asarray(acc["min"])
        with np.errstate(invalid="ignore"):
            alerts = (mx - mn) / np.where(mn > 0, mn, np.inf) >= 0.05
        return {"mean": mean, "min": mn, "max": mx, "alerts": alerts}

    from repro.kernels import (
        segment_aggregate_batched, segment_aggregate_block_table,
        segment_aggregate_block_table_splitk,
    )

    @partial(jax.jit, static_argnames=("num_slots", "mesh", "splitk"))
    def fold_batch(data, fills, slots, num_slots, mesh=None, table=None,
                   splitk=0):
        cap = data["values"].shape[1]
        valid = _batch_valid(cap, jnp.asarray(fills))
        slots = jnp.asarray(slots, jnp.int32)
        if table is not None:
            # keys gather cheaply via one take (int32, needed to derive
            # segment ids); the fat value tiles stay in the arena and are
            # gathered inside the kernel launch (num_cols selects the
            # price column post-gather — no arena-wide slice copy)
            keys = jnp.take(jnp.asarray(data["keys"], jnp.int32), table,
                            axis=0) % num_keys
            if splitk > 0:
                out = segment_aggregate_block_table_splitk(
                    data["values"], keys, table, num_keys, splitk,
                    valid=valid, slot_ids=slots, num_slots=num_slots,
                    mesh=mesh, num_cols=1)
            else:
                out = segment_aggregate_block_table(
                    data["values"], keys,
                    table, num_keys, valid=valid, slot_ids=slots,
                    num_slots=num_slots, mesh=mesh, num_cols=1)
        else:
            keys = jnp.asarray(data["keys"], jnp.int32) % num_keys
            out = segment_aggregate_batched(
                jnp.asarray(data["values"][:, :, :1], jnp.float32), keys,
                num_keys, valid=valid, slot_ids=slots,
                num_slots=num_slots, mesh=mesh, splitk=splitk)
        return {"min": out["min"][:, :, 0], "max": out["max"][:, :, 0],
                "sum": out["sum"][:, :, 0], "count": out["count"]}

    return WindowOperator("stock", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=_per_slot_finalize(finalize),
                          supports_splitk=True)


# ---------------------------------------------------------------------- lrb

def make_lrb(block_capacity: int, width: int,
             num_segments: int = 256) -> WindowOperator:
    """Linear Road: values[:,0]=speed, values[:,1]=lane; per-segment vehicle
    count + average speed + accident flag (stopped vehicles) -> toll."""

    def init_acc():
        return {
            "count": jnp.zeros((num_segments,), jnp.float32),
            "speed_sum": jnp.zeros((num_segments,), jnp.float32),
            "stopped": jnp.zeros((num_segments,), jnp.float32),
        }

    @jax.jit
    def fold(acc, data, fill):
        n = data["values"].shape[0]
        mask = _valid_mask(n, fill)
        seg = jnp.where(mask, data["keys"], 0) % num_segments
        speed = data["values"][:, 0]
        stopped = mask & (speed <= 1e-3)
        return {
            "count": acc["count"].at[seg].add(mask.astype(jnp.float32)),
            "speed_sum": acc["speed_sum"].at[seg].add(
                jnp.where(mask, speed, 0.0)),
            "stopped": acc["stopped"].at[seg].add(stopped.astype(jnp.float32)),
        }

    def finalize(acc):
        count = np.asarray(acc["count"])
        avg_speed = np.asarray(acc["speed_sum"]) / np.maximum(count, 1.0)
        accident = np.asarray(acc["stopped"]) >= 2
        base = 2.0
        congestion = np.maximum(count - 50, 0.0)
        toll = np.where(accident, 0.0, base * congestion ** 2 * 1e-4)
        return {"count": count, "avg_speed": avg_speed,
                "accident": accident, "toll": toll}

    from repro.kernels import segment_aggregate_batched

    @partial(jax.jit, static_argnames=("num_slots", "mesh", "splitk"))
    def fold_batch(data, fills, slots, num_slots, mesh=None, table=None,
                   splitk=0):
        keys, values = data["keys"], data["values"]
        if table is not None:
            # the fold consumes DERIVED values ([speed, stopped]), so the
            # pool gather is one take along the arena's pool axis per
            # tensor — still a single fused gather op, not O(rows)
            # concats. splitk chunking therefore happens at the executor
            # (chunk-group launches merged via merge_acc) rather than
            # inside this launch; the stacked sharded fold below still
            # honours the balanced split-K layout.
            keys = jnp.take(jnp.asarray(keys, jnp.int32), table, axis=0)
            values = jnp.take(values, table, axis=0)
        cap = values.shape[1]
        valid = _batch_valid(cap, jnp.asarray(fills))
        seg = jnp.asarray(keys, jnp.int32) % num_segments
        speed = jnp.asarray(values[:, :, 0], jnp.float32)
        stopped = (valid & (speed <= 1e-3)).astype(jnp.float32)
        # width-2 payload: the segment-sum of [speed, stopped] yields both
        # speed_sum and the stopped-vehicle count in one kernel pass
        vals = jnp.stack([speed, stopped], axis=-1)
        out = segment_aggregate_batched(
            vals, seg, num_segments, valid=valid,
            slot_ids=jnp.asarray(slots, jnp.int32), num_slots=num_slots,
            stats=("sum", "count"), mesh=mesh, splitk=splitk)
        return {"count": out["count"], "speed_sum": out["sum"][:, :, 0],
                "stopped": out["sum"][:, :, 1]}

    return WindowOperator("lrb", False, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=_per_slot_finalize(finalize),
                          supports_splitk=True)


# --------------------------------------------------------------- percentile

def make_percentile(block_capacity: int, width: int,
                    qs=(0.5, 0.95, 0.99)) -> WindowOperator:
    """BLOCKING operator (paper §3.3): the full window must be resident
    before the percentiles can be computed.

    Batch contract (PR 8, the last per-window straggler): the per-slot
    accumulator is a NaN-padded **sorted run** of the slot's valid values
    (``jnp.sort`` orders NaN last, so the first ``count`` entries are the
    ascending data) — exact, not a sketch. Two accumulators merge by
    concatenating runs and re-sorting (a sorted-merge), which is why the
    ``merge`` override exists: the default add-merge would corrupt the
    state. The merge composes with the split-K executor's chunk-group
    partials; ``mesh``/``splitk`` are otherwise ignored inside the fold
    (a sort has no per-slot reduction to shard)."""

    def init_acc():
        return []

    def fold(acc, data, fill):
        # blocking: accumulate device blocks; compute happens in finalize
        acc.append((data["values"][:, 0], fill))
        return acc

    def finalize(acc):
        if not acc:
            return {q: float("nan") for q in qs}
        vals = jnp.concatenate([
            jnp.where(_valid_mask(v.shape[0], f), v, jnp.nan)
            for v, f in acc])
        vals = vals[~jnp.isnan(vals)]
        return {q: float(jnp.quantile(vals, q)) for q in qs}

    @partial(jax.jit, static_argnames=("num_slots", "mesh", "splitk"))
    def fold_batch(data, fills, slots, num_slots, mesh=None, table=None,
                   splitk=0):
        vals = data["values"]
        if table is not None:
            # pool gather: one take along the arena's pool axis (the
            # sort consumes every row's values, so there is no in-kernel
            # formulation to route through)
            vals = jnp.take(vals, table, axis=0)
        v = jnp.asarray(vals[:, :, 0], jnp.float32)           # [B, cap]
        b, cap = v.shape
        valid = _batch_valid(cap, jnp.asarray(fills))
        sl = jnp.asarray(slots, jnp.int32)
        keep = valid[:, :, None] & (sl[:, None, None] ==
                                    jnp.arange(num_slots)[None, None, :])
        mat = jnp.where(keep, v[:, :, None], jnp.nan) \
            .transpose(2, 0, 1).reshape(num_slots, b * cap)
        return {"sorted": jnp.sort(mat, axis=1),
                "count": jnp.sum(keep, axis=(0, 1)).astype(jnp.int32)}

    def merge(a, b):
        # sorted-merge: concatenate the runs and re-sort (NaN padding
        # stays at the tail); counts add
        return {"sorted": jnp.sort(jnp.concatenate(
                    [a["sorted"], b["sorted"]], axis=1), axis=1),
                "count": a["count"] + b["count"]}

    def finalize_batch(acc, num_slots):
        srt = np.asarray(acc["sorted"])
        cnt = np.asarray(acc["count"])
        out = []
        for i in range(num_slots):
            n = int(cnt[i])
            if n == 0:
                out.append({q: float("nan") for q in qs})
            else:
                out.append({q: float(np.quantile(srt[i, :n], q))
                            for q in qs})
        return out

    return WindowOperator("percentile", True, init_acc, fold, finalize,
                          fold_batch=fold_batch,
                          finalize_batch=finalize_batch,
                          merge=merge, supports_splitk=True)


OPERATORS = {
    "average": make_average,
    "bigrams": make_bigrams,
    "stock": make_stock,
    "lrb": make_lrb,
    "percentile": make_percentile,
}


def make_operator(name: str, block_capacity: int, width: int,
                  **kw) -> WindowOperator:
    if name not in OPERATORS:
        raise KeyError(f"unknown operator {name!r}")
    return OPERATORS[name](block_capacity, width, **kw)
