"""Events: keyed, event-timestamped records in SoA layout.

The engine works on *batches* of events (structure-of-arrays), the
accelerator-native analogue of Flink's per-record streams: dense arrays
batch into fixed-size blocks (``core.buckets``) that tile cleanly into
VMEM and transfer in large contiguous DMAs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class EventBatch:
    """keys: [n] int32; timestamps: [n] float64 (event-time seconds);
    values: [n, width] float32."""
    keys: np.ndarray
    timestamps: np.ndarray
    values: np.ndarray

    def __post_init__(self):
        self.keys = np.asarray(self.keys, dtype=np.int32)
        self.timestamps = np.asarray(self.timestamps, dtype=np.float64)
        self.values = np.asarray(self.values, dtype=np.float32)
        if self.values.ndim == 1:
            self.values = self.values[:, None]
        n = len(self.keys)
        assert len(self.timestamps) == n and len(self.values) == n

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def width(self) -> int:
        return self.values.shape[1]

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.timestamps.nbytes + self.values.nbytes

    def select(self, mask: np.ndarray) -> "EventBatch":
        return EventBatch(self.keys[mask], self.timestamps[mask],
                          self.values[mask])

    def slice(self, start: int, stop: int) -> "EventBatch":
        return EventBatch(self.keys[start:stop], self.timestamps[start:stop],
                          self.values[start:stop])

    @staticmethod
    def empty(width: int) -> "EventBatch":
        return EventBatch(np.zeros((0,), np.int32), np.zeros((0,), np.float64),
                          np.zeros((0, width), np.float32))

    @staticmethod
    def concat(batches: list) -> "EventBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            raise ValueError("concat of empty list")
        return EventBatch(
            np.concatenate([b.keys for b in batches]),
            np.concatenate([b.timestamps for b in batches]),
            np.concatenate([b.values for b in batches]),
        )

    def partition_by_shard(self, num_shards: int) -> list:
        """Key-hash partitioning (Flink keyBy analogue) for distributed
        ingest: shard = hash(key) % num_shards."""
        shard = (self.keys.astype(np.uint32) * np.uint32(2654435761)
                 >> np.uint32(16)) % np.uint32(num_shards)
        return [self.select(shard == s) for s in range(num_shards)]
