"""Time domains and watermarks (paper §2).

Event-time drives window assignment; processing-time drives scheduling.
Watermarks are best guesses: events with ts < watermark are *late* and are
routed to past windows instead of being dropped.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class WatermarkTracker:
    """Tracks the current watermark and classifies lateness."""
    watermark: float = -np.inf

    def advance(self, wm: float) -> bool:
        if wm > self.watermark:
            self.watermark = wm
            return True
        return False

    def lateness_of(self, timestamps: np.ndarray) -> np.ndarray:
        """Per-event lateness in seconds (<= 0 for on-time events)."""
        return self.watermark - timestamps

    def is_late(self, timestamps: np.ndarray) -> np.ndarray:
        return timestamps < self.watermark


@dataclass
class PeriodicWatermarkGenerator:
    """Emits watermark = max_seen_ts - slack every ``period`` seconds of
    processing time (paper: periodic watermarks make re-execution times
    predictable — the proactive cache exploits that)."""
    period: float
    slack: float = 0.0
    _last_emit: float = field(default=-np.inf, repr=False)
    _max_ts: float = field(default=-np.inf, repr=False)

    def observe(self, timestamps: np.ndarray) -> None:
        if len(timestamps):
            self._max_ts = max(self._max_ts, float(np.max(timestamps)))

    def maybe_emit(self, processing_time: float) -> Optional[float]:
        if processing_time - self._last_emit >= self.period and \
                np.isfinite(self._max_ts):
            self._last_emit = processing_time
            return self._max_ts - self.slack
        return None


@dataclass
class PunctuatedWatermarkGenerator:
    """Emits when a data-dependent predicate fires (e.g. a flush event)."""
    predicate: Callable[[np.ndarray, np.ndarray], Optional[float]]

    def observe_and_maybe_emit(self, keys: np.ndarray,
                               timestamps: np.ndarray) -> Optional[float]:
        return self.predicate(keys, timestamps)
