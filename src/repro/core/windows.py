"""Window assigners (paper §2): tumbling, sliding, session, count.

A window is identified by ``WindowId(start, end)`` in event-time seconds.
Assignment is vectorized over event batches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np


@dataclass(frozen=True, order=True)
class WindowId:
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


class WindowAssigner:
    def assign(self, timestamps: np.ndarray) -> List[Tuple[WindowId, np.ndarray]]:
        """Returns [(window, index_array)] covering all events."""
        raise NotImplementedError


@dataclass
class TumblingWindows(WindowAssigner):
    size: float

    def assign(self, timestamps):
        starts = np.floor(timestamps / self.size) * self.size
        out = []
        for s in np.unique(starts):
            idx = np.nonzero(starts == s)[0]
            out.append((WindowId(float(s), float(s + self.size)), idx))
        return out


@dataclass
class SlidingWindows(WindowAssigner):
    size: float
    slide: float

    def assign(self, timestamps):
        n_overlap = int(np.ceil(self.size / self.slide))
        out: Dict[float, list] = {}
        base = np.floor(timestamps / self.slide) * self.slide
        for k in range(n_overlap):
            starts = base - k * self.slide
            valid = (timestamps >= starts) & (timestamps < starts + self.size)
            for s in np.unique(starts[valid]):
                idx = np.nonzero(valid & (starts == s))[0]
                out.setdefault(float(s), []).append(idx)
        return [(WindowId(s, s + self.size),
                 np.concatenate(v) if len(v) > 1 else v[0])
                for s, v in sorted(out.items())]


@dataclass
class SessionWindows(WindowAssigner):
    """Per-key sessions separated by >= gap. Stateless approximation over a
    batch: sessions are computed within the batch; the engine merges
    adjacent session windows on append."""
    gap: float

    def assign(self, timestamps):
        if len(timestamps) == 0:
            return []
        order = np.argsort(timestamps, kind="stable")
        ts = timestamps[order]
        breaks = np.nonzero(np.diff(ts) > self.gap)[0]
        bounds = np.concatenate([[0], breaks + 1, [len(ts)]])
        out = []
        for i in range(len(bounds) - 1):
            sel = order[bounds[i]:bounds[i + 1]]
            w = WindowId(float(timestamps[sel].min()),
                         float(timestamps[sel].max() + self.gap))
            out.append((w, np.sort(sel)))
        return out


@dataclass
class CountWindows(WindowAssigner):
    """Groups of ``count`` consecutive events (engine tracks the running
    offset; windows are keyed by sequence number encoded as start)."""
    count: int
    _offset: int = 0

    def assign(self, timestamps):
        n = len(timestamps)
        out = []
        pos = 0
        while pos < n:
            wid = (self._offset + pos) // self.count
            take = min(self.count - (self._offset + pos) % self.count, n - pos)
            out.append((WindowId(float(wid), float(wid + 1)),
                        np.arange(pos, pos + take)))
            pos += take
        self._offset += n
        return out
