"""Proactive caching (paper §3.2): predict when a window will (re-)execute
and pre-stage its p-bucket state Δt ahead of that time.

* Periodic watermarks make re-execution times predictable: the engine knows
  the watermark period and the trigger's planned execution times. For the
  *first* late re-execution of window w, pre-staging starts pessimistically
  when the window preceding w fully expires; during that staging we measure
  Δt (staging seconds) weighted by the number of staged events, and use the
  per-event estimate for all subsequent pre-stagings.
* Punctuated watermarks carry no period: pre-staging starts as soon as a
  late event for w arrives (the re-execution it predicts may be delayed
  until pre-staging concludes).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.buckets import WindowState
from repro.core.windows import WindowId


@dataclass
class StagingCostModel:
    """Online Δt estimate: EWMA of staging seconds per event (the paper's
    'overall time taken weighted by the number of staged events')."""
    seconds_per_event: float = 1e-6
    alpha: float = 0.3
    observations: int = 0

    def observe(self, seconds: float, events: int) -> None:
        if events <= 0:
            return
        per_event = seconds / events
        if self.observations == 0:
            self.seconds_per_event = per_event
        else:
            self.seconds_per_event = (self.alpha * per_event
                                      + (1 - self.alpha) * self.seconds_per_event)
        self.observations += 1

    def delta_t(self, events: int) -> float:
        return self.seconds_per_event * max(events, 0)


@dataclass(order=True)
class _Planned:
    stage_at: float
    window: WindowId = field(compare=False)


class PrestageScheduler:
    """Decides *when* to issue stage requests for past windows.

    ``plan(window, exec_time, now)`` registers a future re-execution;
    ``due(now)`` returns windows whose pre-staging should start now.
    """

    def __init__(self, cost_model: Optional[StagingCostModel] = None,
                 punctuated: bool = False):
        self.cost = cost_model or StagingCostModel()
        self.punctuated = punctuated
        self._heap: List[_Planned] = []
        self._planned: Dict[WindowId, float] = {}
        self._hinted: Dict[WindowId, float] = {}
        self.stats = {"planned": 0, "immediate": 0, "readahead_hints": 0}

    def plan(self, window: WindowId, state: WindowState,
             exec_time: float, now: float,
             min_margin: float = 0.0) -> None:
        """Schedule pre-staging Δt before exec_time (clamped to now).

        ``min_margin``: lower bound on the lead time — the paper starts the
        *first* pre-staging pessimistically a full window ahead; the engine
        passes a fraction of the watermark period so the lead survives
        virtual-clock/wall-clock scale differences."""
        if self.punctuated:
            # no predictable re-execution time: stage immediately
            self.on_late_event(window, state, now)
            return
        p_events = sum(b.fill for b in state.p_blocks())
        dt = max(self.cost.delta_t(p_events), min_margin)
        stage_at = max(exec_time - dt, now)
        prev = self._planned.get(window)
        if prev is not None and prev <= stage_at:
            return
        self._planned[window] = stage_at
        heapq.heappush(self._heap, _Planned(stage_at, window))
        self.stats["planned"] += 1

    def on_late_event(self, window: WindowId, state: WindowState,
                      now: float) -> None:
        """Punctuated mode: a late event predicts an upcoming re-execution."""
        if self._planned.get(window) == now:
            return
        self._planned[window] = now
        heapq.heappush(self._heap, _Planned(now, window))
        self.stats["immediate"] += 1

    def due(self, now: float) -> List[WindowId]:
        out = []
        while self._heap and self._heap[0].stage_at <= now:
            item = heapq.heappop(self._heap)
            if self._planned.get(item.window) == item.stage_at:
                del self._planned[item.window]
                self._hinted.pop(item.window, None)
                out.append(item.window)
        return out

    def upcoming(self, now: float, horizon: float) -> List[WindowId]:
        """Windows whose pre-staging starts within ``horizon`` — the
        store-readahead hook: the engine drives the persistent tier's
        batched prefetch for these BEFORE their staging deadline, so the
        stage itself finds its blocks in the store's read cache. Each
        planned staging is hinted once (re-planning re-arms it)."""
        out = []
        for item in self._heap:
            stage_at = self._planned.get(item.window)
            if stage_at != item.stage_at:
                continue                       # superseded entry
            if now <= stage_at <= now + horizon \
                    and self._hinted.get(item.window) != stage_at:
                self._hinted[item.window] = stage_at
                self.stats["readahead_hints"] += 1
                out.append(item.window)
        return out

    def cancel(self, window: WindowId) -> None:
        self._planned.pop(window, None)
        self._hinted.pop(window, None)
