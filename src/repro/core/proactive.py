"""Proactive caching (paper §3.2): predict when a window will (re-)execute
and pre-stage its p-bucket state Δt ahead of that time.

* Periodic watermarks make re-execution times predictable: the engine knows
  the watermark period and the trigger's planned execution times. For the
  *first* late re-execution of window w, pre-staging starts pessimistically
  when the window preceding w fully expires; during that staging we measure
  Δt (staging seconds) weighted by the number of staged events, and use the
  per-event estimate for all subsequent pre-stagings.
* Punctuated watermarks carry no period: pre-staging starts as soon as a
  late event for w arrives (the re-execution it predicts may be delayed
  until pre-staging concludes).

This module is the paper's *fixed-margin* scheme: whole windows,
a Δt lead from one EWMA. The learned, segment-granular upgrade lives in
``repro.prefetch`` (``AionConfig.prefetch_backend="learned"``) and keeps
this scheduler's interface — the engine talks to either through the same
five methods (plan / on_late_event / due / drive_readahead / cancel).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.buckets import WindowState
from repro.core.windows import WindowId

# rebuild the plan heap once dead (superseded/cancelled) entries
# outnumber live ones AND there are enough of them to matter — lazy
# compaction keeps plan()/cancel() O(log n) while bounding the garbage
# that due()/upcoming() would otherwise scan forever
_HEAP_COMPACT_MIN = 16


@dataclass
class StagingCostModel:
    """Online Δt estimate: EWMA of staging seconds per event (the paper's
    'overall time taken weighted by the number of staged events').

    Before the FIRST observation the model is deliberately pessimistic:
    ``delta_t`` returns ``+inf`` so the first pre-staging starts as early
    as possible (the paper starts it when the preceding window fully
    expires). Afterwards the lead is clamped to ``floor_seconds`` —
    ``observe`` ignores zero-event stagings, so without the floor a
    window whose p-bucket happens to be empty at plan time would collapse
    the margin to exactly ``min_margin`` (or zero)."""
    seconds_per_event: float = 1e-6
    alpha: float = 0.3
    observations: int = 0
    # lower bound on the per-staging lead once observations exist
    floor_seconds: float = 1e-3

    def observe(self, seconds: float, events: int) -> None:
        if events <= 0:
            return
        per_event = seconds / events
        if self.observations == 0:
            self.seconds_per_event = per_event
        else:
            self.seconds_per_event = (self.alpha * per_event
                                      + (1 - self.alpha) * self.seconds_per_event)
        self.observations += 1

    def delta_t(self, events: int) -> float:
        if self.observations == 0:
            # first re-execution: no measurement yet — pre-stage as early
            # as the plan allows (pessimistic lead, paper §3.2)
            return float("inf")
        return max(self.seconds_per_event * max(events, 0),
                   self.floor_seconds)


@dataclass(order=True)
class _Planned:
    stage_at: float
    window: WindowId = field(compare=False)


class PrestageScheduler:
    """Decides *when* to issue stage requests for past windows.

    ``plan(window, exec_time, now)`` registers a future re-execution;
    ``due(now)`` returns windows whose pre-staging should start now.
    """

    def __init__(self, cost_model: Optional[StagingCostModel] = None,
                 punctuated: bool = False):
        self.cost = cost_model or StagingCostModel()
        self.punctuated = punctuated
        self._heap: List[_Planned] = []
        self._planned: Dict[WindowId, float] = {}
        self._hinted: Dict[WindowId, float] = {}
        # superseded/cancelled entries still sitting in _heap
        self._dead = 0
        self.stats = {"planned": 0, "immediate": 0, "readahead_hints": 0,
                      "heap_compactions": 0}

    def plan(self, window: WindowId, state: WindowState,
             exec_time: float, now: float,
             min_margin: float = 0.0) -> None:
        """Schedule pre-staging Δt before exec_time (clamped to now).

        ``min_margin``: lower bound on the lead time — the paper starts the
        *first* pre-staging pessimistically a full window ahead; the engine
        passes a fraction of the watermark period so the lead survives
        virtual-clock/wall-clock scale differences."""
        if self.punctuated:
            # no predictable re-execution time: stage immediately
            self.on_late_event(window, state, now)
            return
        p_events = sum(b.fill for b in state.p_blocks())
        dt = max(self.cost.delta_t(p_events), min_margin)
        stage_at = max(exec_time - dt, now)
        self._push(window, stage_at, "planned")

    def on_late_event(self, window: WindowId, state: WindowState,
                      now: float) -> None:
        """Punctuated mode: a late event predicts an upcoming re-execution."""
        if self._planned.get(window) == now:
            return
        self._push(window, now, "immediate", supersede_later=True)

    def observe_late(self, window: WindowId, keys: np.ndarray,
                     delays: np.ndarray) -> None:
        """Lateness observations (per-key delay samples). The fixed
        scheduler has no lateness model — the learned scheduler
        (``repro.prefetch``) overrides this hook."""

    def _push(self, window: WindowId, stage_at: float, stat: str,
              supersede_later: bool = False) -> None:
        prev = self._planned.get(window)
        if prev is not None:
            if not supersede_later and prev <= stage_at:
                return
            # the old heap entry becomes a tombstone
            self._dead += 1
        self._planned[window] = stage_at
        heapq.heappush(self._heap, _Planned(stage_at, window))
        self.stats[stat] += 1
        self._compact_heap()

    def _compact_heap(self) -> None:
        """Lazy tombstone reclamation: superseded plans and ``cancel``ed
        windows leave dead entries in ``_heap`` (a binary heap has no
        O(log n) remove). Once they dominate, rebuild the heap from the
        live plan map — keeps ``upcoming``'s scan and ``due``'s pops
        proportional to live plans instead of all plans ever made."""
        if self._dead < _HEAP_COMPACT_MIN or self._dead * 2 < len(self._heap):
            return
        self._heap = [_Planned(t, w) for w, t in self._planned.items()]
        heapq.heapify(self._heap)
        self._dead = 0
        self.stats["heap_compactions"] += 1

    def planned_stage_at(self, window: WindowId) -> Optional[float]:
        """Live staging deadline for ``window`` (None if not planned)."""
        return self._planned.get(window)

    def due(self, now: float) -> List[WindowId]:
        out = []
        while self._heap and self._heap[0].stage_at <= now:
            item = heapq.heappop(self._heap)
            if self._planned.get(item.window) == item.stage_at:
                del self._planned[item.window]
                self._hinted.pop(item.window, None)
                out.append(item.window)
            else:
                self._dead = max(self._dead - 1, 0)    # popped a tombstone
        return out

    def upcoming(self, now: float, horizon: float) -> List[WindowId]:
        """Windows whose pre-staging starts within ``horizon`` — the
        store-readahead hook: the engine drives the persistent tier's
        batched prefetch for these BEFORE their staging deadline, so the
        stage itself finds its blocks in the store's read cache. Each
        planned staging is hinted once (re-planning re-arms it)."""
        out = []
        for item in self._heap:
            stage_at = self._planned.get(item.window)
            if stage_at != item.stage_at:
                continue                       # tombstone (dead entry)
            if now <= stage_at <= now + horizon \
                    and self._hinted.get(item.window) != stage_at:
                self._hinted[item.window] = stage_at
                self.stats["readahead_hints"] += 1
                out.append(item.window)
        return out

    def drive_readahead(self, engine, now: float, horizon: float) -> None:
        """Fixed-margin readahead: point (per-window) store prefetch for
        the stagings coming up within the lead margin. The learned
        scheduler replaces this with segment-granular sweeps planned
        against a bandwidth/slack cost model."""
        if engine.io.store is None:
            return
        for wid in self.upcoming(now, horizon):
            state = engine.windows.get(wid)
            if state is not None:
                engine.io.request_readahead(state)

    def cancel(self, window: WindowId) -> None:
        if self._planned.pop(window, None) is not None:
            self._dead += 1                    # heap entry left behind
        self._hinted.pop(window, None)
        self._compact_heap()
