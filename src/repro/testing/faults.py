"""Deterministic, seedable fault injection for the storage/I-O stack.

``FaultInjector`` decides, per store operation, whether to inject a
failure (a ``TransientStoreError`` by default, a ``PermanentStoreError``
while poisoned) or added latency. Decisions come from a seeded RNG plus
optional per-operation *schedules* (exact call indices that must fail),
so every run of a chaos test sees the same fault sequence.

``FaultyBlockStore`` wraps any ``BlockStore`` and injects on the data
path (``get``/``get_many``/``put``/``commit``/``delete``/``readahead``/
``readahead_segments``); everything else delegates untouched, so the
engine's accounting, cost model and stats flow through the inner store
exactly as without the wrapper. ``crash()`` simulates a kill: file
handles are abandoned without a commit and the active log segment's tail
can be torn (truncated) — reopening a fresh store over the directory
exercises WAL recovery.

``TransferExecutor`` dispatch is hooked via ``executor.fault_hook``:
the injector's ``executor_hook`` runs before each task body and may
inject latency or a dispatch failure (recorded on the task's handle like
any other task exception).

The ``max_consecutive`` knob bounds runs of injected failures per
operation: after that many consecutive injections the next call is
forced through. With ``max_consecutive < io_retry_limit`` the retry
path *deterministically* succeeds — the chaos soak's
``io.stats['gave_up'] == 0`` assertion is exact, not probabilistic.
"""
from __future__ import annotations

import contextlib
import random
import time
from typing import Callable, Dict, Iterable, Optional, Sequence

from repro.storage.blockstore import (
    PermanentStoreError, TransientStoreError,
)

#: operations the injector can target (executor = task dispatch hook)
FAULT_OPS = ("get", "put", "commit", "delete", "readahead", "executor")


class FaultInjector:
    """Seeded per-operation fault decisions, shared by the store wrapper
    and the executor dispatch hook."""

    def __init__(self, seed: int = 0, *,
                 rates: Optional[Dict[str, float]] = None,
                 latency: float = 0.0,
                 max_consecutive: int = 0,
                 schedule: Optional[Dict[str, Sequence[int]]] = None):
        self.rng = random.Random(seed)
        self.rates = dict(rates or {})
        self.latency = latency
        self.max_consecutive = max_consecutive
        # op -> set of 0-based call indices that must fail (scripted
        # faults override the rate draw for those calls)
        self.schedule = {op: set(idx) for op, idx in (schedule or {}).items()}
        self.enabled = True
        self._poisoned: set = set()        # ops that raise permanently
        self._calls: Dict[str, int] = {}
        self._streak: Dict[str, int] = {}
        self.stats: Dict[str, int] = {"injected": 0, "latency_injections": 0}

    # ------------------------------------------------------------ control
    def poison(self, ops: Iterable[str]) -> None:
        """Make ``ops`` fail *permanently* (``PermanentStoreError`` on
        every call) until ``heal()`` — drives the restart/restore path."""
        self._poisoned.update(ops)

    def heal(self) -> None:
        self._poisoned.clear()

    @contextlib.contextmanager
    def paused(self):
        """No injection inside the block (checkpoints in chaos tests run
        clean — the checkpoint is the recovery anchor, not the victim)."""
        prev, self.enabled = self.enabled, False
        try:
            yield self
        finally:
            self.enabled = prev

    def fail_next(self, op: str, n: int = 1) -> None:
        """Script the next ``n`` calls of ``op`` to fail."""
        start = self._calls.get(op, 0)
        self.schedule.setdefault(op, set()).update(range(start, start + n))

    # ----------------------------------------------------------- decision
    def should_fail(self, op: str) -> bool:
        """One deterministic decision; advances the op's call counter."""
        idx = self._calls.get(op, 0)
        self._calls[op] = idx + 1
        if not self.enabled:
            return False
        if op in self._poisoned:
            return True
        scripted = idx in self.schedule.get(op, ())
        if self.max_consecutive and \
                self._streak.get(op, 0) >= self.max_consecutive:
            # bound the failure run: the retry that follows MUST succeed
            self._streak[op] = 0
            return False
        fail = scripted or self.rng.random() < self.rates.get(op, 0.0)
        self._streak[op] = self._streak.get(op, 0) + 1 if fail else 0
        return fail

    def maybe_fail(self, op: str) -> None:
        """Injected latency, then the fault decision; raises on fire."""
        if self.enabled and self.latency > 0:
            self.stats["latency_injections"] += 1
            time.sleep(self.latency)
        if self.should_fail(op):
            self.stats["injected"] += 1
            self.stats[op] = self.stats.get(op, 0) + 1
            if op in self._poisoned:
                raise PermanentStoreError(
                    f"injected permanent {op} failure")
            raise TransientStoreError(f"injected {op} failure")

    # ------------------------------------------------------ executor hook
    def executor_hook(self, task) -> None:
        """Install as ``TransferExecutor.fault_hook``: runs before each
        task body on the executor thread; an injected failure is recorded
        on the task's handle like any other task exception."""
        self.maybe_fail("executor")


class FaultyBlockStore:
    """Fault-injecting decorator over any ``BlockStore``.

    Data-path calls consult the injector first; everything else (stats,
    cost model, segment queries, compaction, inventory) delegates to the
    wrapped store, so the engine sees one store with occasional
    failures — not a different store."""

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector
        self.name = f"faulty-{inner.name}"

    # every non-overridden attribute (stats, simcost, durable_writes,
    # segments_for, compact_if_needed, ...) is the inner store's
    def __getattr__(self, item):
        return getattr(self.inner, item)

    # ------------------------------------------------------------- writes
    def put(self, window_key, block_id, arrays, fill):
        self.injector.maybe_fail("put")
        return self.inner.put(window_key, block_id, arrays, fill)

    def commit(self) -> None:
        self.injector.maybe_fail("commit")
        self.inner.commit()

    def delete(self, window_key, block_id) -> None:
        self.injector.maybe_fail("delete")
        self.inner.delete(window_key, block_id)

    # -------------------------------------------------------------- reads
    def get(self, window_key, block_id):
        self.injector.maybe_fail("get")
        return self.inner.get(window_key, block_id)

    def get_many(self, keys):
        self.injector.maybe_fail("get")
        return self.inner.get_many(keys)

    def readahead(self, keys) -> None:
        self.injector.maybe_fail("readahead")
        self.inner.readahead(keys)

    def readahead_segments(self, sid, keys) -> int:
        self.injector.maybe_fail("readahead")
        return self.inner.readahead_segments(sid, keys)

    # ----------------------------------------------------------- lifecycle
    def flush(self) -> None:
        self.commit()

    def close(self) -> None:
        # close is a clean-shutdown barrier, not a data-path op — tests
        # that want a dirty shutdown call crash() instead
        self.inner.close()

    def crash(self, torn_tail_bytes: int = 0) -> None:
        """Simulate a kill -9: abandon the inner store WITHOUT a commit
        (buffered tail records are lost, like a real crash) and
        optionally tear ``torn_tail_bytes`` off the active log segment —
        the torn-tail case WAL recovery must truncate on reopen."""
        f = getattr(self.inner, "_active_f", None)
        if f is not None:
            try:
                f.close()                  # no flush-to-disk guarantee
            except Exception:
                pass
        path_fn = getattr(self.inner, "active_segment_path", None)
        if torn_tail_bytes > 0 and path_fn is not None:
            path = path_fn()
            if path is not None and path.exists():
                size = path.stat().st_size
                with open(path, "ab") as fh:
                    fh.truncate(max(size - torn_tail_bytes, 0))
