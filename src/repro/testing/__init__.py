"""Deterministic fault injection for the storage / I-O / pipeline stack
(ISSUE 9): every failure mode the self-healing path claims to handle is
drivable from tests and the chaos soak."""
from repro.testing.faults import FaultInjector, FaultyBlockStore

__all__ = ["FaultInjector", "FaultyBlockStore"]
