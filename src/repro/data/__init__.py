from repro.data.generators import (
    WorkloadGenerator,
    make_generator,
    lateness_delays,
)
from repro.data.pipeline import PrefetchPipeline

__all__ = ["WorkloadGenerator", "make_generator", "lateness_delays",
           "PrefetchPipeline"]
