"""Host-side prefetch pipeline: produce -> stage -> consume, double-buffered.

The producer thread generates/loads batches into a bounded queue; the
stager moves them to device ahead of consumption (``jax.device_put``
without blocking), so step N's compute overlaps step N+1's H2D — the same
decoupled-transfer principle as the engine's proactive caching, applied to
input data. Key-hash sharded ingest splits batches per data shard (the
Flink keyBy analogue).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax

from repro.core.events import EventBatch


class PrefetchPipeline:
    def __init__(self, source: Iterator[Any], *, depth: int = 2,
                 to_device: bool = True,
                 transform: Optional[Callable[[Any], Any]] = None):
        self.source = source
        self.depth = depth
        self.to_device = to_device
        self.transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self.source:
                if self._stop.is_set():
                    return
                if self.transform is not None:
                    item = self.transform(item)
                if self.to_device:
                    item = jax.tree.map(
                        lambda x: jax.device_put(x)
                        if hasattr(x, "shape") else x, item)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        finally:
            self._q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is StopIteration:
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()


def sharded_ingest(batch: EventBatch, num_shards: int):
    """Partition an event batch by key hash for distributed ingest."""
    return batch.partition_by_shard(num_shards)
