"""Synthetic event generators for the paper's workloads (§5).

Event timestamps follow the paper exactly:

    ts = currentTime - windowIndex * windowDuration

with windowIndex drawn from a log-normal distribution (mean 0, std 1), so
the likelihood a past window receives an event decays exponentially. Q4
also evaluates uniform / normal / bursty lateness distributions — all four
are provided by ``lateness_delays``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.workloads import WorkloadConfig
from repro.core.events import EventBatch


def lateness_delays(dist: str, n: int, horizon: float,
                    rng: np.random.Generator) -> np.ndarray:
    """Late-event delay samples in [0, horizon] for the Q4 distributions."""
    if dist == "lnorm":
        d = rng.lognormal(0.0, 1.0, n) * (horizon / 20.0)
    elif dist == "unif":
        d = rng.uniform(0, horizon, n)
    elif dist == "norm":
        d = rng.normal(horizon / 2, horizon / 8, n)
    elif dist == "bursts":
        centers = rng.choice([0.1, 0.35, 0.7, 0.9], n) * horizon
        d = centers + rng.normal(0, horizon / 40, n)
    else:
        raise ValueError(f"unknown distribution {dist!r}")
    return np.clip(d, 1e-6, horizon)


@dataclass
class WorkloadGenerator:
    cfg: WorkloadConfig
    seed: int = 0
    lateness_dist: str = "lnorm"

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self.width = self.cfg.resolved_value_width()

    def _values(self, n: int) -> np.ndarray:
        op = self.cfg.operator
        if op == "average":
            v = self.rng.integers(0, 1000, (n, self.width)).astype(np.float32)
        elif op == "bigrams":
            # token mini-documents (tweets)
            v = self.rng.integers(0, 255, (n, self.width)).astype(np.float32)
        elif op == "stock":
            base = self.rng.uniform(10, 500, (n, 1)).astype(np.float32)
            noise = self.rng.normal(0, 0.02, (n, self.width)).astype(np.float32)
            v = base * (1 + noise)
        elif op == "lrb":
            v = np.zeros((n, self.width), np.float32)
            v[:, 0] = np.maximum(self.rng.normal(55, 20, n), 0)  # speed
            stopped = self.rng.random(n) < 0.01
            v[stopped, 0] = 0.0
            if self.width > 1:
                v[:, 1] = self.rng.integers(0, 4, n)             # lane
        else:
            v = self.rng.normal(size=(n, self.width)).astype(np.float32)
        return v

    def batch(self, n: int, now: float) -> EventBatch:
        """Generate n events at processing time ``now`` with the paper's
        timestamp model (window_index ~ floor(lognormal))."""
        wd = self.cfg.window_duration
        widx = np.floor(self.rng.lognormal(0.0, 1.0, n)).astype(np.int64)
        ts = now - widx * wd - self.rng.uniform(0, wd, n)
        ts = np.maximum(ts, 0.0)
        keys = self.rng.integers(0, self.cfg.num_keys, n).astype(np.int32)
        return EventBatch(keys, ts, self._values(n))

    def stream(self, *, events_per_batch: int, start: float = 0.0,
               rate: Optional[float] = None) -> Iterator[EventBatch]:
        """Infinite stream; ``rate`` defaults to the workload's max
        ingestion rate. Yields (batch at virtual time now)."""
        rate = rate or self.cfg.max_ingestion_rate
        now = start
        while True:
            yield now, self.batch(events_per_batch, now)
            now += events_per_batch / rate


def make_generator(cfg: WorkloadConfig, seed: int = 0,
                   lateness_dist: str = "lnorm") -> WorkloadGenerator:
    return WorkloadGenerator(cfg, seed=seed, lateness_dist=lateness_dist)


def token_batches(vocab_size: int, batch: int, seq_len: int, seed: int = 0
                  ) -> Iterator[dict]:
    """LM training batches (synthetic next-token data for examples)."""
    rng = np.random.default_rng(seed)
    while True:
        toks = rng.integers(0, vocab_size, (batch, seq_len + 1),
                            dtype=np.int32)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
