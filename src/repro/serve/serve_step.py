"""Serving step factories.

``decode_step``: one new token against an existing KV/SSM cache (the shape
cells ``decode_32k`` / ``long_500k`` lower exactly this). Greedy sampling
keeps the step closed over integer tokens (tokens in -> tokens out), which
is what a production decode loop ships between hosts.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def make_decode_step(model: Model):
    def decode_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache
    return decode_step


def make_prefill_step(model: Model, max_len: int = 0):
    def prefill_step(params, batch):
        logits, cache = model.prefill(
            params, batch, max_len=max_len or None)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache
    return prefill_step
