"""Continuous-batching request scheduler over the tiered KV cache.

Decode-centric loop (vLLM-style, TPU-adapted): a fixed decode batch of
sessions steps one token at a time through ``decode_attention_paged``;
sessions join as pages allow and leave on completion. Before each step the
scheduler (a) stages any host-resident pages of scheduled sessions
(staging = max priority), (b) pre-stages sessions predicted to arrive
within the horizon (proactive caching), (c) evicts idle sessions past the
adaptive bound (predictive cleanup).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention_paged
from repro.serve.kvcache import TieredKVCache


@dataclass
class Request:
    request_id: int
    session_id: int
    prompt_len: int
    max_new_tokens: int
    arrived_at: float
    generated: int = 0
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class ContinuousBatcher:
    def __init__(self, cache: TieredKVCache, *, max_batch: int = 8,
                 pages_per_seq: int = 64, prestage_horizon: float = 0.5):
        self.cache = cache
        self.max_batch = max_batch
        self.pages_per_seq = pages_per_seq
        self.prestage_horizon = prestage_horizon
        self.waiting: Deque[Request] = deque()
        self.active: List[Request] = []
        self.completed: List[Request] = []
        self.steps = 0

    def submit(self, req: Request, k_prompt: np.ndarray,
               v_prompt: np.ndarray, now: float) -> None:
        """k/v_prompt: [L, prompt_len, Hkv, D] precomputed prompt KV
        (prefill output)."""
        s = self.cache.open_session(req.session_id, now)
        for t in range(req.prompt_len):
            ok = self.cache.append_token_kv(
                req.session_id, k_prompt[:, t], v_prompt[:, t], now)
            if not ok:
                break
        self.waiting.append(req)

    def _admit(self, now: float) -> None:
        while self.waiting and len(self.active) < self.max_batch:
            self.active.append(self.waiting.popleft())

    def step(self, q_fn: Callable[[List[int]], jnp.ndarray],
             kv_fn: Callable[[List[int]], np.ndarray], now: float
             ) -> Optional[jnp.ndarray]:
        """One decode step for the active batch.

        q_fn(session_ids)  -> [B, H, D] per-session query vectors
        kv_fn(session_ids) -> ([B, L, Hkv, D], same) new-token K/V to append
        Returns attention outputs [B, H, D] (or None if batch empty).
        """
        self._admit(now)
        if not self.active:
            self.cache.prestage_due(now, self.prestage_horizon)
            self.cache.cleanup_idle(now)
            return None
        sids = [r.session_id for r in self.active]
        for sid in sids:
            self.cache.observe_arrival(sid, now)

        table, lens, missing = self.cache.block_table(sids,
                                                      self.pages_per_seq)
        # staging has max priority: bring any cold pages in before compute
        for sid, li in missing:
            self.cache._stage_page(sid, li, now)
        if missing:
            table, lens, _ = self.cache.block_table(sids, self.pages_per_seq)

        q = q_fn(sids)
        # the scheduler drives attention layer-by-layer; layer 0 shown here
        # (the serve driver loops the model's layers over the same table)
        out = decode_attention_paged(q, self.cache.k_pool[0],
                                     self.cache.v_pool[0], table, lens)

        k_new, v_new = kv_fn(sids)
        for i, req in enumerate(self.active):
            self.cache.append_token_kv(req.session_id, k_new[i], v_new[i],
                                       now)
            req.generated += 1
            if req.first_token_at is None:
                req.first_token_at = now
            if req.generated >= req.max_new_tokens:
                req.done = True
                req.finished_at = now
                self.cache.sessions[req.session_id].finished = True
        self.completed.extend(r for r in self.active if r.done)
        self.active = [r for r in self.active if not r.done]

        # background work (low priority): proactive staging + cleanup
        self.cache.prestage_due(now, self.prestage_horizon)
        self.cache.cleanup_idle(now)
        self.steps += 1
        return out
